#!/usr/bin/env python
"""Bench-regression gate: compare freshly emitted BENCH_*.json rows
against the committed baselines in benchmarks/baselines/.

    python scripts/check_bench.py                 # gate (exit 1 on regression)
    python scripts/check_bench.py --tol 0.5       # widen the tolerance
    python scripts/check_bench.py --bless         # accept current as baseline
    BENCH_TOL=0.5 python scripts/check_bench.py   # env override

Each benchmark file has one gated metric with a known good direction
(lower-better us/vec for kernels, higher-better vecs/s / QPS for encode
and search).

The comparison is LOAD-NORMALIZED: shared-CI machines drift 2-3x with
background load, which moves every row of a file together, while a real
perf cliff (a fusion silently disabled, a kernel falling back) moves
specific rows against the rest. So a row regresses when its drift vs
baseline exceeds the file's MEDIAN drift by more than the relative
tolerance (default +-35%); the median drift itself is only flagged past
a much wider global backstop (default 4x) that machine weather does not
reach. Blind spot, accepted: a uniform whole-file regression smaller
than the backstop rides the normalization — the per-row check exists to
catch op-level cliffs, the backstop to catch collapse.

Rows present on only one side (new ops, retired ops) are reported but
never fail the gate; re-bless to adopt them. A missing baseline file is
a note, not a failure, so bootstrapping a new BENCH artifact doesn't
brick CI. Baselines bless via PESSIMISTIC per-row merge (see --bless):
they converge to the slow edge of the machine's noise band, so normal
runs — including slow-mode runs of bimodal rows — land inside the band
and a real cliff falls out of it. `scripts/ci.sh` runs this after the
bench smokes (with one re-measure retry); set BENCH_GATE=0 there to
skip it entirely (escape hatch for known-noisy machines).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BASELINE_DIR = REPO / "benchmarks" / "baselines"

# file -> (row-key fields, gated metric, direction)
SPECS = {
    "BENCH_kernels.json": (("op", "backend", "mode"), "us_per_vec", "lower"),
    "BENCH_encode.json": (("op", "backend", "fused", "mode"), "vecs_per_s",
                          "higher"),
    "BENCH_search.json": (("mode", "n_shards"), "qps", "higher"),
}


def _rows(path: Path):
    data = json.loads(path.read_text())
    return data["rows"] if isinstance(data, dict) else data


def _key(row, fields):
    return tuple(str(row.get(f)) for f in fields)


def check_file(name: str, tol: float, global_tol: float) -> tuple:
    """-> (n_regressions, lines to print)."""
    fields, metric, direction = SPECS[name]
    fresh_p, base_p = REPO / name, BASELINE_DIR / name
    if not fresh_p.exists():
        return 1, [f"  MISSING fresh {name} (bench smoke did not run?)"]
    if not base_p.exists():
        return 0, [f"  no baseline {base_p.relative_to(REPO)} — skipped "
                   f"(run scripts/check_bench.py --bless to create)"]
    fresh = {_key(r, fields): r[metric] for r in _rows(fresh_p)}
    base = {_key(r, fields): r[metric] for r in _rows(base_p)}
    # per-row drift in log space, oriented so "worse" is positive
    drift = {}
    for k in base.keys() & fresh.keys():
        b, f = base[k], fresh[k]
        if b > 0 and f > 0:
            drift[k] = (math.log(f / b) if direction == "lower"
                        else math.log(b / f))
    med = statistics.median(drift.values()) if drift else 0.0
    # center only on machine-wide SLOWNESS: against the pessimistic
    # baselines a quiet run drifts negative across the board, and
    # centering on that would punish any row sitting at its slow edge
    med_c = max(med, 0.0)
    lines, bad = [], 0
    width = max((len(",".join(k)) for k in fresh | base), default=10)
    lines.append(f"  load drift (median across rows): "
                 f"{math.exp(med) - 1:+.1%} "
                 f"(slowness normalized out; backstop {global_tol:.0%})")
    lines.append(f"  {'row'.ljust(width)}  {'base':>12} {'fresh':>12} "
                 f"{'drift':>8} {'vs med':>8}  status")
    for k in sorted(base):
        label = ",".join(k).ljust(width)
        if k not in fresh:
            lines.append(f"  {label}  {base[k]:12.3f} {'-':>12} {'-':>8} "
                         f"{'-':>8}  gone (not gated)")
            continue
        rel = math.exp(drift.get(k, 0.0)) - 1            # worse-oriented
        excess = math.exp(drift.get(k, 0.0) - med_c) - 1  # vs machine drift
        worse = excess > tol
        bad += worse
        lines.append(f"  {label}  {base[k]:12.3f} {fresh[k]:12.3f} "
                     f"{rel:+7.1%} {excess:+7.1%}  "
                     f"{'REGRESSION' if worse else 'ok'}")
    for k in sorted(fresh.keys() - base.keys()):
        lines.append(f"  {','.join(k).ljust(width)}  {'-':>12} "
                     f"{fresh[k]:12.3f} {'-':>8} {'-':>8}  new (not gated)")
    if med > math.log1p(global_tol):
        bad += 1
        lines.append(f"  GLOBAL REGRESSION: median drift "
                     f"{math.exp(med) - 1:+.1%} exceeds the "
                     f"{global_tol:.0%} backstop — the whole file got "
                     f"slower, beyond machine weather")
    return bad, lines


def bless(reset: bool = False, names=None) -> int:
    """Adopt current BENCH_*.json values as baselines.

    By default each row MERGES pessimistically with the existing
    baseline (keep the slower us/vec, the lower vecs/s-or-QPS):
    repeated blessing converges every baseline to the slow edge of the
    machine's noise band. That is the right reference for regression
    DETECTION on a noisy box — normal runs land inside the band and
    pass, and a genuine cliff falls below it. Blessing against the fast
    edge would instead flag every slow-mode run of a bimodal row.
    ``--bless-reset`` overwrites outright (use after an intentional perf
    change or on a new machine). Both accept a file subset, so one
    artifact can be reset after an intentional perf change without
    touching the others' noise bands:

        scripts/check_bench.py --bless-reset BENCH_search.json
    """
    BASELINE_DIR.mkdir(parents=True, exist_ok=True)
    for name in (names or sorted(SPECS)):
        fields, metric, direction = SPECS[name]
        src = REPO / name
        if not src.exists():
            print(f"[check_bench] {name} not present; skipped")
            continue
        data = json.loads(src.read_text())
        base_p = BASELINE_DIR / name
        if not reset and base_p.exists():
            old = {_key(r, fields): r[metric] for r in _rows(base_p)}
            pick = max if direction == "lower" else min
            for r in data["rows"]:
                k = _key(r, fields)
                if k in old:
                    r[metric] = pick(r[metric], old[k])
        base_p.write_text(json.dumps(data, indent=2))
        print(f"[check_bench] blessed {name} -> "
              f"{base_p.relative_to(REPO)}"
              f"{' (reset)' if reset else ' (pessimistic merge)'}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", 0.35)),
                    help="per-row tolerance vs the file's median drift "
                         "(default 0.35)")
    ap.add_argument("--global-tol", type=float,
                    default=float(os.environ.get("BENCH_GLOBAL_TOL", 3.0)),
                    help="backstop on the median drift itself "
                         "(default 3.0 = whole file 4x slower)")
    ap.add_argument("--bless", action="store_true",
                    help="adopt current BENCH_*.json as baselines "
                         "(pessimistic per-row merge with existing)")
    ap.add_argument("--bless-reset", action="store_true",
                    help="overwrite baselines outright (after an "
                         "intentional perf change / new machine)")
    ap.add_argument("files", nargs="*", default=None,
                    help=f"subset of {sorted(SPECS)} (default: all)")
    args = ap.parse_args(argv)
    names = args.files or sorted(SPECS)
    unknown = [n for n in names if n not in SPECS]
    if unknown:
        ap.error(f"unknown bench files {unknown}; known: {sorted(SPECS)}")
    if args.bless or args.bless_reset:
        return bless(reset=args.bless_reset, names=names)
    total_bad = 0
    for name in names:
        bad, lines = check_file(name, args.tol, args.global_tol)
        total_bad += bad
        print(f"[check_bench] {name} (tol +-{args.tol:.0%} vs median "
              f"drift):")
        print("\n".join(lines))
    if total_bad:
        print(f"[check_bench] FAIL: {total_bad} row(s) regressed beyond "
              f"+-{args.tol:.0%} (re-run, widen BENCH_TOL, or "
              f"`scripts/check_bench.py --bless` if intentional)")
        return 1
    print("[check_bench] OK: no bench regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
