#!/usr/bin/env bash
# Tier-1 CI entry point.
#   scripts/ci.sh           full suite (what the driver runs)
#   QUICK=1 scripts/ci.sh   skip the slow (dry-run subprocess) suites
set -euo pipefail
cd "$(dirname "$0")/.."

# dev-only deps (hypothesis): best-effort — the suite degrades gracefully
# (property tests skip) when the environment is offline.
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "[ci] dev deps unavailable (offline?); continuing without"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# index-store smoke: save -> load -> search round trip in a tmpdir (fast;
# guards the on-disk format independently of the pytest suite)
python - <<'PY'
import tempfile, shutil
import numpy as np, jax, jax.numpy as jnp
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_index_smoke_")
try:
    IndexStore.save(d, idx, shard_size=256)
    loaded = IndexStore(d).load()
    assert loaded.codes.dtype == jnp.uint8
    q = jnp.asarray(xb[:5] + 0.01)
    kw = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3, cfg=cfg)
    i1, s1 = search.search(idx, q, **kw)
    i2, s2 = search.search(loaded, q, **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    print("[ci] index store smoke OK (save -> load -> search bit-identical)")
finally:
    shutil.rmtree(d, ignore_errors=True)
PY

# kernel-backend smoke: xla vs pallas per-op timings for every dispatch op
# (incl. the fused f_theta / adc_topk paths) -> BENCH_kernels.json, so each
# CI run leaves a machine-readable perf data point
python -m benchmarks.run --only backends
test -s BENCH_kernels.json \
    && echo "[ci] kernel backends smoke OK (BENCH_kernels.json written)"

# encode-throughput smoke: fused vs unfused beam steps across the (A, B)
# grid on both backends -> BENCH_encode.json (the encode perf trajectory)
python -m benchmarks.run --only encode
test -s BENCH_encode.json \
    && echo "[ci] encode throughput smoke OK (BENCH_encode.json written)"

if [ "${QUICK:-0}" = "1" ]; then
    exec python -m pytest -q -m "not slow" "$@"
fi
exec python -m pytest -q "$@"
