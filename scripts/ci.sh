#!/usr/bin/env bash
# Tier-1 CI entry point.
#   scripts/ci.sh             full suite (what the driver runs)
#   QUICK=1 scripts/ci.sh     skip the slow (dry-run subprocess) suites
#   BENCH_GATE=0 scripts/ci.sh  skip the bench-regression gate
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# ---- tier 0: static checks (seconds) ----------------------------------------
# syntax breakage anywhere fails before any smoke spends minutes compiling
python -m compileall -q src tests benchmarks
# ...and import breakage in any repro.* module (circular imports, renamed
# symbols, missing gates on optional deps)
python - <<'PY'
import importlib, pkgutil, sys
import repro
bad = []
for m in pkgutil.walk_packages(repro.__path__, "repro."):
    try:
        importlib.import_module(m.name)
    except Exception as e:
        bad.append(f"{m.name}: {type(e).__name__}: {e}")
if bad:
    sys.exit("[ci] import check FAILED:\n  " + "\n  ".join(bad))
print("[ci] static tier OK (compileall + repro.* imports)")
PY

# dev-only deps (hypothesis): best-effort — the suite degrades gracefully
# (property tests skip) when the environment is offline. Skip the install
# (and its network timeout) entirely when hypothesis is already importable.
if python -c "import hypothesis" 2>/dev/null; then
    echo "[ci] dev deps present (hypothesis importable); skipping pip install"
else
    python -m pip install -q -r requirements-dev.txt 2>/dev/null \
        || echo "[ci] dev deps unavailable (offline?); continuing without"
fi

# index-store smoke: save -> load -> search round trip in a tmpdir (fast;
# guards the on-disk format independently of the pytest suite), plus the
# out-of-core path: search_sharded over the same store must be bit-identical
python - <<'PY'
import tempfile, shutil
import numpy as np, jax, jax.numpy as jnp
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore, ShardedIndexView

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_index_smoke_")
try:
    IndexStore.save(d, idx, shard_size=256)
    loaded = IndexStore(d).load()
    assert loaded.codes.dtype == jnp.uint8
    q = jnp.asarray(xb[:5] + 0.01)
    kw = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3, cfg=cfg)
    i1, s1 = search.search(idx, q, **kw)
    i2, s2 = search.search(loaded, q, **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    view = ShardedIndexView(d, max_resident_shards=1)
    i3, s3 = search.search_sharded(view, q, **kw)          # prefetch default
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i3))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s3))
    i4, s4 = search.search_sharded(view, q, prefetch=False, **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i4))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s4))
    assert view.peak_resident_bytes <= view.budget_bytes
    assert view.pool.peak_resident_entries <= 1, \
        "prefetch over-allocated past max_resident_shards"
    print("[ci] index store smoke OK (save -> load -> search bit-identical; "
          "out-of-core search_sharded bit-identical with prefetch on AND "
          "off, staging pool within the LRU budget)")
finally:
    shutil.rmtree(d, ignore_errors=True)
PY

# serve + telemetry smoke: drive the out-of-core server end to end with a
# live metrics endpoint, scrape it over real HTTP, and assert the core
# series exist and are self-consistent (docs/OBSERVABILITY.md)
python - <<'PY'
import json, tempfile, shutil, urllib.request
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore
import repro.launch.serve_search as serve_search

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_serve_smoke_")
try:
    IndexStore.save(d, idx, shard_size=128)
    sj = d + "/stats.jsonl"
    stats = serve_search.main([
        "--store", d, "--queries", "64", "--micro-batch", "8",
        "--out-of-core", "--max-resident-shards", "2",
        "--metrics-port", "0", "--stats-json", sj])
    assert stats.p99_ms >= stats.p50_ms > 0, (stats.p50_ms, stats.p99_ms)
    rec = json.loads(open(sj).read().strip())
    assert rec["n_queries"] == 64 and "staging" in rec, sorted(rec)
    url = serve_search.last_metrics_server.url
    text = urllib.request.urlopen(url + "/metrics").read().decode()
    for series in ("serve_latency_seconds_count", "serve_queries_total",
                   "serve_batches_total", "staging_staged_total",
                   "staging_stall_seconds_total",
                   "search_sharded_calls_total"):
        assert series in text, f"missing series {series} in /metrics"
    snap = json.loads(
        urllib.request.urlopen(url + "/metrics.json").read())
    staged = obs.series_value(snap, "staging_staged_total")
    pf_hits = obs.series_value(snap, "staging_prefetch_hits_total")
    assert staged > 0 and pf_hits <= staged, (pf_hits, staged)
    assert obs.series_value(snap, "serve_queries_total") >= 64
    print("[ci] serve telemetry smoke OK (endpoint scraped; core series "
          "present; prefetch_hits <= staged; stats-json line written)")
finally:
    serve_search.last_metrics_server.close()
    shutil.rmtree(d, ignore_errors=True)
PY

# chaos + fsck smoke: serve a full stream through an active FaultPlan
# (read errors retried away, corrupt shards quarantined, degraded queries
# reporting coverage < 1.0, zero crashes) with the fault counters scraped
# over live HTTP; then fsck a deliberately corrupted copy of the store and
# check it names the bad shard. The seed is picked via the FaultPlan
# decision predicates, so the "at least one corrupt shard / one read
# error" scenario is guaranteed, not probabilistic.
python - <<'PY'
import json, shutil, tempfile, urllib.request
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import FaultPlan, IndexStore, corrupt_file, fsck_store
from repro.index import fsck as fsck_mod
import repro.launch.serve_search as serve_search

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_chaos_smoke_")
try:
    IndexStore.save(d, idx, shard_size=128)
    store = IndexStore(d)
    n_shards = store.manifest["n_shards"]

    # fsck: clean store passes; a corrupted copy fails, naming the shard
    assert fsck_store(store, log=lambda *a, **k: None)["ok"]
    bad_dir = d + "_corrupt"
    shutil.copytree(d, bad_dir)
    corrupt_file(IndexStore(bad_dir).shard_dir(2) / "codes.u8", seed=1)
    assert fsck_mod.main([bad_dir, "--json"]) == 1
    report = fsck_store(bad_dir, log=lambda *a, **k: None)
    assert report["shards_corrupt"] == [2], report
    assert any("shard 00002" in e and "codes.u8" in e
               for e in report["errors"]), report["errors"]
    shutil.rmtree(bad_dir, ignore_errors=True)

    # chaos serve: ~20% faults, seeded so >= 1 shard corrupts (but not
    # all) and >= 1 transient read error fires on a healthy shard
    seed = next(
        s for s in range(1000)
        if 1 <= sum(FaultPlan(s, p_corrupt=0.2).corrupts(sid)
                    for sid in range(n_shards)) < n_shards
        and any(FaultPlan(s, p_read_error=0.25).would_read_error(sid, 0)
                and not FaultPlan(s, p_corrupt=0.2).corrupts(sid)
                for sid in range(n_shards)))
    spec = (f"p_read_error=0.25,read_error_max_per_key=1,"
            f"p_corrupt=0.2,seed={seed}")
    sj = d + "/stats.jsonl"
    stats = serve_search.main([
        "--store", d, "--queries", "64", "--micro-batch", "8",
        "--out-of-core", "--max-resident-shards", "2", "--no-prefetch",
        "--chaos", spec, "--on-shard-error", "skip",
        "--metrics-port", "0", "--stats-json", sj])
    assert stats.n_queries == 64                 # stream completed
    assert stats.degraded_queries >= 1, stats
    assert stats.mean_coverage < 1.0, stats
    rec = json.loads(open(sj).read().strip())
    assert rec["staging"]["quarantined_shards"] >= 1, rec["staging"]
    url = serve_search.last_metrics_server.url
    snap = json.loads(urllib.request.urlopen(url + "/metrics.json").read())
    assert obs.series_value(snap, "index_quarantined_shards_total") >= 1
    assert obs.series_value(snap, "index_integrity_failures_total") >= 1
    assert obs.series_value(snap, "staging_retries_total") >= 1
    assert obs.series_value(snap, "faults_injected_total") >= 2
    assert obs.series_value(snap, "serve_degraded_queries_total") >= 1
    print("[ci] chaos + fsck smoke OK (fsck names the corrupt shard; "
          "degraded serving completed under injected faults with "
          "quarantine/retry/degraded counters live on /metrics)")
finally:
    if serve_search.last_metrics_server is not None:
        serve_search.last_metrics_server.close()
    shutil.rmtree(d, ignore_errors=True)
PY

# socket front-door smoke: a real server subprocess on an ephemeral port,
# driven by the chaos client (connection drops + a malformed frame + a
# vanishing client + an overload burst that sheds), counters asserted over
# the live /metrics endpoint, then SIGTERM mid-stream -> graceful drain:
# every accepted query answered exactly once, stats flushed, exit 0
# (docs/SERVING.md)
python - <<'PY'
import json, os, shutil, signal, subprocess, sys, tempfile, threading
import time, urllib.request
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import FaultPlan, IndexStore
from repro.launch import transport as tp
from repro.launch.search_client import (STATUS_VANISHED, SearchClient,
                                        run_open_loop)

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_socket_smoke_")
proc = None
try:
    IndexStore.save(d, idx, shard_size=256)
    pf, sj, log = d + "/ports.json", d + "/stats.jsonl", d + "/server.log"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_search",
         "--store", d, "--port", "0", "--port-file", pf,
         "--metrics-port", "0", "--micro-batch", "8",
         "--max-queue", "16", "--shed-watermark", "0.5",
         "--max-wait-ms", "1", "--stats-json", sj],
        stdout=open(log, "w"), stderr=subprocess.STDOUT,
        env=dict(os.environ, PYTHONPATH="src"))
    t0 = time.time()
    while not os.path.exists(pf):                 # warmup compile
        assert proc.poll() is None, open(log).read()
        assert time.time() - t0 < 180, "server never bound"
        time.sleep(0.2)
    ports = json.load(open(pf))
    port, murl = ports["port"], f"http://127.0.0.1:{ports['metrics_port']}"
    assert urllib.request.urlopen(murl + "/healthz").status == 200
    assert urllib.request.urlopen(murl + "/readyz").status == 200

    q = np.asarray(xb[:1] + 0.01, np.float32)
    ok_rows = vanished = 0

    # chaos: a connection drop that the retry clears (the dropped frame
    # was never admitted -> no duplicate), one malformed frame answered
    # INVALID and survived, one client that vanishes before its reply
    seed = next(s for s in range(2000)
                if FaultPlan(s, p_conn_drop=0.5).would_conn_drop(0, 0)
                and not FaultPlan(s, p_conn_drop=0.5).would_conn_drop(0, 1))
    fp_drop = FaultPlan(seed, p_conn_drop=0.5)
    r = SearchClient("127.0.0.1", port, faults=fp_drop,
                     max_retries=4).search(q, req_key=0)
    assert r.ok and r.retries == 1, (r.status, r.retries)
    assert fp_drop.injected.get("conn_drop") == 1
    ok_rows += 1
    fp_bad = FaultPlan(0, p_malformed=1.0)
    r = SearchClient("127.0.0.1", port, faults=fp_bad).search(q, req_key="m")
    assert r.ok and fp_bad.injected.get("malformed") == 1
    ok_rows += 1
    fp_gone = FaultPlan(0, p_client_vanish=1.0)
    r = SearchClient("127.0.0.1", port, faults=fp_gone).search(q,
                                                               req_key="v")
    assert r.status == STATUS_VANISHED
    vanished += 1

    # overload burst past the watermark: 30 concurrent full-micro-batch
    # requests (240 rows) against an 8-row queue cap — the aggregate
    # service time dwarfs the arrival window, so shedding is structural,
    # not a scheduling accident. Sheds are typed + hinted; retries clear
    # some; exhausted requests end shed (never admitted, never doubled).
    q8 = np.repeat(q, 8, axis=0)
    burst = SearchClient("127.0.0.1", port, max_retries=10,
                         backoff_base_s=0.02)
    results = [None] * 30
    ts = [threading.Thread(target=lambda i=i: results.__setitem__(
        i, burst.search(q8, req_key=f"b{i}"))) for i in range(30)]
    for t in ts: t.start()
    for t in ts: t.join(30)
    assert all(r is not None for r in results)
    ok_rows += 8 * sum(1 for r in results if r.ok)
    assert sum(r.retries for r in results) >= 1, "burst never retried"

    snap = json.loads(urllib.request.urlopen(murl + "/metrics.json").read())
    sv = lambda name, **kw: obs.series_value(snap, name, **kw)
    assert sv("transport_conn_aborts_total") >= 1        # the dropped conn
    assert sv("transport_frame_errors_total") >= 1       # the garbage frame
    assert sv("frontdoor_shed_total") >= 1, "burst never shed"
    assert sv("frontdoor_accepted_total", tenant="default") \
        == sv("frontdoor_answered_total", tenant="default"), \
        "accepted != answered at quiescence"

    # SIGTERM mid-stream: open-loop load is still arriving when the drain
    # starts; accepted-before-drain queries are answered, late ones get
    # UNAVAILABLE / a closed listener, the process exits 0
    stream = SearchClient("127.0.0.1", port, max_retries=0, timeout_s=10)
    qs = np.repeat(q, 400, axis=0)
    box = {}
    th = threading.Thread(target=lambda: box.update(
        st=run_open_loop(stream, qs, 300.0, seed=1)))
    th.start()
    time.sleep(0.4)
    proc.send_signal(signal.SIGTERM)
    assert proc.wait(timeout=60) == 0, open(log).read()
    th.join(30)
    ok_rows += int(box["st"].n_ok)

    rec = json.loads(open(sj).read().strip())
    assert rec["drained_clean"], rec
    assert rec["n_accepted"] == rec["n_answered"], rec
    # exactly once, end to end: every accepted query is accounted for by
    # a client-received OK or the one deliberately vanished client
    assert rec["n_accepted"] == ok_rows + vanished, (
        rec["n_accepted"], ok_rows, vanished)
    assert rec["n_shed"] >= 1 and rec["n_batches"] >= 1, rec
    print("[ci] socket front-door smoke OK (chaos client survived drops/"
          "malformed/vanish; shed+retry cleared the burst; SIGTERM drained "
          f"{rec['n_accepted']} accepted == {rec['n_answered']} answered "
          "exactly once; exit 0)")
finally:
    if proc is not None and proc.poll() is None:
        proc.kill()
    shutil.rmtree(d, ignore_errors=True)
PY

# live-mutation smoke: a --refresh-ms server subprocess picks up appends,
# deletes, and a compaction published by a mutator in this process, under
# query traffic the whole time. Gates: deleted ids never returned after the
# refresh, appended rows findable, generation bump observed over /metrics,
# accepted == answered after drain, zero degraded queries (tombstones mask
# inside the scan — they must not look like shard skips), fsck clean, and
# the server gc'd the superseded generation (unlink-after-release).
# (docs/INDEX_FORMAT.md "Mutation", docs/SERVING.md)
python - <<'PY'
import json, os, shutil, signal, subprocess, sys, tempfile, time
import urllib.request
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import Compactor, IndexStore
from repro.launch.search_client import SearchClient

rng = np.random.default_rng(0)
xb = rng.normal(size=(600, 16)).astype(np.float32)
cfg = tiny(epochs=1)
params = training.init_qinco2(jax.random.key(0), xb[:256], cfg)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=8, m_tilde=2, n_pair_books=4)
d = tempfile.mkdtemp(prefix="ci_mutation_smoke_")
proc = None
try:
    IndexStore.save(d, idx, shard_size=256)
    pf, sj, log = d + "/ports.json", d + "/stats.jsonl", d + "/server.log"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_search",
         "--store", d, "--port", "0", "--port-file", pf,
         "--out-of-core", "--max-resident-shards", "2",
         "--refresh-ms", "100", "--metrics-port", "0",
         "--micro-batch", "8", "--max-wait-ms", "1", "--stats-json", sj],
        stdout=open(log, "w"), stderr=subprocess.STDOUT,
        env=dict(os.environ, PYTHONPATH="src"))
    t0 = time.time()
    while not os.path.exists(pf):
        assert proc.poll() is None, open(log).read()
        assert time.time() - t0 < 180, "server never bound"
        time.sleep(0.2)
    ports = json.load(open(pf))
    murl = f"http://127.0.0.1:{ports['metrics_port']}"
    client = SearchClient("127.0.0.1", ports["port"], timeout_s=30)

    def snap():
        return json.loads(
            urllib.request.urlopen(murl + "/metrics.json").read())

    def wait_for(pred, what, timeout=20):
        t0 = time.time()
        while True:
            s = snap()
            if pred(s):
                return s
            assert time.time() - t0 < timeout, f"timed out waiting: {what}"
            time.sleep(0.1)

    q = np.asarray(xb[7:8] + 0.01, np.float32)
    r = client.search(q, req_key="base")
    assert r.ok
    victim = int(next(i for i in r.ids[0] if i != 0))  # never delete row 0

    store = IndexStore(d)
    store.delete([victim])
    refreshes0 = obs.series_value(snap(), "index_refreshes_total")
    wait_for(lambda s: obs.series_value(s, "index_refreshes_total")
             > refreshes0, "tombstone refresh")
    for i in range(5):                    # the delete must stick, every time
        r = client.search(q, req_key=f"del{i}")
        assert r.ok and victim not in r.ids[0], (victim, r.ids)

    xa = (xb[50:70] + 0.001).astype(np.float32)
    store.append(xa)
    refreshes1 = obs.series_value(snap(), "index_refreshes_total")
    wait_for(lambda s: obs.series_value(s, "index_refreshes_total")
             > refreshes1, "delta refresh")
    r = client.search(np.asarray(xa[:1]), req_key="app")
    assert r.ok and (r.ids[0] >= 600).any(), r.ids  # appended row findable

    # churn: queries racing a second append + delete round
    store.append(xa)
    store.delete([int(r.ids[0].max())])
    for i in range(20):
        r = client.search(q, req_key=f"churn{i}")
        assert r.ok and victim not in r.ids[0]

    # quiesce mutation, then compact (no gc: the server gc's for itself
    # once its last old-generation pin releases) and watch the live view
    # adopt the new generation mid-traffic
    rep = Compactor(store).run()
    assert rep["compacted"] and rep["generation"] == 1, rep
    wait_for(lambda s: obs.series_value(s, "index_generation") == 1,
             "generation pickup", timeout=30)
    for i in range(5):
        assert client.search(q, req_key=f"post{i}").ok
    t0 = time.time()
    while store.orphan_paths():           # unlink-after-release, server-side
        assert time.time() - t0 < 20, \
            f"server never gc'd: {store.orphan_paths()}"
        client.search(q, req_key=f"gc{time.time()}")
        time.sleep(0.2)

    s = snap()
    assert obs.series_value(s, "search_degraded_queries_total") == 0
    assert obs.series_value(s, "index_refreshes_total") >= 3
    proc.send_signal(signal.SIGTERM)
    assert proc.wait(timeout=60) == 0, open(log).read()
    rec = json.loads(open(sj).read().strip())
    assert rec["drained_clean"] and rec["n_accepted"] == rec["n_answered"]

    from repro.index import fsck_store
    assert fsck_store(d, log=lambda *a, **k: None)["ok"]
    assert not IndexStore(d).mutated
    print("[ci] live-mutation smoke OK (delete masked under traffic, "
          "append served after refresh, compaction adopted mid-stream "
          f"with gc after release; {rec['n_accepted']} accepted == "
          f"{rec['n_answered']} answered; fsck clean)")
finally:
    if proc is not None and proc.poll() is None:
        proc.kill()
    shutil.rmtree(d, ignore_errors=True)
PY

# kernel-backend smoke: xla vs pallas per-op timings for every dispatch op
# (incl. the fused f_theta / adc_topk paths) -> BENCH_kernels.json, so each
# CI run leaves a machine-readable perf data point
python -m benchmarks.run --only backends
test -s BENCH_kernels.json \
    && echo "[ci] kernel backends smoke OK (BENCH_kernels.json written)"

# encode-throughput smoke: fused vs unfused beam steps across the (A, B)
# grid on both backends -> BENCH_encode.json (the encode perf trajectory)
python -m benchmarks.run --only encode
test -s BENCH_encode.json \
    && echo "[ci] encode throughput smoke OK (BENCH_encode.json written)"

# search-throughput smoke: resident vs out-of-core QPS/p50/p99 across shard
# counts, plus cold-scan rows (pool holds half the shards; prefetch on vs
# off) at the largest count -> BENCH_search.json (the search-side perf
# trajectory)
python -m benchmarks.run --only search
test -s BENCH_search.json \
    && echo "[ci] search throughput smoke OK (BENCH_search.json written)"

# bench-regression gate: fresh BENCH_*.json vs benchmarks/baselines/*.json
# (load-normalized, per-row tolerance default +-35%; BENCH_GATE=0 is the
# escape hatch). A failure re-measures once before failing for real: a
# transient CPU-contention window poisons one measurement run, a genuine
# regression reproduces in both.
if [ "${BENCH_GATE:-1}" = "1" ]; then
    if ! python scripts/check_bench.py; then
        echo "[ci] bench gate failed; re-measuring once to rule out a" \
             "transient load spike"
        python -m benchmarks.run --only backends > /dev/null
        python -m benchmarks.run --only encode > /dev/null
        python -m benchmarks.run --only search > /dev/null
        python scripts/check_bench.py
    fi
else
    echo "[ci] bench-regression gate SKIPPED (BENCH_GATE=0)"
fi

if [ "${QUICK:-0}" = "1" ]; then
    exec python -m pytest -q -m "not slow" "$@"
fi
exec python -m pytest -q "$@"
