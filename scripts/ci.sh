#!/usr/bin/env bash
# Tier-1 CI entry point.
#   scripts/ci.sh           full suite (what the driver runs)
#   QUICK=1 scripts/ci.sh   skip the slow (dry-run subprocess) suites
set -euo pipefail
cd "$(dirname "$0")/.."

# dev-only deps (hypothesis): best-effort — the suite degrades gracefully
# (property tests skip) when the environment is offline.
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "[ci] dev deps unavailable (offline?); continuing without"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "${QUICK:-0}" = "1" ]; then
    exec python -m pytest -q -m "not slow" "$@"
fi
exec python -m pytest -q "$@"
