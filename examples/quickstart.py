"""Quickstart: train a QINCo2 codec on synthetic vectors, encode a small
database, and run the full search cascade — the whole paper in ~2 minutes
on one CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import rq, search, training
from repro.data.synthetic import make_splits

# ---- data (synthetic BigANN-like; DESIGN.md §7) -----------------------------
xt, xb, xq, gt = make_splits("bigann", n_train=6000, n_db=4000, n_query=64,
                             seed=0)
dim = 24
xt, xb, xq = xt[:, :dim], xb[:, :dim], xq[:, :dim]
xt, (mu, sd) = training.normalize_dataset(xt)
xb = ((xb - mu) / sd).astype(np.float32)
xq = ((xq - mu) / sd).astype(np.float32)
gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)

# ---- train QINCo2 (pre-selection + beam search, App. A.2 recipe) ------------
cfg = tiny(d=dim, M=4, K=16, de=32, dh=48, L=2, A_train=4, B_train=8,
           A_eval=8, B_eval=16, epochs=3, batch_size=512)
params, hist = training.train(jax.random.key(0), xt, cfg, x_val=xb[:512])

# ---- compare with RQ on held-out MSE ----------------------------------------
cbs = rq.rq_train(jax.random.key(1), jnp.asarray(xt), cfg.M, cfg.K)
_, xhat_rq = rq.rq_encode(cbs, jnp.asarray(xb), B=1)
mse_rq = float(jnp.mean(jnp.sum((jnp.asarray(xb) - xhat_rq) ** 2, -1)))
mse_q2 = float(enc.reconstruction_mse(params, jnp.asarray(xb), cfg))
print(f"\nheld-out MSE   RQ: {mse_rq:.4f}   QINCo2: {mse_q2:.4f} "
      f"({(1 - mse_q2 / mse_rq):.1%} better)")

# ---- build the search index (IVF -> AQ -> pairwise -> neural rerank) --------
idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                         k_ivf=32, m_tilde=2, n_pair_books=8)
ids, dists = search.search(idx, jnp.asarray(xq), n_probe=8, n_short_aq=48,
                           n_short_pw=12, topk=1, cfg=cfg)
r1 = float((np.asarray(ids[:, 0]) == gt).mean())
print(f"cascade R@1: {r1:.3f}  (IVF probe -> ADC -> pairwise -> QINCo2)")
assert mse_q2 < mse_rq
print("quickstart OK")
