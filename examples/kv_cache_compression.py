"""BEYOND-PAPER example: the paper's RQ machinery compressing an LM KV
cache. Fits per-(head) residual codebooks on prefill K/V, decodes with the
quantized cache, and compares logits + memory against the bf16 cache.

    PYTHONPATH=src python examples/kv_cache_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import kv_quant
from repro.launch.serve import generate
from repro.models import lm
from repro.models.common import ShardCtx, init_params

arch = get_arch("qwen2.5-32b").reduced()
params = init_params(lm.param_specs(arch), jax.random.key(0))
prompts = jax.random.randint(jax.random.key(1), (2, 24), 0, arch.vocab_size)

full = generate(arch, params, prompts, gen_len=12, kv_quant_on=False)
quant = generate(arch, params, prompts, gen_len=12, kv_quant_on=True)
agree = float((np.asarray(full) == np.asarray(quant)).mean())
print(f"token agreement quantized vs full cache: {agree:.2%}")

# memory math for the real configs (the dry-run §Perf numbers)
for name in ("deepseek-coder-33b", "mistral-large-123b"):
    a = get_arch(name)
    hd = a.attn.head_dim
    ratio = kv_quant.compression_ratio(hd, a.kv_quant.m_bytes)
    cache_gb = (a.n_layers * 128 * 32768 * 2 * a.attn.num_kv_heads * hd * 2
                / 16 / 1e9)
    print(f"{name}: decode_32k cache {cache_gb:.1f} GB/device bf16 -> "
          f"{cache_gb / ratio:.2f} GB at m={a.kv_quant.m_bytes} "
          f"({ratio:.0f}x)")

# quantization error falls with more bytes (rate-distortion, paper Fig. S1)
rng = np.random.default_rng(0)
kv = jnp.asarray(rng.normal(size=(2048, 2, 32)).astype(np.float32))
for m in (1, 2, 4, 8):
    cb = kv_quant.fit_kv_codebooks(jax.random.key(2), kv, m, 32)
    mse = float(kv_quant.quantization_mse(kv[None], cb))
    print(f"  m={m} bytes/vector: K/V quantization MSE {mse:.4f}")
print("kv_cache_compression OK")
