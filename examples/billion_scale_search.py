"""Billion-scale index lifecycle at demonstration scale, end-to-end
through the persistent `repro.index` subsystem:

    sharded build (2 owners, one killed mid-range) -> resume
      -> load (resident) -> out-of-core serving off the mmap'd shards

Codes are packed uint8 on disk AND in HBM (4x smaller than int32); the
per-shard ADC scan consumes the packed bytes directly through the Pallas
one-hot kernel path (`kernels/ops`). The build is data-axis sharded:
each "host" owns a contiguous shard range of ONE store and writes
disjoint files (byte-identical to a single-process build), and a killed
owner resumes from its own cursor. Serving then runs out-of-core:
`search_sharded` streams the fused per-shard `ops.adc_topk` shortlist
over an LRU of staged shards and gathers only shortlist rows for the
re-rank — bit-identical to resident `search()`, with device residency
bounded by the LRU budget instead of the database size.

    PYTHONPATH=src python examples/billion_scale_search.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.data.synthetic import make_splits
from repro.index import IndexStore, ShardedIndexView, StreamingIndexBuilder
from repro.launch.serve_search import SearchServer, synthetic_stream

# data ------------------------------------------------------------------------
xt, xb, _, _ = make_splits("bigann", n_train=4000, n_db=16000, n_query=32,
                           seed=1)
dim = 24
xt, xb = xt[:, :dim], xb[:, :dim]
xt, (mu, sd) = training.normalize_dataset(xt)
xb = ((xb - mu) / sd).astype(np.float32)
rng = np.random.default_rng(7)
pick = rng.integers(0, len(xb), size=32)
xq = (xb[pick] + 0.05 * rng.normal(size=(32, dim))).astype(np.float32)
gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)

cfg = tiny(d=dim, M=4, K=16, de=32, dh=48, L=2, epochs=2, batch_size=512)
params, _ = training.train(jax.random.key(0), xt, cfg, verbose=False)

# sharded build: 2 owners, owner 1 killed mid-range, resumed -----------------
store_dir = tempfile.mkdtemp(prefix="qinco2_index_")


def make_builder():
    b = StreamingIndexBuilder(store_dir, shard_size=4000, encode_chunk=2048,
                              verbose=True)
    b.prepare(jax.random.key(1), xb[:6000], params, cfg, n_total=len(xb),
              k_ivf=64, m_tilde=2, n_pair_books=8)
    return b


t0 = time.time()
done = make_builder().build(xb, host_id=0, n_hosts=2)  # owner 0: shards [0,2)
assert not done, "owner 0 alone must not complete the store"
done = make_builder().build(xb, host_id=1, n_hosts=2,
                            max_shards=1)           # owner 1 "power loss"
assert not done, "expected the interrupted owner to stop before completion"
print(f"-- owner 1 interrupted mid-range "
      f"({IndexStore(store_dir).manifest['n_shards']} shards total); "
      f"restarting from its cursor --")
done = make_builder().build(xb, host_id=1, n_hosts=2)  # resumes, finalizes
assert done
print(f"sharded build (2 owners, incl. interruption): "
      f"{time.time() - t0:.2f}s")

# load (mmap) -----------------------------------------------------------------
t0 = time.time()
store = IndexStore(store_dir)
idx = store.load()
print(f"loaded {store.manifest['n_total']} vectors "
      f"({store.bytes_per_vector():.1f} B/vec on disk, codes "
      f"{idx.codes.dtype}) in {time.time() - t0:.2f}s")
assert idx.codes.dtype == jnp.uint8                 # packed end-to-end

# recall check against brute force -------------------------------------------
ids, _ = search.search(idx, jnp.asarray(xq), n_probe=8, n_short_aq=64,
                       n_short_pw=16, topk=1, cfg=cfg)
r1 = float((np.asarray(ids[:, 0]) == gt).mean())
print(f"store-loaded cascade R@1: {r1:.3f}")
assert r1 > 0.3

# out-of-core: shards stay mmap'd, bit-identical to resident search ----------
view = ShardedIndexView(store_dir, max_resident_shards=1)
ids_oc, dists_oc = search.search_sharded(view, jnp.asarray(xq), n_probe=8,
                                         n_short_aq=64, n_short_pw=16,
                                         topk=1, cfg=cfg)
ref_ids, ref_d = search.search(idx, jnp.asarray(xq), n_probe=8,
                               n_short_aq=64, n_short_pw=16, topk=1, cfg=cfg)
np.testing.assert_array_equal(np.asarray(ids_oc), np.asarray(ref_ids))
np.testing.assert_array_equal(np.asarray(dists_oc), np.asarray(ref_d))
print(f"out-of-core == resident (bit-identical); peak staged "
      f"{view.peak_resident_bytes / 1e3:.0f} kB of "
      f"{view.budget_bytes / 1e3:.0f} kB budget "
      f"({len(view.shard_ids)} shards on disk)")

# batched query serving, straight off the mmap'd store ------------------------
server = SearchServer(view, micro_batch=16, n_probe=8, n_short_aq=64,
                      n_short_pw=16, topk=10)
q_stream, arrivals = synthetic_stream(view, n_queries=128, rate_qps=1000.0)
stats = server.serve_stream(q_stream, arrivals, max_wait_s=2e-3)
print(f"out-of-core serving: {stats.row()}")

import shutil
shutil.rmtree(store_dir, ignore_errors=True)
print("billion_scale_search OK")
