"""Billion-scale search layout at demonstration scale: the database is
sharded across devices (here: across chunks on one device), each shard runs
ADC with the Pallas one-hot kernel, shortlists are merged, and the QINCo2
decoder re-ranks — exactly the Fig. 3 pipeline the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/billion_scale_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import tiny
from repro.core import aq, search, training
from repro.data.synthetic import make_splits
from repro.kernels import ops

# data
xt, xb, _, _ = make_splits("bigann", n_train=4000, n_db=16000, n_query=32,
                           seed=1)
dim = 24
xt, xb = xt[:, :dim], xb[:, :dim]
xt, (mu, sd) = training.normalize_dataset(xt)
xb = ((xb - mu) / sd).astype(np.float32)
rng = np.random.default_rng(7)
pick = rng.integers(0, len(xb), size=32)
xq = (xb[pick] + 0.05 * rng.normal(size=(32, dim))).astype(np.float32)
gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)

cfg = tiny(d=dim, M=4, K=16, de=32, dh=48, L=2, epochs=2, batch_size=512)
params, _ = training.train(jax.random.key(0), xt, cfg, verbose=False)
idx = search.build_index(jax.random.key(1), jnp.asarray(xb), params, cfg,
                         k_ivf=64, m_tilde=2, n_pair_books=8)

# ---- sharded ADC scan with the Pallas kernel (interpret on CPU) -------------
n_shards = 4
shard_len = len(xb) // n_shards
q = jnp.asarray(xq)
lut = aq.adc_lut(idx.aq_books, q)                  # (Q, M, K)
cent_ip = q @ idx.ivf.centroids.T                  # (Q, K_ivf)
k = 32
t0 = time.time()
parts = []
for s in range(n_shards):                          # one device per shard IRL
    sl = slice(s * shard_len, (s + 1) * shard_len)
    codes_s = idx.codes[sl]
    norms_s = idx.aq_norms[sl]
    # full ADC score: residual-code LUT sum + the IVF-centroid term
    ip = ops.adc_scores(codes_s, lut) + cent_ip[:, idx.ivf.assignments[sl]]
    scores = 2.0 * ip - norms_s[None]
    sc, ii = jax.lax.top_k(scores, k)              # local top-k
    parts.append((sc, ii + s * shard_len))
sc = jnp.concatenate([p[0] for p in parts], axis=1)   # merge (all-gather IRL)
ii = jnp.concatenate([p[1] for p in parts], axis=1)
sc2, order = jax.lax.top_k(sc, k)
merged = jnp.take_along_axis(ii, order, axis=1)
print(f"sharded ADC + merge: {time.time()-t0:.2f}s over {n_shards} shards")

# ---- neural re-rank of the merged shortlist --------------------------------
from repro.core import qinco
flat = merged.reshape(-1)
recon = (qinco.decode(params, idx.codes[flat], cfg)
         + idx.ivf.centroids[idx.ivf.assignments[flat]])
recon = recon.reshape(len(xq), k, dim)
d2 = jnp.sum((q[:, None] - recon) ** 2, -1)
best = np.asarray(jnp.take_along_axis(merged, jnp.argmin(d2, 1)[:, None], 1))
r1 = float((best[:, 0] == gt).mean())
print(f"distributed-layout R@1: {r1:.3f}")
assert r1 > 0.3
print("billion_scale_search OK")
