"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate (checkpointing, preemption guard, straggler
monitor, resumable data pipeline).

Any assigned arch works via --arch; the default qwen2.5 family config is
cut to ~100M params. With --steps 300 this is the "train a ~100M model for
a few hundred steps" deliverable (takes a while on 1 CPU core; use
--steps 60 for a quick look).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.configs.base import AttnConfig
from repro.launch.train import train_loop


def lm_100m(base: str = "qwen2.5-32b"):
    a = get_arch(base)
    return dataclasses.replace(
        a, name="lm-100m", n_layers=6, d_model=512, d_ff=1536,
        vocab_size=8192,
        attn=dataclasses.replace(a.attn, num_heads=8, num_kv_heads=4,
                                 head_dim=64),
        parallel=dataclasses.replace(a.parallel, fsdp=False,
                                     param_dtype="float32",
                                     compute_dtype="float32",
                                     remat_policy="nothing",
                                     attn_chunk=128),
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id (reduced)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    arch = get_arch(args.arch).reduced() if args.arch else lm_100m()
    from repro.models import lm as lm_mod
    print(f"training {arch.name}: {lm_mod.count_params(arch)/1e6:.1f}M params")
    params, _, losses = train_loop(
        arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=25, lr=1e-3)
    assert losses[-1] < losses[0], "loss should decrease"
    print("train_lm OK")
