"""Per-arch smoke: REDUCED same-family config, one forward/train step on
CPU, asserting output shapes + finiteness (spec requirement), plus one
decode step against the cache built by cache_specs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import lm
from repro.models.common import ShardCtx, abstract_params, init_params
from repro.launch.steps import make_train_step
from repro.optim import adamw

CTX = ShardCtx(active=False)
ARCHS = list_archs()


def _batch(arch, B=2, S=32, seed=0):
    key = jax.random.key(seed)
    b = {"tokens": jax.random.randint(key, (B, S), 0, arch.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, arch.vocab_size)}
    if arch.family == "encdec":
        b["frames"] = jax.random.normal(
            jax.random.key(seed + 1), (B, arch.encoder_context, arch.d_model))
    return b


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_train_step(name):
    arch = get_arch(name).reduced()
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    batch = _batch(arch)
    loss = jax.jit(lambda p, b: lm.loss_fn(p, b, arch, CTX))(params, batch)
    assert np.isfinite(float(loss)), name
    assert 0 < float(loss) < 3 * np.log(arch.vocab_size), name

    opt_cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    step = jax.jit(make_train_step(arch, CTX, opt_cfg))
    opt_state = adamw.init(params, opt_cfg)
    p2, s2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert float(metrics["grad_norm"]) > 0, name
    # params changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, name


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name):
    arch = get_arch(name).reduced()
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    B, T = 2, 16
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         abstract_params(lm.cache_specs(arch, B, T)))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, 3, arch, CTX))(
        params, cache, tok)
    assert logits.shape == (B, 1, arch.vocab_size), name
    assert np.isfinite(np.asarray(logits)).all(), name
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache), name


@pytest.mark.parametrize("name", ["qwen2.5-32b", "dbrx-132b", "zamba2-1.2b"])
def test_kv_quant_decode_step(name):
    arch = get_arch(name).reduced()
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    B, T = 2, 16
    cache = init_params(lm.cache_specs(arch, B, T, kv_quant=True),
                        jax.random.key(1))
    cache = jax.tree.map(
        lambda a: jnp.zeros_like(a) if a.dtype == jnp.uint8 else a, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, _ = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, 3, arch, CTX,
                                       kv_quant=True))(params, cache, tok)
    assert np.isfinite(np.asarray(logits)).all(), name


def test_loss_decreases_when_training():
    from repro.launch.train import train_loop
    arch = get_arch("deepseek-coder-33b").reduced()
    _, _, losses = train_loop(arch, steps=20, batch=8, seq=64,
                              verbose=False, lr=5e-3)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), (
        losses[:5], losses[-5:])


def test_moe_2d_sharding_is_semantics_preserving():
    """moe_2d only changes sharding annotations: on one device the loss is
    bit-identical to the baseline dispatch."""
    import dataclasses
    arch = get_arch("dbrx-132b").reduced()
    arch2d = dataclasses.replace(
        arch, parallel=dataclasses.replace(arch.parallel, moe_2d=True))
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    batch = _batch(arch)
    l1 = jax.jit(lambda p, b: lm.loss_fn(p, b, arch, CTX))(params, batch)
    l2 = jax.jit(lambda p, b: lm.loss_fn(p, b, arch2d, CTX))(params, batch)
    assert float(l1) == float(l2)


@pytest.mark.parametrize("name", ["deepseek-coder-33b", "kimi-k2-1t-a32b"])
def test_parallel_block_trains(name):
    """The fused PaLM-style block (a §Perf architecture variant) is a
    different model — assert it trains sanely rather than matches."""
    import dataclasses
    arch = get_arch(name).reduced()
    arch = dataclasses.replace(
        arch, parallel=dataclasses.replace(arch.parallel,
                                           parallel_block=True))
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    batch = _batch(arch)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, grad_clip=1.0)
    step = jax.jit(make_train_step(arch, CTX, opt_cfg))
    opt_state = adamw.init(params, opt_cfg)
    losses = []
    for _ in range(4):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), name
    assert losses[-1] < losses[0], name


def test_dp_only_is_semantics_preserving():
    """dp_only changes the mesh mapping only; on one device loss matches."""
    import dataclasses
    arch = get_arch("mamba2-1.3b").reduced()
    archdp = dataclasses.replace(
        arch, parallel=dataclasses.replace(arch.parallel, dp_only=True,
                                           fsdp=True))
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    batch = _batch(arch)
    l1 = jax.jit(lambda p, b: lm.loss_fn(p, b, arch, CTX))(params, batch)
    l2 = jax.jit(lambda p, b: lm.loss_fn(p, b, archdp, CTX))(params, batch)
    assert float(l1) == float(l2)
