"""The telemetry contract (src/repro/obs, docs/OBSERVABILITY.md):

- the registry is get-or-create, type-checked, label-aware, and its
  histograms answer interpolated + windowed quantiles from fixed
  buckets;
- disabled metrics are a TRUE no-op: values freeze, mutators cost one
  flag check (overhead bound asserted loosely), and — the part that
  matters — search()/search_sharded() results are bitwise identical
  with metrics on, off, and with tracing on, on both dispatch backends;
- the exporters round-trip: Prometheus text carries every series,
  the JSON snapshot supports delta/series_value arithmetic, and the
  HTTP endpoint serves both;
- `StagingPool.stats()` is now a *view* over the registry: the legacy
  dict equals the per-pool labeled series, key for key.
"""
import time
import urllib.request

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore, ShardedIndexView
from repro.obs.metrics import MetricsRegistry, exp_buckets

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    rng = np.random.default_rng(11)
    xb = clustered(rng, 900, 16, k=16)
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params,
                             cfg, k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("obs_store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=256)
    q = jnp.asarray(xb[:9] + 0.02)
    return cfg, idx, store_dir, q


# ---------------------------------------------------------------------------
# registry semantics


def test_registry_types_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("x_total") is c          # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        reg.counter("not_a_counter")            # must end in _total
    with pytest.raises(ValueError):
        c.inc(-1)                               # counters only go up
    g = reg.gauge("y")
    g.set(5)
    g.dec(2)
    assert g.value == 3.0
    a = c.labels(pool="1")
    b = c.labels(pool="2")
    assert a is c.labels(pool="1") and a is not b
    a.inc(7)
    assert a.value == 7 and b.value == 0 and c.value == 3.5


def test_histogram_quantiles_windowed():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=exp_buckets(1e-3, 2.0, 16))
    for v in (0.004, 0.005, 0.006, 0.05):
        h.observe(v)
    p50, p99 = h.quantile(0.5), h.quantile(0.99)
    assert 0 < p50 <= p99
    assert 0.002 <= p50 <= 0.016                # lands in the 4-6ms region
    win = h.collect()
    for v in (1.0, 1.1, 1.2):
        h.observe(v)
    # windowed quantile sees only the second batch (~1s), not the ms ones
    assert h.quantile(0.5, since=win) > 0.5
    assert h.quantile(0.5) < 0.5                # lifetime median still low
    empty = h.collect()
    assert h.quantile(0.9, since=empty) == 0.0  # empty window


def test_disable_freezes_and_is_cheap():
    reg = MetricsRegistry()
    c = reg.counter("z_total")
    h = reg.histogram("z_seconds")
    c.inc(5)
    h.observe(0.1)
    reg.disable()
    c.inc(100)
    h.observe(9.9)
    assert c.value == 5 and h.collect()["count"] == 1   # frozen
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    dt_off = time.perf_counter() - t0
    reg.enable()
    # loose bound: a disabled inc is one attribute check — budget 2us/op
    # absorbs CI-host noise while still catching an accidental lock/alloc
    assert dt_off / n < 2e-6, f"disabled inc costs {dt_off / n * 1e9:.0f}ns"
    assert c.value == 5
    c.inc()
    assert c.value == 6


# ---------------------------------------------------------------------------
# exporters


def test_prometheus_and_snapshot_round_trip():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.labels(route="a").inc(3)
    c.labels(route="b").inc(4)
    reg.gauge("depth").set(2)
    h = reg.histogram("dur_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = obs.render_prometheus(reg)
    assert '# TYPE req_total counter' in text
    assert 'req_total{route="a"} 3' in text
    assert 'req_total{route="b"} 4' in text
    assert 'dur_seconds_bucket{le="0.1"} 1' in text
    assert 'dur_seconds_bucket{le="1"} 2' in text         # cumulative
    assert 'dur_seconds_bucket{le="+Inf"} 3' in text
    assert 'dur_seconds_count 3' in text
    snap = obs.snapshot(reg)
    assert obs.series_value(snap, "req_total") == 7        # summed
    assert obs.series_value(snap, "req_total", route="a") == 3
    assert obs.series_value(snap, "depth") == 2
    c.labels(route="a").inc(10)
    delta = obs.snapshot_delta(snap, obs.snapshot(reg))
    assert obs.series_value(delta, "req_total", route="a") == 10
    assert obs.series_value(delta, "req_total", route="b") == 0


def test_http_endpoint_scrape():
    reg = MetricsRegistry()
    reg.counter("hits_total").inc(2)
    srv = obs.start_metrics_server(0, registry=reg)
    try:
        text = urllib.request.urlopen(srv.url + "/metrics").read().decode()
        assert "hits_total 2" in text
        import json
        snap = json.loads(
            urllib.request.urlopen(srv.url + "/metrics.json").read())
        assert obs.series_value(snap, "hits_total") == 2
        traces = json.loads(
            urllib.request.urlopen(srv.url + "/traces.json").read())
        assert isinstance(traces, list)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# the guarantee that matters: telemetry never changes results


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_metrics_on_off_bitwise_parity(world, backend):
    cfg, idx, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    i_on, s_on = search.search(idx, q, cfg=cfg, backend=backend,
                               **SEARCH_KW)
    si_on, ss_on = search.search_sharded(view, q, cfg=cfg, backend=backend,
                                         **SEARCH_KW)
    obs.disable()
    try:
        i_off, s_off = search.search(idx, q, cfg=cfg, backend=backend,
                                     **SEARCH_KW)
        si_off, ss_off = search.search_sharded(view, q, cfg=cfg,
                                               backend=backend, **SEARCH_KW)
    finally:
        obs.enable()
    with obs.tracing_scope():                   # fenced spans active
        i_tr, s_tr = search.search_sharded(view, q, cfg=cfg,
                                           backend=backend, **SEARCH_KW)
    assert np.array_equal(np.asarray(i_on), np.asarray(i_off))
    assert np.array_equal(np.asarray(s_on), np.asarray(s_off))
    assert np.array_equal(np.asarray(si_on), np.asarray(si_off))
    assert np.array_equal(np.asarray(ss_on), np.asarray(ss_off))
    assert np.array_equal(np.asarray(si_on), np.asarray(i_tr))
    assert np.array_equal(np.asarray(ss_on), np.asarray(s_tr))


def test_tracing_records_stages(world):
    cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    with obs.tracing_scope():
        search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    traces = obs.recent_traces()
    assert traces, "query_trace should land in the ring"
    t = traces[-1]
    assert t["name"] == "search_sharded"
    stages = {s["stage"] for s in t["spans"]}
    assert {"search/probe", "search/fold", "search/rerank"} <= stages
    hist = obs.get_metric("search_stage_seconds")
    assert hist is not None
    assert hist.labels(stage="fold").collect()["count"] > 0


# ---------------------------------------------------------------------------
# staging migration: stats() is a registry view


def test_staging_stats_equal_registry(world):
    cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    st = view.pool.stats()
    assert st["staged"] > 0
    pool_label = str(view.pool.pool_id)
    for key, name in [("staged", "staging_staged_total"),
                      ("device_hits", "staging_device_hits_total"),
                      ("host_hits", "staging_host_hits_total"),
                      ("prefetch_issued", "staging_prefetch_issued_total"),
                      ("prefetch_hits", "staging_prefetch_hits_total"),
                      ("evictions", "staging_evictions_total"),
                      ("stall_s", "staging_stall_seconds_total")]:
        m = obs.get_metric(name)
        assert m is not None, name
        assert st[key] == m.labels(pool=pool_label).value, key
    # cross-series consistency the CI smoke also asserts
    snap = obs.snapshot()
    assert (obs.series_value(snap, "staging_prefetch_hits_total")
            <= obs.series_value(snap, "staging_staged_total"))
