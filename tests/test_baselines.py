"""MCQ baselines: RQ / PQ / OPQ / k-means invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import rq
from repro.core.kmeans import kmeans, kmeans_cost, pairwise_sqdist

from conftest import clustered


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(2)
    return jnp.asarray(clustered(rng, 2048, 32))


def test_kmeans_reduces_cost(data):
    key = jax.random.key(0)
    c1, _ = kmeans(key, data, 16, iters=1)
    c10, _ = kmeans(key, data, 16, iters=10)
    assert float(kmeans_cost(data, c10)) <= float(kmeans_cost(data, c1)) + 1e-6


def test_rq_beam_improves(data):
    cbs = rq.rq_train(jax.random.key(0), data, 4, 16)
    _, x1 = rq.rq_encode(cbs, data, B=1)
    _, x8 = rq.rq_encode(cbs, data, B=8)
    m1 = float(jnp.mean(jnp.sum((data - x1) ** 2, -1)))
    m8 = float(jnp.mean(jnp.sum((data - x8) ** 2, -1)))
    assert m8 <= m1 + 1e-6


def test_rq_decode_matches_encode(data):
    cbs = rq.rq_train(jax.random.key(0), data, 4, 16)
    codes, xhat = rq.rq_encode(cbs, data, B=2)
    np.testing.assert_allclose(np.asarray(rq.rq_decode(cbs, codes)),
                               np.asarray(xhat), rtol=1e-5, atol=1e-5)


def test_rq_more_steps_better(data):
    m_prev = None
    for M in (1, 2, 4):
        cbs = rq.rq_train(jax.random.key(0), data, M, 16)
        _, xh = rq.rq_encode(cbs, data, B=1)
        m = float(jnp.mean(jnp.sum((data - xh) ** 2, -1)))
        if m_prev is not None:
            assert m <= m_prev + 1e-6
        m_prev = m


def test_pq_roundtrip(data):
    cbs = rq.pq_train(jax.random.key(0), data, 4, 16)
    codes = rq.pq_encode(cbs, data)
    xhat = rq.pq_decode(cbs, codes)
    assert xhat.shape == data.shape
    mse = float(jnp.mean(jnp.sum((data - xhat) ** 2, -1)))
    base = float(jnp.mean(jnp.sum(data ** 2, -1)))
    assert mse < base        # better than the zero coder


def test_opq_no_worse_than_pq(data):
    pq_cbs = rq.pq_train(jax.random.key(0), data, 4, 16)
    pq_mse = float(jnp.mean(jnp.sum(
        (data - rq.pq_decode(pq_cbs, rq.pq_encode(pq_cbs, data))) ** 2, -1)))
    opq = rq.opq_train(jax.random.key(0), data, 4, 16, outer=3)
    opq_mse = float(jnp.mean(jnp.sum(
        (data - rq.opq_decode(opq, rq.opq_encode(opq, data))) ** 2, -1)))
    assert opq_mse <= pq_mse * 1.05      # small slack: alternation is local


def test_rq_beats_pq_on_correlated_data(data):
    """RQ exploits cross-subspace structure PQ cannot (paper §1)."""
    rq_cbs = rq.rq_train(jax.random.key(0), data, 4, 16)
    _, xh = rq.rq_encode(rq_cbs, data, B=4)
    rq_mse = float(jnp.mean(jnp.sum((data - xh) ** 2, -1)))
    pq_cbs = rq.pq_train(jax.random.key(0), data, 4, 16)
    pq_mse = float(jnp.mean(jnp.sum(
        (data - rq.pq_decode(pq_cbs, rq.pq_encode(pq_cbs, data))) ** 2, -1)))
    assert rq_mse < pq_mse


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_pairwise_sqdist_nonnegative(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(20, 5)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(7, 5)).astype(np.float32))
    d2 = pairwise_sqdist(x, c)
    assert float(jnp.min(d2)) > -1e-4
    # diagonal: distance to self is 0
    dd = pairwise_sqdist(x[:5], x[:5])
    assert float(jnp.max(jnp.abs(jnp.diag(dd)))) < 1e-4
