# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real single CPU device. Only launch/dryrun.py (run
# as a subprocess) requests placeholder devices.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def clustered(rng, n, d, k=32, scale=2.0):
    centers = rng.normal(size=(k, d)).astype(np.float32) * scale
    x = centers[rng.integers(0, k, n)] + rng.normal(size=(n, d))
    x = x - x.mean(0, keepdims=True)
    return (x / x.std()).astype(np.float32)
