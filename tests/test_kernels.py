"""Per-kernel allclose vs the ref.py oracles, swept over shapes/dtypes.

All kernels run in interpret mode on CPU (the kernel body itself executes,
so BlockSpec indexing, scratch accumulation and masking are covered)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("N,d,K,A", [
    (64, 16, 16, 4), (300, 32, 64, 8), (128, 96, 256, 32), (17, 8, 16, 16),
])
def test_l2_topk_matches_ref(N, d, K, A):
    rng = np.random.default_rng(N + d)
    r = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    idx, d2 = ops.l2_topk(r, cb, A, backend="pallas", tile_n=64)
    ridx, rd2 = ref.l2_topk_ref(r, cb, A)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2),
                               rtol=1e-4, atol=1e-4)
    # indices may differ on exact ties; distances must agree
    same = (np.asarray(idx) == np.asarray(ridx)).mean()
    assert same > 0.98


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_topk_dtypes(dtype):
    rng = np.random.default_rng(7)
    r = jnp.asarray(rng.normal(size=(50, 24)), dtype)
    cb = jnp.asarray(rng.normal(size=(32, 24)), dtype)
    idx, d2 = ops.l2_topk(r, cb, 4, backend="pallas")
    ridx, rd2 = ref.l2_topk_ref(r.astype(jnp.float32),
                                cb.astype(jnp.float32), 4)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("Q,N,M,K", [
    (8, 100, 4, 16), (33, 500, 8, 16), (5, 64, 16, 64), (64, 256, 8, 256),
])
def test_adc_matches_ref(Q, N, M, K):
    rng = np.random.default_rng(Q * N)
    codes = jnp.asarray(rng.integers(0, K, size=(N, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(Q, M, K)).astype(np.float32))
    s = ops.adc_scores(codes, lut, backend="pallas", tile_q=16, tile_n=64)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref.adc_ref(codes, lut)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("N,de,dh,L", [
    (64, 16, 32, 1), (100, 24, 48, 3), (33, 128, 256, 2), (256, 64, 64, 8),
])
def test_resmlp_matches_ref(N, de, dh, L):
    rng = np.random.default_rng(N + L)
    v = jnp.asarray(rng.normal(size=(N, de)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(L, de, dh)).astype(np.float32) * 0.2)
    w2 = jnp.asarray(rng.normal(size=(L, dh, de)).astype(np.float32) * 0.2)
    out = ops.resmlp_chain(v, w1, w2, backend="pallas", tile_n=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.resmlp_ref(v, w1, w2)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,T,KVH,G,D,Mq,Kq,valid", [
    (1, 64, 1, 2, 8, 2, 8, 64), (2, 96, 2, 4, 16, 3, 8, 57),
    (1, 128, 2, 1, 32, 4, 16, 100),
])
def test_kv_dequant_attn_matches_ref(B, T, KVH, G, D, Mq, Kq, valid):
    rng = np.random.default_rng(T + valid)
    q = jnp.asarray(rng.normal(size=(B, KVH, G, D)).astype(np.float32))
    ck = jnp.asarray(rng.integers(0, Kq, size=(B, T, KVH, Mq)).astype(np.int32))
    cv = jnp.asarray(rng.integers(0, Kq, size=(B, T, KVH, Mq)).astype(np.int32))
    cbk = jnp.asarray(rng.normal(size=(KVH, Mq, Kq, D)).astype(np.float32))
    cbv = jnp.asarray(rng.normal(size=(KVH, Mq, Kq, D)).astype(np.float32))
    out = ops.kv_dequant_attn(q, ck, cv, cbk, cbv, valid, backend="pallas", tile_t=32)
    rout = ref.kv_dequant_attn_ref(q, ck, cv, cbk, cbv, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                               rtol=1e-4, atol=1e-4)


def test_kv_dequant_attn_matches_model_dequant_path():
    """Kernel agrees with the model's jnp dequant+attention decode path."""
    from repro.models import common as cm
    from repro.models.dense import _dequant_chunk
    rng = np.random.default_rng(0)
    B, T, KVH, G, D, Mq, Kq = 2, 64, 2, 2, 16, 2, 8
    q = jnp.asarray(rng.normal(size=(B, KVH, G, D)).astype(np.float32))
    ck = jnp.asarray(rng.integers(0, Kq, size=(B, T, KVH, Mq)).astype(np.int32))
    cv = jnp.asarray(rng.integers(0, Kq, size=(B, T, KVH, Mq)).astype(np.int32))
    cbk = jnp.asarray(rng.normal(size=(KVH, Mq, Kq, D)).astype(np.float32))
    cbv = jnp.asarray(rng.normal(size=(KVH, Mq, Kq, D)).astype(np.float32))
    valid = 50
    out = ops.kv_dequant_attn(q, ck, cv, cbk, cbv, valid, backend="pallas", tile_t=32)

    chunk = 32
    qd = q * (D ** -0.5)  # decode_attention scales internally; use raw q
    def chunks(i):
        sl = lambda c, cb: _dequant_chunk(
            jax.lax.dynamic_slice_in_dim(c, i * chunk, chunk, 1), cb)
        return sl(ck, cbk), sl(cv, cbv)
    mout = cm.decode_attention(q, chunks, T // chunk, chunk, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mout),
                               rtol=1e-4, atol=1e-4)
