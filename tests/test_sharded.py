"""The out-of-core sharded search + sharded build contract:

- `search_sharded` over a `ShardedIndexView` returns bit-identical
  indices AND scores to resident `search()` on the same store, on both
  dispatch backends, including the degenerate small-probe case where
  bucket-table padding enters the shortlist;
- peak device residency of the staged codes is bounded by the shard-LRU
  budget (database size is independent of device memory);
- `allow_partial=True` searches exactly the completed shards, matching
  resident search over the partially-loaded prefix;
- a data-axis sharded multi-owner build (`host_id`/`n_hosts`) writes
  byte-identical shard files to a single-owner build, including after a
  kill/resume of one owner (cursor-per-owner, stale cursors recovered);
- out-of-core serving (`SearchServer` over a view) matches resident.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import (IndexStore, ShardedIndexView,
                         StreamingIndexBuilder, owner_range)
from repro.parallel.collectives import merge_topk_ranked

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)
SHARD_FILES = ("codes.u8", "assign.i32", "aq_norms.f32", "pw_norms.f32")


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Clustered database -> resident index -> saved store (4 shards)."""
    rng = np.random.default_rng(21)
    xb = clustered(rng, 1100, 16, k=16)          # non-tile-multiple N
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    q = jnp.asarray(xb[:13] + 0.02)
    return xb, cfg, params, store_dir, q


@pytest.fixture(scope="module")
def resident(world):
    _, _, _, store_dir, _ = world
    return IndexStore(store_dir).load()


# ---------------------------------------------------------------------------
# bit-identity of the out-of-core cascade
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_search_sharded_bitwise_identical(world, resident, backend):
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    i1, s1 = search.search(resident, q, cfg=cfg, backend=backend,
                           **SEARCH_KW)
    i2, s2 = search.search_sharded(view, q, cfg=cfg, backend=backend,
                                   **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_degenerate_padding_parity(world, resident, backend):
    """Shortlists wider than the probed candidates force the resident
    top-k onto bucket-table padding slots (-inf, id 0); the out-of-core
    merge must synthesize identical entries (positions and all)."""
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    kw = dict(n_probe=2, n_short_aq=500, n_short_pw=100, topk=50, cfg=cfg,
              backend=backend)
    i1, s1 = search.search(resident, q, **kw)
    i2, s2 = search.search_sharded(view, q, **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_single_query_and_full_probe(world, resident):
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir)
    kw = dict(n_probe=8, n_short_aq=64, n_short_pw=16, topk=10, cfg=cfg)
    i1, s1 = search.search(resident, q[:1], **kw)
    i2, s2 = search.search_sharded(view, q[:1], **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_lru_eviction_and_residency_budget(world, resident):
    """max_resident_shards=1 over a 4-shard store: every shard cycles
    through one staging slot, results stay bit-identical, and the peak
    staged bytes never exceed the 1-shard budget — which is strictly
    smaller than staging the whole database (the out-of-core claim)."""
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=1)
    i1, s1 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert len(view.shard_ids) == 4
    assert len(view.resident_shards) == 1            # LRU held the budget
    assert view.peak_resident_bytes <= view.budget_bytes
    total = sum(view.shard_staged_bytes(s) for s in view.shard_ids)
    assert view.budget_bytes < total                 # bounded < database
    # a second search re-stages evicted shards and is deterministic
    i3, s3 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i3))
    assert view.peak_resident_bytes <= view.budget_bytes


def test_lru_moves_hot_shard_to_back(world):
    _, _, _, store_dir, _ = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    view.staged(0), view.staged(1)
    view.staged(0)                                   # touch 0 -> MRU
    view.staged(2)                                   # evicts 1, not 0
    assert view.resident_shards == [0, 2]


def test_gather_rows_matches_store_bytes(world, resident):
    _, _, _, store_dir, _ = world
    view = ShardedIndexView(store_dir)
    gids = np.array([[0, 299, 300], [1099, 600, 0]])
    codes, assign, pw_norms = view.gather_rows(gids)
    np.testing.assert_array_equal(codes,
                                  np.asarray(resident.codes)[gids])
    np.testing.assert_array_equal(assign,
                                  np.asarray(resident.ivf.assignments)[gids])
    np.testing.assert_array_equal(pw_norms,
                                  np.asarray(resident.pw_norms)[gids])


def test_view_guards(world):
    _, _, _, store_dir, _ = world
    with pytest.raises(ValueError, match="max_resident_shards"):
        ShardedIndexView(store_dir, max_resident_shards=0)
    import json
    store = IndexStore(store_dir)
    m = json.loads(store.manifest_path.read_text())
    m["complete"] = False
    store.manifest_path.write_text(json.dumps(m))
    try:
        with pytest.raises(ValueError, match="incomplete"):
            ShardedIndexView(store_dir)
        assert ShardedIndexView(store_dir, allow_partial=True) is not None
    finally:
        m["complete"] = True
        store.manifest_path.write_text(json.dumps(m))


def test_merge_topk_ranked_matches_topk_over_ordered_input():
    """The running merge == one lax.top_k over the pos-ordered list,
    including value ties broken by pos and -inf entries."""
    rng = np.random.default_rng(0)
    vals = rng.choice([1.0, 2.0, 3.0, -np.inf], size=(5, 12)).astype(
        np.float32)
    pos = rng.permutation(12 * 5).reshape(5, 12).astype(np.int32)
    gids = np.arange(60, dtype=np.int32).reshape(5, 12)
    s, p, g = merge_topk_ranked(jnp.asarray(vals), jnp.asarray(pos),
                                jnp.asarray(gids), 6)
    order = np.argsort(pos, axis=1)
    vo = np.take_along_axis(vals, order, 1)
    go = np.take_along_axis(gids, order, 1)
    s_ref, i_ref = jax.lax.top_k(jnp.asarray(vo), 6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(g),
                                  np.take_along_axis(go, np.asarray(i_ref),
                                                     1))


# ---------------------------------------------------------------------------
# partial stores / m_tilde = 0
# ---------------------------------------------------------------------------


def _make_builder(path, xb, params, cfg, **prep):
    b = StreamingIndexBuilder(path, shard_size=300, encode_chunk=256)
    b.prepare(jax.random.key(3), xb, params, cfg, n_total=len(xb),
              k_ivf=8, m_tilde=prep.pop("m_tilde", 2), n_pair_books=4)
    return b


def test_partial_store_view_matches_partial_load(world, tmp_path):
    xb, cfg, params, _, q = world
    b = _make_builder(tmp_path / "p", xb, params, cfg)
    assert not b.build(xb, max_shards=2)
    partial = IndexStore(tmp_path / "p").load(allow_partial=True)
    view = ShardedIndexView(tmp_path / "p", allow_partial=True)
    assert view.n_rows == partial.codes.shape[0] == 600
    i1, s1 = search.search(partial, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_view_m_tilde_zero(world, tmp_path):
    xb, cfg, params, _, q = world
    b = StreamingIndexBuilder(tmp_path / "z", shard_size=600,
                              encode_chunk=256)
    b.prepare(jax.random.key(5), xb, params, cfg, n_total=len(xb),
              k_ivf=8, m_tilde=0, n_pair_books=4)
    assert b.build(xb)
    resident0 = IndexStore(tmp_path / "z").load()
    view = ShardedIndexView(tmp_path / "z")
    assert view.centroid_codes is None
    i1, s1 = search.search(resident0, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ---------------------------------------------------------------------------
# multi-owner sharded builds
# ---------------------------------------------------------------------------


def test_owner_range_partitions_exactly():
    for n_shards in (1, 4, 7, 12):
        for n_hosts in (1, 2, 3, 5):
            ranges = [owner_range(n_shards, h, n_hosts)
                      for h in range(n_hosts)]
            covered = [s for lo, hi in ranges for s in range(lo, hi)]
            assert covered == list(range(n_shards))
            sizes = [hi - lo for lo, hi in ranges]
            assert max(sizes) - min(sizes) <= 1      # balanced
    with pytest.raises(ValueError, match="host_id"):
        owner_range(4, 2, 2)


def _shard_bytes(store_dir, sid):
    d = IndexStore(store_dir).shard_dir(sid)
    return {f: (d / f).read_bytes() for f in SHARD_FILES}


def test_multi_owner_build_byte_identical(world, tmp_path):
    """Two owners writing disjoint ranges of one store == a single-owner
    build, byte-for-byte per shard file — including a kill/resume of one
    owner mid-range (its cursor) and a stale-cursor recovery."""
    xb, cfg, params, _, q = world
    # reference: single owner
    assert _make_builder(tmp_path / "one", xb, params, cfg).build(xb)
    # multi-owner: owner 1 killed after one shard, cursor deleted (stale),
    # then resumed; owner 0 runs after (any interleaving is valid)
    two = tmp_path / "two"
    assert not _make_builder(two, xb, params, cfg).build(
        xb, host_id=1, n_hosts=2, max_shards=1)
    IndexStore(two).cursor_path_for(1).unlink()      # stale cursor
    assert not _make_builder(two, xb, params, cfg).build(
        xb, host_id=0, n_hosts=2)                    # owner 0: not complete
    assert _make_builder(two, xb, params, cfg).build(
        xb, host_id=1, n_hosts=2)                    # owner 1 finalizes
    n_shards = IndexStore(two).manifest["n_shards"]
    assert n_shards == 4
    for sid in range(n_shards):
        assert _shard_bytes(tmp_path / "one", sid) == _shard_bytes(two, sid)
    ia = IndexStore(tmp_path / "one").load()
    ib = IndexStore(two).load()
    i1, s1 = search.search(ia, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search_sharded(ShardedIndexView(two), q, cfg=cfg,
                                   **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(ia.codes), np.asarray(ib.codes))


def test_owner_cursors_are_disjoint_files(world, tmp_path):
    xb, cfg, params, _, _ = world
    d = tmp_path / "c"
    assert not _make_builder(d, xb, params, cfg).build(
        xb, host_id=0, n_hosts=2, max_shards=1)
    assert not _make_builder(d, xb, params, cfg).build(
        xb, host_id=1, n_hosts=2, max_shards=1)
    store = IndexStore(d)
    assert store.cursor_path_for(0).name == "cursor.json"
    assert store.cursor_path_for(1).name == "cursor_00001.json"
    c0, c1 = store.read_cursor(owner=0), store.read_cursor(owner=1)
    assert c0["next_shard"] == 1 and c1["next_shard"] == 3
    # owner 1's fill covers shards [0, 3): recomputed for the absent
    # shard 1 (owner 0 hasn't written it), identical to disk-backed counts
    assert sum(c1["fill"]) == 3 * 300


# ---------------------------------------------------------------------------
# out-of-core serving
# ---------------------------------------------------------------------------


def test_search_server_out_of_core_matches_resident(world, resident):
    from repro.launch.serve_search import SearchServer, synthetic_stream
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    srv = SearchServer(view, micro_batch=8, topk=3, n_probe=4,
                       n_short_aq=16, n_short_pw=8)
    assert srv.out_of_core
    ids, dists = srv.search_batch(np.asarray(q)[:5])
    ref_q = jnp.concatenate([q[:5], jnp.zeros((3, q.shape[1]))])
    ref_ids, ref_d = search.search(resident, ref_q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids)[:5])
    np.testing.assert_array_equal(dists, np.asarray(ref_d)[:5])
    stats = srv.serve_stream(*synthetic_stream(view, 24, 2000.0))
    assert stats.n_queries == 24
    assert view.peak_resident_bytes <= view.budget_bytes
