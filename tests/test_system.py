"""End-to-end behaviour tests for the paper's system.

The QINCo2 pipeline from raw vectors to search results, exercising every
paper component in one flow: normalize -> RQ init -> train QINCo2 (encode
w/ pre-selection+beam, AdamW, dead-code reset) -> IVF index -> AQ +
pairwise shortlists -> neural re-rank, validating the paper's ordering
claims along the way.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny, qinco1
from repro.core import aq, encode as enc, pairwise as pw, rq, search, training

from conftest import clustered


@pytest.fixture(scope="module")
def pipeline():
    rng = np.random.default_rng(7)
    xb = clustered(rng, 8000, 16, k=48)
    xt, xdb = xb[:4000], xb[4000:]
    xq = 0.8 * xdb[:64] + 0.2 * rng.normal(size=(64, 16)).astype(np.float32)
    gt = np.argmin(((xq[:, None] - xdb[None]) ** 2).sum(-1), axis=1)
    cfg = tiny(epochs=3)
    params, hist = training.train(jax.random.key(0), xt, cfg, x_val=xdb[:512],
                                  verbose=False)
    return xt, xdb, xq, gt, cfg, params, hist


def test_paper_ordering_claims(pipeline):
    """Table 3 ordering on synthetic data: QINCo2(beam) <= QINCo2(greedy),
    QINCo2 < RQ on held-out MSE."""
    xt, xdb, xq, gt, cfg, params, hist = pipeline
    val = jnp.asarray(xdb[:1024])
    cbs = rq.rq_train(jax.random.key(0), jnp.asarray(xt), cfg.M, cfg.K, 15)
    _, xhat_rq = rq.rq_encode(cbs, val, B=1)
    mse_rq = float(jnp.mean(jnp.sum((val - xhat_rq) ** 2, -1)))
    mse_greedy = float(enc.reconstruction_mse(params, val, cfg, cfg.K, 1))
    mse_beam = float(enc.reconstruction_mse(params, val, cfg,
                                            cfg.A_eval, cfg.B_eval))
    assert mse_beam <= mse_greedy + 1e-6
    assert mse_beam < mse_rq


def test_training_history_improves(pipeline):
    *_, hist = pipeline
    assert hist[-1]["val_mse"] <= hist[0]["val_mse"] + 1e-6


def test_full_search_flow(pipeline):
    xt, xdb, xq, gt, cfg, params, _ = pipeline
    idx = search.build_index(jax.random.key(1), jnp.asarray(xdb), params,
                             cfg, k_ivf=32, m_tilde=2, n_pair_books=8)
    ids, dists = search.search(idx, jnp.asarray(xq), n_probe=8,
                               n_short_aq=48, n_short_pw=12, topk=5, cfg=cfg)
    r1 = float((np.asarray(ids[:, 0]) == gt).mean())
    r5 = float((np.asarray(ids) == gt[:, None]).any(1).mean())
    assert r1 >= 0.4
    assert r5 >= r1
    # distances are sorted ascending
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-5).all()


def test_shortlist_cascade_claim(pipeline):
    """Table 4's core claim: a pairwise shortlist re-ranked by the QINCo2
    decoder beats the pairwise decoder's own top-1, on the SAME candidates."""
    from repro.core import qinco
    xt, xdb, xq, gt, cfg, params, _ = pipeline
    # few pair-books: the pairwise decoder is the deliberately cheap/less
    # accurate stage (paper §2: "a less accurate but faster decoder") —
    # with many books on a small db it can overfit past the neural codec.
    idx = search.build_index(jax.random.key(1), jnp.asarray(xdb), params,
                             cfg, k_ivf=32, m_tilde=2, n_pair_books=2)
    q = jnp.asarray(xq)
    lut = pw.pairwise_lut(idx.pw.codebooks, q)
    scores = pw.pairwise_scores(lut, idx.ext_codes, idx.pw.pairs, cfg.K,
                                idx.pw_norms)                   # (Q, N)
    direct = np.asarray(jnp.argmax(scores, 1))
    r1_direct = float((direct == gt).mean())
    # shortlist of 10 from the same scores, re-ranked with the full decoder
    _, short = jax.lax.top_k(scores, 10)                        # (Q, 10)
    flat = short.reshape(-1)
    recon = (qinco.decode(params, idx.codes[flat], cfg)
             + idx.ivf.centroids[idx.ivf.assignments[flat]])
    recon = recon.reshape(q.shape[0], 10, -1)
    d2 = jnp.sum((q[:, None] - recon) ** 2, -1)
    rerank = np.asarray(jnp.take_along_axis(
        short, jnp.argmin(d2, 1)[:, None], 1))[:, 0]
    r1_rerank = float((rerank == gt).mean())
    assert r1_rerank >= r1_direct - 1e-9, (r1_rerank, r1_direct)
