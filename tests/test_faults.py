"""The fault-tolerance contract (integrity + injection + degradation):

- `write_shard` records a crc32 checksum sidecar per shard;
  `verify_shard` catches on-disk bit flips and truncation, naming the
  exact shard and file, and `fsck_store` audits a whole store;
- a corrupt shard is quarantined at stage (or open) time: with
  ``on_shard_error="skip"`` serving continues over the healthy shards
  and the affected queries report coverage < 1.0; with ``"raise"``
  (the default) the integrity failure propagates;
- `FaultPlan` is a deterministic oracle (same seed => same faults),
  transient read errors are retried away with zero result impact, a
  dead prefetch worker is resurrected, and failed staging never leaks
  reservation bytes (the budget-leak regression);
- a deadline ejects unfolded shards instead of crashing, and a killed
  build resumed over a corrupt shard rewrites it, byte-for-byte equal
  to an uninterrupted build;
- with faults disabled everything above is inert: `search_sharded`
  stays bit-identical to resident `search()`.
"""
import shutil
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import (FaultPlan, IndexStore, ShardIntegrityError,
                         ShardedIndexView, StagingPool,
                         StreamingIndexBuilder, TransientReadError,
                         corrupt_file, fsck_store, parse_chaos)

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)
_SILENT = lambda *a, **k: None


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Clustered database -> resident index -> saved store (4 shards)."""
    rng = np.random.default_rng(7)
    xb = clustered(rng, 1100, 16, k=16)
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    q = jnp.asarray(xb[:13] + 0.02)
    return xb, cfg, params, store_dir, q


@pytest.fixture(scope="module")
def resident(world):
    _, _, _, store_dir, _ = world
    return IndexStore(store_dir).load()


def _copy_store(store_dir, dst) -> IndexStore:
    shutil.copytree(store_dir, dst)
    return IndexStore(dst)


# ---------------------------------------------------------------------------
# checksums + fsck
# ---------------------------------------------------------------------------


def test_checksum_sidecar_written_and_fsck_clean(world):
    _, _, _, store_dir, _ = world
    store = IndexStore(store_dir)
    for sid in range(store.manifest["n_shards"]):
        cks = store.shard_checksums(sid)
        assert cks["algo"] == "crc32"
        assert set(cks["files"]) == {"codes.u8", "assign.i32",
                                     "aq_norms.f32", "pw_norms.f32"}
        store.verify_shard(sid)                  # sizes + crc on disk
    report = fsck_store(store, log=_SILENT)
    assert report["ok"] and not report["errors"]
    assert len(report["shards_ok"]) == store.manifest["n_shards"]
    assert report["legacy_unchecksummed"] == []


def test_fsck_cli_names_the_corrupt_shard(world, tmp_path, capsys):
    """A flipped on-disk bit fails verify_shard with the exact shard and
    file named, and `python -m repro.index.fsck` exits 1 over it."""
    from repro.index import fsck
    _, _, _, store_dir, _ = world
    store = _copy_store(store_dir, tmp_path / "idx")
    corrupt_file(store.shard_dir(1) / "codes.u8", seed=5)
    with pytest.raises(ShardIntegrityError, match="codes.u8") as ei:
        store.verify_shard(1)
    assert ei.value.shard_id == 1 and "crc32 mismatch" in ei.value.reason
    assert fsck.main([str(store.dir)]) == 1
    capsys.readouterr()                          # drain the log lines
    assert fsck.main([str(store.dir), "--json"]) == 1
    import json
    report = json.loads(capsys.readouterr().out)
    assert report["shards_corrupt"] == [1]
    assert any("shard 00001" in e and "codes.u8" in e
               for e in report["errors"])
    assert fsck.main([str(world[3])]) == 0       # pristine store passes


def test_truncated_shard_detected_without_sidecar(world, tmp_path):
    """Truncation is caught from manifest-implied sizes alone, so even
    legacy shards (sidecar deleted) cannot serve short reads."""
    _, _, _, store_dir, _ = world
    store = _copy_store(store_dir, tmp_path / "idx")
    path = store.shard_dir(2) / "aq_norms.f32"
    (store.shard_dir(2) / "checksums.json").unlink()
    with open(path, "r+b") as f:
        f.truncate(path.stat().st_size - 4)
    with pytest.raises(ShardIntegrityError, match="truncated"):
        store.verify_shard(2)
    report = fsck_store(store, log=_SILENT)
    assert not report["ok"] and report["shards_corrupt"] == [2]


def test_legacy_store_without_sidecars_still_serves(world, resident,
                                                    tmp_path):
    """Pre-sidecar stores stay fully usable (size checks only): view
    opens, results bit-identical, fsck warns but passes."""
    _, cfg, _, store_dir, q = world
    store = _copy_store(store_dir, tmp_path / "idx")
    for sid in range(store.manifest["n_shards"]):
        (store.shard_dir(sid) / "checksums.json").unlink()
    report = fsck_store(store, log=_SILENT)
    assert report["ok"] and len(report["legacy_unchecksummed"]) == 4
    view = ShardedIndexView(store, max_resident_shards=2)
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    i1, s1 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# ---------------------------------------------------------------------------
# quarantine + degraded serving
# ---------------------------------------------------------------------------


def test_corrupt_shard_quarantined_and_skipped(world, tmp_path):
    """On-disk corruption in a staged field surfaces at stage time:
    'raise' propagates it, 'skip' quarantines the shard and answers from
    the remaining shards with per-query coverage < 1.0."""
    _, cfg, _, store_dir, q = world
    store = _copy_store(store_dir, tmp_path / "idx")
    corrupt_file(store.shard_dir(2) / "codes.u8", seed=9)
    strict = ShardedIndexView(store, max_resident_shards=2)
    with pytest.raises(ShardIntegrityError):
        search.search_sharded(strict, q, cfg=cfg, **SEARCH_KW)
    assert 2 in strict.quarantined
    lax_view = ShardedIndexView(store, max_resident_shards=2)
    ids, dists, cov = search.search_sharded(
        lax_view, q, cfg=cfg, on_shard_error="skip", return_coverage=True,
        **SEARCH_KW)
    assert lax_view.quarantined == {2}
    cov = np.asarray(cov)
    assert ids.shape == (13, 3) and cov.shape == (13,)
    assert (cov < 1.0).any() and (cov > 0.0).all()
    # second pass: the denylist short-circuits (no re-read) and results
    # are unchanged
    i2, d2, cov2 = search.search_sharded(
        lax_view, q, cfg=cfg, on_shard_error="skip", return_coverage=True,
        **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(d2))
    np.testing.assert_array_equal(cov, np.asarray(cov2))


def test_corrupt_assign_quarantined_at_open(world, tmp_path):
    """assign.i32 feeds the within-bucket-rank pass, so a corrupt copy
    would silently poison every later shard's ranks — it must be caught
    at OPEN, excluded from the rank/bitmap pass, and count as relevant
    to every query in coverage."""
    _, cfg, _, store_dir, q = world
    store = _copy_store(store_dir, tmp_path / "idx")
    corrupt_file(store.shard_dir(1) / "assign.i32", seed=4)
    view = ShardedIndexView(store, max_resident_shards=2)
    assert view.quarantined == {1}
    assert 1 not in view._bucket_hit and 1 not in view._wbr
    ids, _, cov = search.search_sharded(
        view, q, cfg=cfg, on_shard_error="skip", return_coverage=True,
        **SEARCH_KW)
    assert ids.shape == (13, 3)
    assert (np.asarray(cov) < 1.0).all()         # relevant to every query
    with pytest.raises(ShardIntegrityError, match="quarantined"):
        search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)


def test_coverage_all_ones_on_clean_run(world, resident):
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    i1, s1, cov = search.search_sharded(view, q, cfg=cfg,
                                        return_coverage=True, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(cov), np.ones(13, np.float32))


def test_deadline_ejects_unfolded_shards(world):
    """deadline_s=0 ejects the whole scan: still well-formed output with
    coverage < 1.0; a generous deadline is bit-identical to none."""
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    ids, dists, cov = search.search_sharded(
        view, q, cfg=cfg, deadline_s=0.0, on_shard_error="skip",
        return_coverage=True, **SEARCH_KW)
    assert ids.shape == (13, 3) and dists.shape == (13, 3)
    assert (np.asarray(cov) < 1.0).any()
    i0, s0 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    i1, s1, cov1 = search.search_sharded(
        view, q, cfg=cfg, deadline_s=600.0, return_coverage=True,
        **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(cov1), np.ones(13, np.float32))


def test_serve_stream_reports_degradation(world, tmp_path):
    """`SearchServer(on_shard_error='skip')` over a store with one
    corrupt shard: the stream completes, `ServeStats` carries
    degraded_queries >= 1 and mean_coverage < 1.0."""
    from repro.launch.serve_search import SearchServer, synthetic_stream
    _, _, _, store_dir, _ = world
    store = _copy_store(store_dir, tmp_path / "idx")
    corrupt_file(store.shard_dir(3) / "codes.u8", seed=2)
    view = ShardedIndexView(store, max_resident_shards=2)
    srv = SearchServer(view, micro_batch=8, topk=3, n_probe=4,
                       n_short_aq=16, n_short_pw=8, on_shard_error="skip")
    stats = srv.serve_stream(*synthetic_stream(view, 24, 2000.0))
    assert view.quarantined == {3}
    assert stats.n_queries == 24
    assert stats.degraded_queries >= 1
    assert 0.0 < stats.mean_coverage < 1.0
    assert f"degraded={stats.degraded_queries}" in stats.row()


# ---------------------------------------------------------------------------
# fault injection: determinism, retries, worker death, leak regression
# ---------------------------------------------------------------------------


def test_faultplan_deterministic_and_seed_sensitive():
    a = FaultPlan(5, p_read_error=0.5, p_corrupt=0.5)
    b = FaultPlan(5, p_read_error=0.5, p_corrupt=0.5)
    for k in range(64):
        assert a.would_read_error(k, 0) == b.would_read_error(k, 0)
        assert a.corrupts(k) == b.corrupts(k)
    c = FaultPlan(6, p_read_error=0.5, p_corrupt=0.5)
    assert any(a.would_read_error(k, 0) != c.would_read_error(k, 0)
               for k in range(64))
    arrays = {"x": np.zeros(64, np.uint8), "y": np.ones(16, np.float32)}
    key = next(k for k in range(64) if a.corrupts(k))
    ca, cb = a.corrupt_arrays(key, arrays), b.corrupt_arrays(key, arrays)
    assert not arrays["x"].any() and (arrays["y"] == 1.0).all()  # copies
    changed = [n for n in arrays if not np.array_equal(ca[n], arrays[n])]
    assert len(changed) == 1                     # one field touched...
    np.testing.assert_array_equal(ca[changed[0]], cb[changed[0]])
    diff = np.bitwise_xor(ca[changed[0]].reshape(-1).view(np.uint8),
                          arrays[changed[0]].reshape(-1).view(np.uint8))
    assert int(np.unpackbits(diff).sum()) == 1   # ...by exactly one bit


def test_parse_chaos_roundtrip():
    p = parse_chaos("p_read_error=0.2, p_corrupt=0.1, seed=7, "
                    "read_error_max_per_key=1, latency_s=0.005")
    assert (p.seed, p.p_read_error, p.p_corrupt) == (7, 0.2, 0.1)
    assert p.read_error_max_per_key == 1 and p.latency_s == 0.005
    with pytest.raises(ValueError, match="key=value"):
        parse_chaos("p_read_error")
    with pytest.raises(ValueError, match="outside"):
        parse_chaos("p_corrupt=1.5")


def test_transient_read_errors_retried_away(world, resident):
    """p_read_error=1.0 capped at one failure per shard: every first
    read fails, every retry succeeds — results bit-identical, the
    staging retry counter proves the failures actually happened."""
    _, cfg, _, store_dir, q = world
    plan = FaultPlan(3, p_read_error=1.0, read_error_max_per_key=1)
    view = ShardedIndexView(store_dir, max_resident_shards=2,
                            prefetch=False, faults=plan)
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    i1, s1 = search.search_sharded(view, q, cfg=cfg, prefetch=False,
                                   **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert plan.injected["read_error"] == len(view.shard_ids)
    assert view.pool.stats()["retries"] == len(view.shard_ids)
    assert view.quarantined == set()


def test_staging_failure_leaks_no_reservation():
    """Budget-leak regression: N failed acquires (retry exhaustion) and
    a failed prefetch leave resident_bytes at baseline, and the full
    budget is still usable afterwards."""
    plan = FaultPlan(0, p_read_error=1.0)        # uncapped: never succeeds

    def bad():
        plan.on_read("k")
        raise AssertionError("unreachable")      # pragma: no cover

    pool = StagingPool(64, prefetch=False, retries=1, retry_backoff_s=0.0)
    for _ in range(5):
        with pytest.raises(TransientReadError):
            pool.acquire(("o", 0), bad, 48)
    assert pool.resident_bytes == 0
    assert pool.stats()["retries"] == 5          # one retry per acquire
    pool2 = StagingPool(64, retries=1, retry_backoff_s=0.0)
    assert pool2.prefetch(("o", 0), bad, 48)     # worker aborts it
    with pytest.raises(TransientReadError):
        pool2.acquire(("o", 0), bad, 48)
    assert pool2.resident_bytes == 0
    mk = lambda: {"x": np.ones(16, np.float32)}  # 64 B = the whole budget
    for pool_ in (pool, pool2):
        pool_.acquire(("o", 1), mk, 64)
        assert pool_.resident_bytes == 64
        pool_.release(("o", 1))


def test_worker_death_resurrection():
    """p_worker_death=1.0: the worker dies on every job, aborting the
    job's reservation; acquire recovers synchronously and the next
    prefetch resurrects the thread (worker_restarts counts it)."""
    plan = FaultPlan(0, p_worker_death=1.0)
    pool = StagingPool(64, faults=plan)
    mk = lambda: {"x": np.ones(8, np.float32)}
    assert pool.prefetch(("o", 0), mk, 32)
    deadline = time.monotonic() + 10.0
    while pool.resident_bytes and time.monotonic() < deadline:
        time.sleep(0.005)                        # death aborts reservation
    assert pool.resident_bytes == 0
    assert plan.injected["worker_death"] == 1
    thread = pool._worker
    while thread.is_alive() and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not thread.is_alive()
    pool.acquire(("o", 0), mk, 32)               # sync recovery
    assert pool.prefetch(("o", 1), mk, 32)       # resurrects the worker
    assert pool.stats()["worker_restarts"] == 1
    pool.acquire(("o", 1), mk, 32)               # job #2 dies; sync again
    assert plan.injected["worker_death"] == 2
    pool.release(("o", 0)), pool.release(("o", 1))
    assert pool.resident_bytes == 64


def test_chaos_transient_only_is_bit_identical(world, resident):
    """A plan with read errors, latency spikes and worker deaths — but
    NO corruption — must be invisible in the results: every fault is
    retried or recovered away. (read_error_max_per_key=2 keeps the
    worst case inside the pool's default retry budget.)"""
    _, cfg, _, store_dir, q = world
    plan = FaultPlan(11, p_read_error=0.5, read_error_max_per_key=2,
                     p_latency=0.3, latency_s=0.001, p_worker_death=0.5)
    view = ShardedIndexView(store_dir, max_resident_shards=2, faults=plan)
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    for _ in range(2):                           # second pass re-stages
        i1, s1 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert view.quarantined == set()
    assert view.pool.resident_bytes <= view.budget_bytes


# ---------------------------------------------------------------------------
# builder: chaos resume rewrites corrupt shards
# ---------------------------------------------------------------------------


def _make_builder(path, xb, params, cfg):
    b = StreamingIndexBuilder(path, shard_size=300, encode_chunk=256)
    b.prepare(jax.random.key(3), xb, params, cfg, n_total=len(xb),
              k_ivf=8, m_tilde=2, n_pair_books=4)
    return b


def test_builder_resume_rewrites_corrupt_shard(world, tmp_path):
    """Chaos resume: kill the build at a seeded random point, corrupt a
    seeded completed shard on disk, resume — the corrupt shard is
    treated as absent and rewritten, and every shard file ends up
    byte-for-byte equal to an uninterrupted build (fsck-clean)."""
    xb, cfg, params, _, _ = world
    rng = np.random.default_rng(123)
    kill_at = int(rng.integers(1, 4))            # die after 1-3 of 4 shards
    a = _make_builder(tmp_path / "a", xb, params, cfg)
    assert not a.build(xb, max_shards=kill_at)
    store_a = IndexStore(tmp_path / "a")
    victim = int(rng.integers(0, kill_at))
    corrupt_file(store_a.shard_dir(victim) / "codes.u8", seed=5)
    with pytest.raises(ShardIntegrityError):
        store_a.verify_shard(victim)
    a2 = _make_builder(tmp_path / "a", xb, params, cfg)
    assert a2.build(xb)
    b = _make_builder(tmp_path / "b", xb, params, cfg)
    assert b.build(xb)
    store_b = IndexStore(tmp_path / "b")
    for sid in range(store_a.manifest["n_shards"]):
        da, db = store_a.shard_dir(sid), store_b.shard_dir(sid)
        assert (sorted(p.name for p in da.iterdir())
                == sorted(p.name for p in db.iterdir()))
        for p in da.iterdir():
            assert p.read_bytes() == (db / p.name).read_bytes(), \
                f"shard {sid}/{p.name} differs after chaos resume"
    assert fsck_store(store_a, log=_SILENT)["ok"]
