"""The ops-dispatch contract (kernels/ops.py):

- pallas(interpret) == xla backend for every scoring op, on shapes that are
  NOT tile multiples (padding is the facade's job, not the caller's);
- encode(..., backend="xla") is bit-identical to the pre-refactor greedy
  Python-loop path (A=K, B=1, qinco1 mode) and to backend="pallas";
- encode() traces ONE lax.scan over steps (trace size independent of M);
- encode_dataset covers a dataset larger than its chunk with static shapes.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, training
from repro.kernels import ops, ref

from conftest import clustered


# ---------------------------------------------------------------------------
# backend parity on non-tile-multiple shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,d,K,A", [(37, 12, 16, 4), (130, 24, 32, 8)])
def test_l2_topk_backend_parity(N, d, K, A):
    rng = np.random.default_rng(N)
    r = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    ip, dp = ops.l2_topk(r, cb, A, backend="pallas", tile_n=64)
    ix, dx = ops.l2_topk(r, cb, A, backend="xla")
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dx),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(ip) == np.asarray(ix)).mean() > 0.98


@pytest.mark.parametrize("Q,N,M,K", [(13, 37, 4, 16), (7, 129, 3, 32)])
def test_adc_shared_backend_parity(Q, N, M, K):
    rng = np.random.default_rng(Q * N)
    codes = jnp.asarray(rng.integers(0, K, size=(N, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(Q, M, K)).astype(np.float32))
    norms = jnp.asarray(rng.normal(size=(N,)).astype(np.float32) ** 2)
    sp = ops.adc_scores(codes, lut, norms=norms, backend="pallas",
                        tile_q=8, tile_n=32)
    sx = ops.adc_scores(codes, lut, norms=norms, backend="xla")
    sr = 2.0 * ref.adc_ref(codes, lut) - norms[None, :]
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,C,M,K", [(5, 33, 4, 16), (11, 70, 3, 8)])
def test_adc_batched_backend_parity(Q, C, M, K):
    """Per-query candidate form: codes (Q, C, M) -> (Q, C)."""
    rng = np.random.default_rng(Q + C)
    codes = jnp.asarray(rng.integers(0, K, size=(Q, C, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(Q, M, K)).astype(np.float32))
    sp = ops.adc_scores(codes, lut, backend="pallas", tile_q=4, tile_n=32)
    sx = ops.adc_scores(codes, lut, backend="xla")
    sr = ref.adc_batched_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("shape", ["shared", "batched"])
def test_pairwise_scores_backend_parity(shape):
    """K^2-alphabet pairwise codes reuse the one-hot ADC machinery."""
    rng = np.random.default_rng(3)
    K, M_all, Mp = 8, 5, 3
    pairs = ((0, 2), (1, 4), (2, 3))
    if shape == "shared":
        codes = rng.integers(0, K, size=(41, M_all)).astype(np.int32)
        norms = (rng.normal(size=(41,)) ** 2).astype(np.float32)
    else:
        codes = rng.integers(0, K, size=(6, 21, M_all)).astype(np.int32)
        norms = (rng.normal(size=(6, 21)) ** 2).astype(np.float32)
    codes = jnp.asarray(codes)
    norms = jnp.asarray(norms)
    lut = jnp.asarray(rng.normal(size=(6, Mp, K * K)).astype(np.float32))
    sp = ops.pairwise_scores(codes, lut, pairs, K, norms=norms,
                             backend="pallas", tile_q=4, tile_n=16)
    sx = ops.pairwise_scores(codes, lut, pairs, K, norms=norms,
                             backend="xla")
    buckets = ops.pairwise_buckets(codes, pairs, K)
    if shape == "shared":
        sr = 2.0 * ref.adc_ref(buckets, lut) - norms[None, :]
    else:
        sr = 2.0 * ref.adc_batched_ref(buckets, lut) - norms
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# encode: pre-refactor equivalence + scan structure
# ---------------------------------------------------------------------------


def _encode_reference(params, x, cfg, A, B):
    """The pre-refactor encoder: Python loop over m, beam grown from 1."""
    A = min(A, cfg.K)
    N, d = x.shape
    xhat = jnp.zeros((N, 1, d), x.dtype)
    err = jnp.zeros((N, 1), x.dtype)
    codes = jnp.zeros((N, 1, cfg.M), jnp.int32)
    for m in range(cfg.M):
        fm = jax.tree.map(lambda a: a[m], params["f"])
        cb = params["codebooks"][m]
        pre_cb = params["pre_codebooks"][m]
        Bcur = xhat.shape[1]
        r = x[:, None, :] - xhat
        if A >= cfg.K:
            idx = jnp.broadcast_to(jnp.arange(cfg.K), (N, Bcur, cfg.K))
        else:
            r2 = jnp.sum(r * r, axis=-1, keepdims=True)
            c2 = jnp.sum(pre_cb * pre_cb, axis=-1)
            d2 = r2 - 2.0 * jnp.einsum("nbd,kd->nbk", r, pre_cb) + c2
            _, idx = lax.top_k(-d2, A)
        cand = cb[idx]
        f_out = qinco.f_apply(fm, cand, xhat[..., None, :], cfg)
        new_xhat = xhat[..., None, :] + f_out
        new_err = jnp.sum(jnp.square(x[:, None, None, :] - new_xhat), -1)
        k = min(B, Bcur * A)
        flat_err = new_err.reshape(N, Bcur * A)
        top_err, flat_idx = lax.top_k(-flat_err, k)
        b_idx = flat_idx // A
        xhat = jnp.take_along_axis(
            new_xhat.reshape(N, Bcur * A, d), flat_idx[..., None], axis=1)
        sel_code = jnp.take_along_axis(
            idx.reshape(N, Bcur * A), flat_idx, axis=1)
        codes = jnp.take_along_axis(codes, b_idx[..., None], axis=1)
        codes = codes.at[:, :, m].set(sel_code)
        err = -top_err
    best = jnp.argmin(err, axis=1)
    return (jnp.take_along_axis(codes, best[:, None, None], 1)[:, 0],
            jnp.take_along_axis(xhat, best[:, None, None], 1)[:, 0])


@pytest.fixture(scope="module")
def q1_setup():
    rng = np.random.default_rng(0)
    x = clustered(rng, 256, 8)
    cfg = tiny(d=8, de=8, dh=16, M=3, K=8, qinco1_mode=True)
    params = training.init_qinco2(jax.random.key(1), x, cfg)
    return cfg, params, jnp.asarray(x)


def test_encode_xla_bit_identical_to_greedy_reference(q1_setup):
    """A=K, B=1 (QINCo1 greedy) must survive the scan refactor bitwise."""
    cfg, params, x = q1_setup
    c_ref, xh_ref = _encode_reference(params, x, cfg, cfg.K, 1)
    c_new, xh_new, _ = enc.encode(params, x, cfg, cfg.K, 1, backend="xla")
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_new))
    np.testing.assert_array_equal(np.asarray(xh_ref), np.asarray(xh_new))


def test_encode_beam_matches_growing_beam_reference(q1_setup):
    """A<K, B>1: static-width beam (inf-masked empty slots) == grown beam."""
    cfg, params, x = q1_setup
    c_ref, xh_ref = _encode_reference(params, x, cfg, 4, 6)
    c_new, xh_new, _ = enc.encode(params, x, cfg, 4, 6, backend="xla")
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_new))
    np.testing.assert_allclose(np.asarray(xh_ref), np.asarray(xh_new),
                               rtol=1e-6, atol=1e-6)


def test_encode_backend_parity(q1_setup):
    cfg, params, x = q1_setup
    c_x, xh_x, _ = enc.encode(params, x, cfg, 4, 4, backend="xla")
    c_p, xh_p, _ = enc.encode(params, x, cfg, 4, 4, backend="pallas")
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_p))
    np.testing.assert_allclose(np.asarray(xh_x), np.asarray(xh_p),
                               rtol=1e-5, atol=1e-5)


def test_encode_traces_one_scan_independent_of_M():
    """The jaxpr must contain a scan and not grow with M (no unrolling)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    sizes = {}
    for M in (2, 7):
        cfg = tiny(d=8, de=8, dh=16, M=M, K=8)
        params = training.init_qinco2(jax.random.key(0), np.asarray(x), cfg)
        jaxpr = jax.make_jaxpr(
            lambda p, xx: enc._encode_impl(p, xx, cfg, 4, 4))(params, x)
        assert any(e.primitive.name == "scan" for e in jaxpr.eqns)
        sizes[M] = len(jaxpr.eqns)
    assert sizes[2] == sizes[7], sizes


def test_encode_dataset_chunks_match_single_batch():
    """A dataset larger than the chunk encodes identically, chunk by chunk
    (static chunk shapes; padded tail rows never leak)."""
    rng = np.random.default_rng(4)
    x = clustered(rng, 300, 16)
    cfg = tiny()
    params = training.init_qinco2(jax.random.key(0), x, cfg)
    codes_d, xhat_d, mse_d = enc.encode_dataset(params, x, cfg, 4, 4,
                                                chunk=128)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4)
    np.testing.assert_array_equal(codes_d, np.asarray(codes))
    np.testing.assert_allclose(xhat_d, np.asarray(xhat), rtol=1e-6,
                               atol=1e-6)
    assert np.isfinite(mse_d)
