"""Substrate: optimizer, schedules, checkpointing, data pipeline,
grad compression, KV quantization, sharding rules."""
import os
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager, StragglerMonitor
from repro.core import grad_compress as gc
from repro.core import kv_quant
from repro.data.synthetic import make_vectors, token_stream
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


# -- optimizer ---------------------------------------------------------------

def test_adamw_decoupled_weight_decay():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=None)
    p = {"w": jnp.ones((4,))}
    s = adamw.init(p, cfg)
    zero_g = {"w": jnp.zeros((4,))}
    p2, _, _ = adamw.update(zero_g, s, p, cfg)
    # pure decay: p - lr*wd*p
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - 0.1 * 0.5, rtol=1e-5)


def test_grad_clipping():
    g = {"w": jnp.full((100,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(100.0, rel=1e-4)


def test_cosine_schedule_shape():
    sch = cosine_with_warmup(1e-3, 100, 10, min_ratio=0.1)
    assert float(sch(jnp.asarray(0))) == pytest.approx(0.0, abs=1e-8)
    assert float(sch(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(sch(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


# -- checkpointing ------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    mgr.save(5, tree, extra={"loss": 1.0})
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 5 and extra["loss"] == 1.0
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert sorted(mgr.all_steps()) == [3, 4]


def test_checkpoint_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(AssertionError):
        mgr.restore(1, {"a": jnp.zeros(2), "b": jnp.zeros(2)})


def test_resume_bit_exact(tmp_path):
    """train k steps + resume == train straight through (restart safety).

    total_steps pins the LR-schedule horizon across the restart."""
    from repro.configs import get_arch
    from repro.launch.train import train_loop
    arch = get_arch("mamba2-1.3b").reduced()
    kw = dict(batch=4, seq=32, verbose=False, lr=1e-3, total_steps=8)
    pA, _, lA = train_loop(arch, steps=8, **kw)
    train_loop(arch, steps=4, ckpt_dir=tmp_path, ckpt_every=3, **kw)
    pB, _, lB = train_loop(arch, steps=8, ckpt_dir=tmp_path, ckpt_every=100,
                           **kw)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_straggler_monitor():
    m = StragglerMonitor(window=20, k=3.0)
    for i in range(15):
        assert not m.record(i, 1.0 + 0.01 * (i % 3))
    assert m.record(15, 10.0)


# -- data ----------------------------------------------------------------------

def test_token_stream_deterministic():
    a = next(token_stream(64, 16, 4, seed=3))
    b = next(token_stream(64, 16, 4, seed=3))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_vectors_reproducible_and_normalizable():
    x1 = make_vectors("bigann", 256, seed=5)
    x2 = make_vectors("bigann", 256, seed=5)
    np.testing.assert_array_equal(x1, x2)
    assert x1.shape == (256, 128)


# -- gradient compression -------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 64, 256]))
def test_int8_roundtrip_error_bound(seed, block):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(333,)).astype(np.float32))
    q, s = gc.quantize_int8(g, block)
    deq = gc.dequantize_int8(q, s, g.shape)
    # per-block error bounded by scale/2 = absmax/254
    err = np.abs(np.asarray(deq - g))
    bound = np.repeat(np.asarray(s)[:, 0] / 2 + 1e-7,
                      block)[:g.shape[0]]
    assert (err <= bound + 1e-6).all()


def test_compressed_psum_single_pod():
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 8))
                          .astype(np.float32))}
    from repro.parallel import compat
    with compat.use_mesh(mesh):
        out = gc.compressed_psum_pods(g, mesh)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=2e-2, atol=2e-2)


def test_wire_bytes_model():
    full, comp = gc.wire_bytes_saved(1_000_000, pods=2)
    assert comp < full / 3.5


# -- kv quantization -------------------------------------------------------------

def test_kv_quant_mse_decreases_with_bytes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512, 2, 16)).astype(np.float32))
    mses = []
    for m in (1, 2, 4):
        cb = kv_quant.fit_kv_codebooks(jax.random.key(0), x, m, 16)
        mses.append(float(kv_quant.quantization_mse(x[None], cb)))
    assert mses[2] < mses[1] < mses[0]


def test_kv_quant_roundtrip_shapes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 2, 8)).astype(np.float32))
    cb = kv_quant.fit_kv_codebooks(jax.random.key(0), x, 2, 8)
    codes = kv_quant.encode_kv(x, cb)
    assert codes.shape == (64, 2, 2) and codes.dtype == jnp.uint8


# -- sharding rules ---------------------------------------------------------------

def test_rules_drop_nondivisible():
    from repro.configs import get_arch, SHAPE_BY_NAME
    from repro.models import lm
    from repro.parallel import sharding as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # build against a fake 16x16 mesh shape by monkeypatching sizes
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    arch = get_arch("deepseek-coder-33b")      # 56 heads: not divisible
    rules, ctx = shd.make_rules(arch, FakeMesh(), SHAPE_BY_NAME["train_4k"])
    specs = lm.param_specs(arch)
    ps = shd.pspec_tree(specs, rules, FakeMesh())
    wq = ps["backbone"]["layers"]["attn"]["wq"]      # (L, d, 56, 128)
    # heads dim (56) must be replicated, embed fsdp'd over data
    assert wq[1] == "data" and (len(wq) < 3 or wq[2] is None)
    mlp = ps["backbone"]["layers"]["mlp"]["gate"]    # (L, d, 19200)
    assert mlp[2] == "model"


def test_bytes_per_device_math():
    from repro.models.common import ParamSpec
    from repro.parallel import sharding as shd

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    spec = {"w": ParamSpec((8, 16), ("embed", "mlp"), jnp.float32)}
    rules = {"embed": "data", "mlp": "model"}
    b = shd.bytes_per_device(spec, rules, FakeMesh())
    assert b == 8 * 16 * 4 // 8
