"""The network front door contract (docs/SERVING.md):

- framing: encode/decode roundtrip, partial reads reassembled, malformed
  frames typed `INVALID_ARGUMENT`, mid-frame EOF counted as a peer drop;
- socket-served results are BIT-IDENTICAL to the in-process
  `search_batch` path on the same store (resident and out-of-core);
- admission: continuous batching coalesces concurrent requests, typed
  rejections (unknown op/tenant, bad shapes, bad deadline), bounded
  queue sheds `RESOURCE_EXHAUSTED` past the watermark and the client's
  capped-backoff retry policy clears transient sheds while never
  retrying persistent errors;
- deadline propagation: queueing delay spends the per-query budget
  (remaining-budget arithmetic in `serve_stream`), a fully-expired
  budget still dispatches and answers degraded (coverage < 1), never
  stalls;
- multi-tenancy: round-robin scheduling answers both tenants, quotas
  bound one tenant's queue share;
- graceful drain: every accepted query is answered exactly once,
  late requests get `UNAVAILABLE`, `/healthz` / `/readyz` flip, the
  empty-stream `serve_stream` regression returns zeroed stats;
- chaos: injected connection drops, slow writes, malformed frames and
  vanishing clients (FaultPlan network kinds) never crash the server,
  never duplicate an answer.
"""
import json
import socket
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore, ShardedIndexView
from repro.index.faults import FaultPlan
from repro.launch import transport as tp
from repro.launch.search_client import (
    STATUS_VANISHED, SearchClient, run_closed_loop, run_open_loop)
from repro.launch.serve_search import (
    SearchFrontDoor, SearchServer, ServeStats, _PendingRequest, _Tenant)

from conftest import clustered

SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def _pair():
    a, b = socket.socketpair()
    return a, b


def test_frame_roundtrip():
    a, b = _pair()
    body = np.arange(7, dtype="<f4").tobytes()
    tp.send_frame(a, {"op": "search", "n": 7}, body)
    header, got = tp.recv_frame(b)
    assert header == {"op": "search", "n": 7}
    assert got == body
    a.close()
    assert tp.recv_frame(b) is None              # clean EOF between frames
    b.close()


def test_frame_partial_writes_reassembled():
    a, b = _pair()
    frame = tp.encode_frame({"x": 1}, b"abcdef" * 100)
    done = threading.Event()

    def dribble():
        for i in range(0, len(frame), 7):
            a.sendall(frame[i:i + 7])
            time.sleep(0.0005)
        done.set()

    threading.Thread(target=dribble, daemon=True).start()
    header, body = tp.recv_frame(b)
    assert header == {"x": 1} and body == b"abcdef" * 100
    done.wait(2.0)
    a.close()
    b.close()


def test_frame_malformed_and_abort():
    # bad header JSON -> FrameError
    a, b = _pair()
    garbage = b"\xffnot-json" * 2
    a.sendall(tp._U32.pack(len(garbage)) + garbage)
    with pytest.raises(tp.FrameError):
        tp.recv_frame(b)
    a.close()
    b.close()
    # oversized declared length -> FrameError before reading the payload
    a, b = _pair()
    a.sendall(tp._U32.pack(tp.MAX_FRAME + 1))
    with pytest.raises(tp.FrameError):
        tp.recv_frame(b)
    a.close()
    b.close()
    # EOF mid-frame -> ConnectionAbort (a peer drop, not a protocol error)
    a, b = _pair()
    frame = tp.encode_frame({"x": 1}, b"y" * 64)
    a.sendall(frame[: len(frame) // 2])
    a.close()
    with pytest.raises(tp.ConnectionAbort):
        tp.recv_frame(b)
    b.close()


def test_slow_reader_send_times_out_not_wedges():
    """A client that keeps the connection open but stops READING fills
    its TCP buffer; the per-socket send timeout turns the would-be
    forever-blocked `sendall` into a counted send failure + close —
    other connections keep being served and `close()` doesn't deadlock
    on the write lock a blocked sendall would hold."""
    from repro import obs
    big = b"x" * (1 << 21)                       # 2 MB reply

    def handler(conn, header, body):
        conn.send({"id": header.get("id"), "status": tp.STATUS_OK}, big)

    srv = tp.TransportServer(handler, send_timeout_s=0.3)
    fails0 = obs.series_value(obs.snapshot(),
                              "transport_send_failures_total")
    try:
        stalled = socket.socket()
        stalled.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 1 << 14)
        stalled.connect(("127.0.0.1", srv.port))
        t0 = time.perf_counter()
        for i in range(8):                       # never reads a reply
            tp.send_frame(stalled, {"id": i})
        # the stalled connection must be torn down within a few timeout
        # periods, never block indefinitely
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline:
            fails = obs.series_value(obs.snapshot(),
                                     "transport_send_failures_total")
            if fails > fails0:
                break
            time.sleep(0.02)
        assert fails > fails0, "blocked sendall never timed out"
        assert time.perf_counter() - t0 < 10
        # a healthy client on another connection is still answered
        ok = socket.create_connection(("127.0.0.1", srv.port), timeout=5)
        tp.send_frame(ok, {"id": 99})
        header, body = tp.recv_frame(ok)
        assert header["status"] == tp.STATUS_OK and body == big
        ok.close()
        stalled.close()
    finally:
        t0 = time.perf_counter()
        srv.close()                              # must not deadlock
        assert time.perf_counter() - t0 < 10


def test_reader_threads_pruned_after_disconnect():
    # regression: one Thread object leaked per connection ever accepted
    srv = tp.TransportServer(lambda conn, h, b: conn.send({"ok": 1}))
    try:
        for _ in range(5):
            s = socket.create_connection(("127.0.0.1", srv.port),
                                         timeout=5)
            tp.send_frame(s, {"id": 0})
            tp.recv_frame(s)
            s.close()
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline:
            with srv._lock:
                if not srv._threads and not srv._conns:
                    break
            time.sleep(0.01)
        with srv._lock:
            assert srv._threads == [] and not srv._conns
    finally:
        srv.close()


def test_transport_server_echo_and_malformed():
    got = []

    def handler(conn, header, body):
        got.append(header)
        conn.send({"echo": header["id"]}, body[::-1])

    srv = tp.TransportServer(handler)
    try:
        sock = socket.create_connection(("127.0.0.1", srv.port), timeout=5)
        tp.send_frame(sock, {"id": 1}, b"abc")
        header, body = tp.recv_frame(sock)
        assert header == {"echo": 1} and body == b"cba"
        # garbage after a good frame: typed reply, then the server closes
        garbage = b"\x00bad" * 3
        sock.sendall(tp._U32.pack(len(garbage)) + garbage)
        header, _ = tp.recv_frame(sock)
        assert header["status"] == tp.STATUS_INVALID
        assert tp.recv_frame(sock) is None
        sock.close()
    finally:
        srv.close()
    assert got == [{"id": 1}]


# ---------------------------------------------------------------------------
# fixtures: a real tiny store (resident + out-of-core servers) and a
# cheap fake server for pure scheduling tests (no jit warmup)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    rng = np.random.default_rng(33)
    xb = clustered(rng, 900, 16, k=16)
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params,
                             cfg, k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    q = np.asarray(xb[:13] + 0.02, np.float32)
    return store_dir, q


@pytest.fixture(scope="module")
def resident_server(world):
    store_dir, _ = world
    idx = IndexStore(store_dir).load()
    return SearchServer(idx, micro_batch=8, **SEARCH_KW)


@pytest.fixture(scope="module")
def ooc_server(world):
    store_dir, _ = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    return SearchServer(view, micro_batch=8, **SEARCH_KW)


def _fake_server(*, d=4, micro_batch=8, service_s=0.0, out_of_core=False):
    """A `SearchServer` shell with a deterministic, index-free
    `search_batch` — scheduling/admission tests without a jit warmup."""
    srv = SearchServer.__new__(SearchServer)
    srv.index = None
    srv.micro_batch = micro_batch
    srv.d = d
    srv.out_of_core = out_of_core
    srv.deadline_s = None
    srv.last_coverage = None
    srv.warmup_s = 0.0
    calls = []

    def search_batch(q, **kw):
        calls.append(dict(kw, n=np.asarray(q).shape[0]))
        if service_s:
            time.sleep(service_s)
        q = np.asarray(q)
        ids = (np.arange(3)[None, :] + np.round(q.sum(1))[:, None]
               ).astype(np.int32)
        dists = q[:, :3].astype(np.float32)
        if out_of_core:
            srv.last_coverage = np.ones(q.shape[0], np.float32)
        return ids, dists

    srv.search_batch = search_batch
    srv._fake_calls = calls
    return srv


def _front(server, name="default", **kw):
    fd = SearchFrontDoor(**kw)
    fd.register(name, server)
    fd.start()
    return fd


# ---------------------------------------------------------------------------
# bit-identical serving over the socket (both backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["resident", "ooc"])
def test_socket_results_bit_identical(which, world, resident_server,
                                      ooc_server):
    server = resident_server if which == "resident" else ooc_server
    _, q = world
    want_ids, want_dists = server.search_batch(q[:8])
    fd = _front(server)
    try:
        client = SearchClient("127.0.0.1", fd.port)
        pong = client.ping()
        assert pong["status"] == tp.STATUS_OK
        assert pong["tenants"]["default"]["d"] == 16
        res = client.search(q[:8])
        assert res.ok
        np.testing.assert_array_equal(res.ids, np.asarray(want_ids))
        assert res.dists.tobytes() == np.asarray(
            want_dists, "<f4").tobytes()
        if which == "ooc":
            assert res.coverage is not None
            np.testing.assert_array_equal(res.coverage, 1.0)
        else:
            assert res.coverage is None
        # several single-row requests coalesce into batches; results
        # still match the rows of the one-shot call
        results = [client.search(q[i:i + 1]) for i in range(8)]
        for i, r in enumerate(results):
            assert r.ok
            np.testing.assert_array_equal(
                r.ids[0], np.asarray(want_ids)[i])
    finally:
        fd.shutdown()


# ---------------------------------------------------------------------------
# admission: typed rejections + never-retry-persistent
# ---------------------------------------------------------------------------


def test_typed_rejections():
    fd = _front(_fake_server(d=4))
    try:
        client = SearchClient("127.0.0.1", fd.port, max_retries=3)
        q = np.zeros((1, 4), np.float32)
        r = client.search(q, tenant="nope")
        assert r.status == tp.STATUS_NOT_FOUND and r.retries == 0
        r = client.search(np.zeros((1, 5), np.float32))   # wrong d
        assert r.status == tp.STATUS_INVALID and r.retries == 0
        r = client.search(np.zeros((9, 4), np.float32))   # n > micro_batch
        assert r.status == tp.STATUS_INVALID
        r = client.search(q, deadline_ms=-5)
        assert r.status == tp.STATUS_INVALID
        # a VALID deadline on a resident tenant is rejected too (no
        # shard loop to eject — the network mirror of the CLI rule), so
        # the knob never silently no-ops
        r = client.search(q, deadline_ms=50)
        assert r.status == tp.STATUS_INVALID and r.retries == 0
        assert "out-of-core" in r.error
        # unknown op straight on the wire
        sock = socket.create_connection(("127.0.0.1", fd.port), timeout=5)
        tp.send_frame(sock, {"id": 1, "op": "mystery"})
        header, _ = tp.recv_frame(sock)
        assert header["status"] == tp.STATUS_INVALID
        sock.close()
        assert fd.n_rejected == 5 + 1 and fd.n_shed == 0
    finally:
        fd.shutdown()


def test_continuous_batching_coalesces():
    srv = _fake_server(d=4, micro_batch=8, service_s=0.01)
    fd = _front(srv, max_wait_s=0.05)
    try:
        client = SearchClient("127.0.0.1", fd.port)
        qs = [np.full((1, 4), i, np.float32) for i in range(6)]
        results = [None] * 6
        ts = [threading.Thread(target=lambda i=i: results.__setitem__(
            i, client.search(qs[i]))) for i in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)
        assert all(r is not None and r.ok for r in results)
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.dists[0], qs[i][0, :3])
        # 6 concurrent 1-row requests landed in far fewer batches than 6
        assert fd.n_batches < 6
        assert fd.n_accepted == fd.n_answered == 6
    finally:
        fd.shutdown()


def test_shedding_and_retry():
    # slow service + tiny queue: a burst must shed, and the client's
    # typed retries (honoring retry_after_ms) eventually clear it
    srv = _fake_server(d=4, micro_batch=2, service_s=0.05)
    fd = _front(srv, max_queue=4, shed_watermark=0.75, max_wait_s=1e-4)
    try:
        no_retry = SearchClient("127.0.0.1", fd.port, max_retries=0)
        retry = SearchClient("127.0.0.1", fd.port, max_retries=12,
                             backoff_base_s=0.03)
        q = np.zeros((1, 4), np.float32)
        results = [None] * 10
        clients = [no_retry] * 5 + [retry] * 5

        def fire(i):
            results[i] = clients[i].search(q)

        ts = [threading.Thread(target=fire, args=(i,)) for i in range(10)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(20)
        shed = [r for r in results if r.status == tp.STATUS_SHED]
        assert fd.n_shed > 0, "burst never hit the watermark"
        for r in shed:                       # typed + hinted
            assert r.retry_after_ms is not None and r.retry_after_ms > 0
        # every retry-enabled client got an answer
        assert all(r.ok for r in results[5:])
        assert fd.n_accepted == fd.n_answered
    finally:
        fd.shutdown()


def test_multi_tenant_round_robin_and_quota():
    a, b = _fake_server(d=4, service_s=0.01), _fake_server(d=6,
                                                           service_s=0.01)
    fd = SearchFrontDoor(max_queue=64, max_wait_s=1e-3)
    fd.register("alpha", a)
    fd.register("beta", b, quota=2)
    fd.start()
    try:
        client = SearchClient("127.0.0.1", fd.port, max_retries=0)
        pong = client.ping()
        assert set(pong["tenants"]) == {"alpha", "beta"}
        assert pong["tenants"]["beta"]["d"] == 6
        outcomes = []

        def fire(tenant, d):
            outcomes.append((tenant, client.search(
                np.zeros((1, d), np.float32), tenant=tenant)))

        ts = [threading.Thread(target=fire, args=("alpha", 4))
              for _ in range(6)]
        ts += [threading.Thread(target=fire, args=("beta", 6))
               for _ in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(15)
        stats = fd.stats()
        # both tenants were served (round-robin, no starvation)...
        assert stats.per_tenant["alpha"]["answered"] > 0
        assert stats.per_tenant["beta"]["answered"] > 0
        # ...and beta's quota of 2 queued rows shed part of its burst
        assert stats.per_tenant["beta"]["shed"] > 0
        ok_beta = [r for t, r in outcomes
                   if t == "beta" and r.status == tp.STATUS_OK]
        assert all(r.dists.shape == (1, 3) for r in ok_beta)
    finally:
        fd.shutdown()


# ---------------------------------------------------------------------------
# deadline propagation (satellite: remaining-budget arithmetic)
# ---------------------------------------------------------------------------


def test_serve_stream_remaining_budget_clamps_not_stalls():
    """Queueing eats the per-query budget: later batches of a backlogged
    stream get a strictly smaller `deadline_s`, clamped at 0.0 — and the
    already-expired batch still dispatches (answers, never stalls)."""
    srv = _fake_server(d=4, micro_batch=4, service_s=0.02,
                       out_of_core=True)
    srv.deadline_s = 0.01
    q = np.zeros((12, 4), np.float32)
    stats = srv.serve_stream(q, np.zeros(12), max_wait_s=1e-3)
    assert isinstance(stats, ServeStats) and stats.n_queries == 12
    budgets = [c["deadline_s"] for c in srv._fake_calls]
    assert len(budgets) == 3
    assert budgets[0] == pytest.approx(0.01)
    # service (~20ms) exceeds the 10ms budget: every later batch is
    # fully expired at dispatch and clamps to exactly 0.0
    assert budgets[1] == 0.0 and budgets[2] == 0.0
    assert all(b0 >= b1 for b0, b1 in zip(budgets, budgets[1:]))


def test_deadline_expired_answers_degraded(world):
    store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    srv = SearchServer(view, micro_batch=8, deadline_s=1e-6, **SEARCH_KW)
    stats = srv.serve_stream(q[:8], np.zeros(8), max_wait_s=1e-4)
    # an exhausted budget folds nothing: degraded coverage, no stall
    assert stats.n_queries == 8
    assert stats.degraded_queries == 8
    assert stats.mean_coverage < 1.0


def test_socket_deadline_propagates_arrival_origin():
    srv = _fake_server(d=4, out_of_core=True)
    fd = _front(srv, max_wait_s=1e-3)
    try:
        client = SearchClient("127.0.0.1", fd.port)
        res = client.search(np.zeros((2, 4), np.float32), deadline_ms=250)
        assert res.ok
        (call,) = srv._fake_calls
        assert call["deadline_s"] == pytest.approx(0.25)
        # budget origin = the request's admission timestamp, in the
        # perf_counter clock, strictly before "now"
        assert call["t_start_s"] <= time.perf_counter()
    finally:
        fd.shutdown()


def test_deadline_requests_form_solo_batches():
    """`formed_rows` boundaries: a deadline-carrying request is never
    co-batched (its budget must not eject shards for neighbors that
    asked for none) — solo immediately-full batch at the head, batch
    boundary when queued behind no-deadline requests."""
    srv = _fake_server(d=4, micro_batch=8, out_of_core=True)
    t = _Tenant("t", srv, 64)

    def mk(n, dl):
        return _PendingRequest(None, 0, np.zeros((n, 4), np.float32),
                               0.0, dl)

    t.pending.extend([mk(2, None), mk(1, None), mk(1, 0.5), mk(3, None)])
    assert t.formed_rows(8) == (3, True)    # closes at the deadline req
    t.pending.popleft()
    t.pending.popleft()
    assert t.formed_rows(8) == (1, True)    # deadline head: solo + full
    t.pending.popleft()
    assert t.formed_rows(8) == (3, False)   # plain tail: normal fill wait


def test_socket_deadline_never_degrades_cobatched_neighbor():
    """A no-deadline request concurrent with a deadline-carrying one
    must reach `search_batch` in its own batch with NO deadline — the
    old tightest-deadline-of-the-batch rule answered it degraded for a
    budget it never asked for."""
    srv = _fake_server(d=4, micro_batch=2, out_of_core=True)
    fd = _front(srv, max_wait_s=0.25)
    try:
        client = SearchClient("127.0.0.1", fd.port)
        q = np.zeros((1, 4), np.float32)
        results = [None, None]
        ts = [threading.Thread(target=lambda: results.__setitem__(
                  0, client.search(q))),
              threading.Thread(target=lambda: results.__setitem__(
                  1, client.search(q, deadline_ms=200)))]
        for th in ts:
            th.start()
        for th in ts:
            th.join(10)
        assert all(r is not None and r.ok for r in results)
        calls = srv._fake_calls
        assert len(calls) == 2 and all(c["n"] == 1 for c in calls)
        dl_calls = [c for c in calls if "deadline_s" in c]
        assert len(dl_calls) == 1
        assert dl_calls[0]["deadline_s"] == pytest.approx(0.2)
        assert fd.n_batches == 2
    finally:
        fd.shutdown()


def test_serve_stream_empty_is_zeroed(resident_server):
    # regression: arrival_s[0] IndexError on an empty stream
    stats = resident_server.serve_stream(
        np.zeros((0, 16), np.float32), np.zeros(0))
    assert stats.n_queries == 0 and stats.n_batches == 0
    assert stats.p50_ms == 0.0 and stats.qps == 0.0


# ---------------------------------------------------------------------------
# graceful drain + health probes
# ---------------------------------------------------------------------------


def test_graceful_drain_answers_everything_once():
    srv = _fake_server(d=4, micro_batch=2, service_s=0.03)
    fd = _front(srv, max_queue=64, max_wait_s=1e-3)
    from repro import obs
    ms = obs.start_metrics_server(0)
    fd.attach_health(ms)
    try:
        assert urllib.request.urlopen(
            f"{ms.url}/healthz", timeout=5).status == 200
        assert urllib.request.urlopen(
            f"{ms.url}/readyz", timeout=5).status == 200
        client = SearchClient("127.0.0.1", fd.port, max_retries=0)
        q = np.zeros((1, 4), np.float32)
        results = [None] * 8
        ts = [threading.Thread(
            target=lambda i=i: results.__setitem__(i, client.search(q)))
            for i in range(8)]
        for t in ts:
            t.start()
        while fd.n_accepted < 4:                    # backlog exists
            time.sleep(0.001)
        clean = fd.shutdown()
        for t in ts:
            t.join(15)
        assert clean and fd.stats().drained_clean
        # exactly once: every accepted query answered, none left queued
        assert fd.n_accepted == fd.n_answered > 0
        assert fd._queued_total == 0
        # every accepted query's client actually received its answer;
        # requests racing the final socket close may see the connection
        # drop (TRANSPORT_ERROR) — those were never admitted
        assert sum(1 for r in results
                   if r is not None and r.ok) == fd.n_accepted
        statuses = {r.status for r in results if r is not None}
        assert statuses <= {tp.STATUS_OK, tp.STATUS_UNAVAILABLE,
                            "TRANSPORT_ERROR"}
        # readiness flipped; liveness stayed up
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{ms.url}/readyz", timeout=5)
        assert ei.value.code == 503
        assert urllib.request.urlopen(
            f"{ms.url}/healthz", timeout=5).status == 200
        # the listener is gone: new connections are refused
        with pytest.raises(OSError):
            socket.create_connection(("127.0.0.1", fd.port), timeout=1)
    finally:
        ms.close()
        fd.shutdown()


# ---------------------------------------------------------------------------
# chaos: the four network fault kinds, exactly-once answering
# ---------------------------------------------------------------------------


def _seed_where(pred, lo=0, hi=2000):
    for seed in range(lo, hi):
        if pred(seed):
            return seed
    raise AssertionError("no seed found")


def test_chaos_conn_drop_retried_not_duplicated():
    # a seed where request key 0 drops on attempt 0 and passes attempt 1
    seed = _seed_where(lambda s: (
        FaultPlan(s, p_conn_drop=0.5).would_conn_drop(0, 0)
        and not FaultPlan(s, p_conn_drop=0.5).would_conn_drop(0, 1)))
    srv = _fake_server(d=4)
    fd = _front(srv)
    try:
        fp = FaultPlan(seed, p_conn_drop=0.5)
        client = SearchClient("127.0.0.1", fd.port, faults=fp,
                              max_retries=3, backoff_base_s=1e-3)
        res = client.search(np.zeros((1, 4), np.float32), req_key=0)
        assert res.ok and res.retries == 1
        assert fp.injected.get("conn_drop") == 1
        # the dropped attempt was never admitted: answered exactly once
        assert fd.n_accepted == fd.n_answered == 1
        assert len(srv._fake_calls) == 1
    finally:
        fd.shutdown()


def test_chaos_slow_write_and_malformed_still_served():
    srv = _fake_server(d=4)
    fd = _front(srv)
    try:
        fp = FaultPlan(0, p_slow_write=1.0, slow_write_chunk=8,
                       slow_write_s=1e-4, p_malformed=1.0)
        client = SearchClient("127.0.0.1", fd.port, faults=fp)
        res = client.search(np.arange(4, dtype=np.float32), req_key="k")
        assert res.ok and res.retries == 0
        assert fp.injected.get("malformed") == 1
        assert fp.injected.get("slow_write", 0) >= 1
        assert fd.n_accepted == fd.n_answered == 1
    finally:
        fd.shutdown()


def test_chaos_client_vanish_answered_exactly_once():
    srv = _fake_server(d=4)
    fd = _front(srv)
    try:
        fp = FaultPlan(0, p_client_vanish=1.0)
        client = SearchClient("127.0.0.1", fd.port, faults=fp,
                              max_retries=3)
        res = client.search(np.zeros((1, 4), np.float32), req_key="v")
        # the request WAS admitted; the client must not retry it
        assert res.status == STATUS_VANISHED and res.retries == 0
        deadline = time.perf_counter() + 5
        while fd.n_answered < 1 and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert fd.n_accepted == fd.n_answered == 1
        assert len(srv._fake_calls) == 1
    finally:
        fd.shutdown()


# ---------------------------------------------------------------------------
# load loops + CLI satellites
# ---------------------------------------------------------------------------


def test_open_and_closed_loops():
    srv = _fake_server(d=4, service_s=0.002)
    fd = _front(srv, max_queue=128)
    try:
        client = SearchClient("127.0.0.1", fd.port, max_retries=4,
                              backoff_base_s=5e-3)
        q = np.zeros((24, 4), np.float32)
        closed = run_closed_loop(client, q, batch=2)
        assert closed.mode == "closed" and closed.n_ok == 12
        assert closed.n_failed == 0 and closed.achieved_qps > 0
        opened = run_open_loop(client, q, 800.0, batch=2, seed=3)
        assert opened.mode == "open" and opened.n_requests == 12
        assert opened.n_ok + opened.n_failed == 12
        assert opened.offered_qps == 1600.0        # rows/s: 800 req/s x 2
    finally:
        fd.shutdown()


def test_ooc_flags_require_out_of_core(capsys):
    from repro.launch import serve_search
    for flags in (["--chaos", "p_corrupt=1"],
                  ["--deadline-ms", "5"],
                  ["--on-shard-error", "skip"],
                  ["--no-verify"]):
        with pytest.raises(SystemExit) as ei:
            serve_search.main(["--store", "/nonexistent"] + flags)
        assert ei.value.code == 2
        assert "--out-of-core" in capsys.readouterr().err
    # the flags stay accepted WITH --out-of-core (the failure is now the
    # missing store, not an argparse exit)
    with pytest.raises(Exception):
        serve_search.main(["--store", "/nonexistent", "--out-of-core",
                           "--no-verify"])
