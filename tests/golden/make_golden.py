"""Regenerate the golden outputs for the f_theta-dispatch parity suite.

The .npz captured here was produced by the PRE-refactor code (direct-jnp
`qinco.f_apply` step network, PR 2 tree) and is the fixed point the
`ops.f_theta` refactor must reproduce bit-for-bit on the xla backend:

    PYTHONPATH=src python tests/golden/make_golden.py

Only rerun this against a tree whose encode/decode/search outputs are
already known-good — regenerating from a broken tree would just bake the
breakage into the contract.
"""
import pathlib
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from conftest import clustered  # noqa: E402

from repro.configs.qinco2 import tiny  # noqa: E402
from repro.core import encode as enc  # noqa: E402
from repro.core import qinco, search, training  # noqa: E402


def capture():
    out = {}
    rng = np.random.default_rng(0)

    # -- qinco2-shaped (de != d, projections) -------------------------------
    x = clustered(rng, 192, 16)
    cfg = tiny(epochs=1)  # d=16 de=24 dh=32 L=1 M=4 K=16
    params = training.init_qinco2(jax.random.key(1), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4)
    out["q2_x"] = x
    out["q2_codes"] = np.asarray(codes)
    out["q2_xhat"] = np.asarray(xhat)
    out["q2_recon"] = np.asarray(qinco.decode(params, codes, cfg))

    # -- qinco1 mode (identity projections, greedy A=K B=1) -----------------
    x1 = clustered(rng, 128, 8)
    cfg1 = tiny(d=8, de=8, dh=16, M=3, K=8, qinco1_mode=True)
    params1 = training.init_qinco2(jax.random.key(2), x1, cfg1)
    codes1, xhat1, _ = enc.encode(params1, jnp.asarray(x1), cfg1, cfg1.K, 1)
    out["q1_x"] = x1
    out["q1_codes"] = np.asarray(codes1)
    out["q1_xhat"] = np.asarray(xhat1)
    out["q1_recon"] = np.asarray(qinco.decode(params1, codes1, cfg1))

    # -- L_s >= 1 pre-selector ----------------------------------------------
    xs = clustered(rng, 96, 12)
    cfgs = tiny(d=12, de=16, dh=16, M=3, K=16, Ls=1)
    paramss = training.init_qinco2(jax.random.key(3), xs, cfgs)
    codess, xhats, _ = enc.encode(paramss, jnp.asarray(xs), cfgs, 4, 4)
    out["ls_x"] = xs
    out["ls_codes"] = np.asarray(codess)
    out["ls_xhat"] = np.asarray(xhats)

    # -- end-to-end search cascade ------------------------------------------
    xb = clustered(rng, 400, 16)
    idx = search.build_index(jax.random.key(4), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4)
    q = jnp.asarray(xb[:7] + 0.01)
    ids, dists = search.search(idx, q, n_probe=4, n_short_aq=16,
                               n_short_pw=8, topk=3, cfg=cfg)
    out["srch_xb"] = xb
    out["srch_ids"] = np.asarray(ids)
    out["srch_dists"] = np.asarray(dists)
    return out


if __name__ == "__main__":
    dst = pathlib.Path(__file__).with_name("qinco_golden.npz")
    np.savez_compressed(dst, **capture())
    print(f"wrote {dst} ({dst.stat().st_size} bytes)")
