"""The `repro.index` subsystem contract:

- packed uint8 codes are the stored and kernel-consumed representation,
  bit-identical to int32 through every backend and the full cascade;
- `build_ivf` never drops vectors on bucket overflow (spill regression);
- `IndexStore.save -> load` round-trips `SearchIndex` bit-identically;
- an interrupted `StreamingIndexBuilder` run resumes from its shard
  cursor and produces the same index as an uninterrupted run;
- `SearchServer` micro-batched serving returns the direct-search results.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import ivf, search, training
from repro.index import IndexStore, PackedCodes, StreamingIndexBuilder
from repro.index import codes as pcodes
from repro.kernels import ops

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)


@pytest.fixture(scope="module")
def world():
    """Small clustered database + untrained (init-only) QINCo2 params —
    parity and round-trip properties hold regardless of training."""
    rng = np.random.default_rng(11)
    xb = clustered(rng, 1100, 16, k=16)       # non-tile-multiple N
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    q = jnp.asarray(xb[:13] + 0.02)
    return xb, cfg, params, idx, q


# ---------------------------------------------------------------------------
# packed codes
# ---------------------------------------------------------------------------


def test_build_index_packs_codes(world):
    _, cfg, _, idx, _ = world
    assert idx.codes.dtype == jnp.uint8
    assert idx.codes.shape[1] == cfg.M        # 1 byte/step on the wire


def test_packed_codes_container():
    rng = np.random.default_rng(0)
    c = PackedCodes.pack(rng.integers(0, 200, size=(10, 8)), 256)
    assert c.nbytes == 80 and c.bytes_per_vector == 8 and len(c) == 10
    assert c[2:5].shape == (3, 8)
    np.testing.assert_array_equal(c.unpack(), c.codes.astype(np.int32))
    with pytest.raises(ValueError):
        pcodes.pack_codes(np.zeros((2, 2), np.int32), 512)
    with pytest.raises(ValueError):
        PackedCodes(np.zeros((2, 2), np.int32), 16)   # not packed dtype


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_packed_search_topk_identical(world, backend):
    """uint8 vs int32 codes -> bit-identical search() top-k, on a
    non-tile-multiple N, under both dispatch backends."""
    _, cfg, _, idx, q = world
    idx32 = dataclasses.replace(idx, codes=idx.codes.astype(jnp.int32))
    i8, s8 = search.search(idx, q, cfg=cfg, backend=backend, **SEARCH_KW)
    i32, s32 = search.search(idx32, q, cfg=cfg, backend=backend, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i8), np.asarray(i32))
    np.testing.assert_array_equal(np.asarray(s8), np.asarray(s32))


@pytest.mark.parametrize("backend", ["xla", "pallas", "xla_onehot"])
def test_adc_scores_uint8_parity_shared(backend):
    rng = np.random.default_rng(5)
    codes = jnp.asarray(rng.integers(0, 16, size=(37, 4)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(5, 4, 16)).astype(np.float32))
    norms = jnp.asarray((rng.normal(size=(37,)) ** 2).astype(np.float32))
    s8 = ops.adc_scores(codes, lut, norms=norms, backend=backend,
                        tile_q=4, tile_n=16)
    s32 = ops.adc_scores(codes.astype(jnp.int32), lut, norms=norms,
                         backend=backend, tile_q=4, tile_n=16)
    np.testing.assert_array_equal(np.asarray(s8), np.asarray(s32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_adc_scores_uint8_parity_batched(backend):
    rng = np.random.default_rng(6)
    codes = jnp.asarray(rng.integers(0, 16, size=(5, 21, 4)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(5, 4, 16)).astype(np.float32))
    s8 = ops.adc_scores(codes, lut, backend=backend, tile_q=4, tile_n=16)
    s32 = ops.adc_scores(codes.astype(jnp.int32), lut, backend=backend,
                         tile_q=4, tile_n=16)
    np.testing.assert_array_equal(np.asarray(s8), np.asarray(s32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_pairwise_uint8_no_byte_overflow(backend):
    """K=32 buckets reach 32*31+31 > 255: the widen-before-multiply in
    `pairwise_buckets` is what keeps uint8 codes correct."""
    rng = np.random.default_rng(7)
    K = 32
    codes = jnp.asarray(rng.integers(0, K, size=(41, 5)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(3, 2, K * K)).astype(np.float32))
    pairs = ((0, 3), (1, 4))
    s8 = ops.pairwise_scores(codes, lut, pairs, K, backend=backend,
                             tile_q=2, tile_n=16)
    s32 = ops.pairwise_scores(codes.astype(jnp.int32), lut, pairs, K,
                              backend=backend, tile_q=2, tile_n=16)
    np.testing.assert_array_equal(np.asarray(s8), np.asarray(s32))


# ---------------------------------------------------------------------------
# IVF overflow spill (regression: vectors used to become unsearchable)
# ---------------------------------------------------------------------------


def test_build_ivf_spills_instead_of_dropping():
    """Skewed assignment (one tight cluster, cap_factor=1) overflows the
    favorite bucket; every vector must still land in exactly one bucket."""
    rng = np.random.default_rng(3)
    n = 200
    x = (rng.normal(size=(n, 8)) * 0.01 + 1.0).astype(np.float32)
    idx = ivf.build_ivf(jax.random.key(0), jnp.asarray(x), 8, cap_factor=1.0)
    mask = np.asarray(idx.bucket_mask)
    assert mask.sum() == n                       # nothing dropped
    ids = np.sort(np.asarray(idx.buckets)[mask])
    np.testing.assert_array_equal(ids, np.arange(n))
    # assignments agree with the bucket a vector actually lives in
    assign = np.asarray(idx.assignments)
    for i in (0, 57, n - 1):
        row = np.asarray(idx.buckets)[assign[i]][mask[assign[i]]]
        assert i in row
    # capacity respected everywhere
    assert mask.sum(axis=1).max() <= idx.buckets.shape[1]


def test_assign_with_spill_streaming_fill_continues():
    """Passing running fill counts across calls == one big call."""
    rng = np.random.default_rng(4)
    x = (rng.normal(size=(60, 4)) * 0.01).astype(np.float32)
    cent = rng.normal(size=(4, 4)).astype(np.float32)
    cent[0] = 0.0                                # everyone's favorite
    raw = np.zeros(60, np.int32)
    a_all, f_all = ivf.assign_with_spill(x, cent, raw, cap=20)
    a1, f1 = ivf.assign_with_spill(x[:30], cent, raw[:30], cap=20)
    a2, f2 = ivf.assign_with_spill(x[30:], cent, raw[30:], cap=20, fill=f1)
    np.testing.assert_array_equal(a_all, np.concatenate([a1, a2]))
    np.testing.assert_array_equal(f_all, f2)


def _spill_reference(xb, centroids, assign, cap, fill=None):
    """The naive sequential loop `assign_with_spill` must match exactly."""
    assign = np.asarray(assign).astype(np.int32).copy()
    fill = (np.zeros(len(centroids), np.int64) if fill is None
            else np.asarray(fill, np.int64).copy())
    for i in range(len(assign)):
        b = assign[i]
        if fill[b] >= cap:
            d2 = np.sum((xb[i] - centroids) ** 2, axis=-1)
            b = next(int(nb) for nb in np.argsort(d2, kind="stable")
                     if fill[nb] < cap)
            assign[i] = b
        fill[b] += 1
    return assign, fill


@pytest.mark.parametrize("seed,skew", [(0, 0.9), (1, 0.5), (2, 0.99)])
def test_assign_with_spill_matches_naive_reference(seed, skew):
    """The risky-rows-only walk == the naive per-row loop, including
    cascading spills (spilled rows filling up secondary buckets)."""
    rng = np.random.default_rng(seed)
    n, k, cap = 200, 6, 50
    x = rng.normal(size=(n, 4)).astype(np.float32)
    cent = rng.normal(size=(k, 4)).astype(np.float32)
    raw = np.where(rng.random(n) < skew, 0,
                   rng.integers(0, k, n)).astype(np.int32)
    fill0 = rng.integers(0, 10, k).astype(np.int64)   # fits k*cap total
    a_ref, f_ref = _spill_reference(x, cent, raw, cap, fill0)
    a_new, f_new = ivf.assign_with_spill(x, cent, raw, cap, fill0)
    np.testing.assert_array_equal(a_ref, a_new)
    np.testing.assert_array_equal(f_ref, f_new)


def test_buckets_from_assignments_matches_build():
    rng = np.random.default_rng(5)
    x = clustered(rng, 300, 8, k=8)
    idx = ivf.build_ivf(jax.random.key(1), jnp.asarray(x), 8)
    b, m = ivf.buckets_from_assignments(np.asarray(idx.assignments), 8,
                                        idx.buckets.shape[1])
    np.testing.assert_array_equal(b, np.asarray(idx.buckets))
    np.testing.assert_array_equal(m, np.asarray(idx.bucket_mask))


# ---------------------------------------------------------------------------
# store round trip
# ---------------------------------------------------------------------------


def test_store_roundtrip_bit_identical(world, tmp_path):
    _, cfg, _, idx, q = world
    store = IndexStore.save(tmp_path / "idx", idx, shard_size=400)
    assert store.manifest["complete"]
    loaded = store.load()
    assert loaded.codes.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(loaded.codes),
                                  np.asarray(idx.codes))
    np.testing.assert_array_equal(np.asarray(loaded.ivf.buckets),
                                  np.asarray(idx.ivf.buckets))
    i1, s1 = search.search(idx, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search(loaded, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_store_refuses_incomplete_and_wrong_version(world, tmp_path):
    _, cfg, _, idx, _ = world
    store = IndexStore.save(tmp_path / "idx", idx, shard_size=400)
    import json
    m = json.loads(store.manifest_path.read_text())
    m["complete"] = False
    store.manifest_path.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="incomplete"):
        IndexStore(tmp_path / "idx").load()
    assert IndexStore(tmp_path / "idx").load(allow_partial=True) is not None
    m["format_version"] = 99
    store.manifest_path.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="format_version"):
        IndexStore(tmp_path / "idx").load()


def test_store_mmap_shard_views(world, tmp_path):
    """open_shard returns mmap views with the exact stored bytes."""
    _, cfg, _, idx, _ = world
    store = IndexStore.save(tmp_path / "idx", idx, shard_size=400)
    sh0 = store.open_shard(0)
    assert isinstance(sh0["codes"], np.memmap)
    np.testing.assert_array_equal(np.asarray(sh0["codes"]),
                                  np.asarray(idx.codes[:400]))
    assert store.shard_rows(store.manifest["n_shards"] - 1) == 1100 - 2 * 400
    assert store.bytes_per_vector() > cfg.M    # codes + norms + overhead


def test_checkpoint_restore_flat(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    tree = {"b": np.arange(6, dtype=np.float32).reshape(2, 3),
            "a": {"x": np.ones(4, np.int32)}}
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(0, tree, extra={"tag": 1})
    leaves, extra = mgr.restore_flat(0)
    assert extra == {"tag": 1}
    # flat order is jax order (dict keys sorted): a/x then b
    np.testing.assert_array_equal(leaves[0], tree["a"]["x"])
    np.testing.assert_array_equal(leaves[1], tree["b"])


def test_treespec_roundtrip():
    from repro.index.store import tree_spec, tree_unflatten_spec
    tree = {"p": {"w": np.ones(2), "b": np.zeros(3)}, "none": None,
            "seq": [np.arange(2), np.arange(3)]}
    leaves, _ = jax.tree.flatten(tree)
    rebuilt = tree_unflatten_spec(tree_spec(tree), leaves)
    assert rebuilt["none"] is None
    np.testing.assert_array_equal(rebuilt["p"]["w"], tree["p"]["w"])
    np.testing.assert_array_equal(rebuilt["seq"][1], tree["seq"][1])


# ---------------------------------------------------------------------------
# streaming builder: resume == uninterrupted
# ---------------------------------------------------------------------------


def _make_builder(path, xb, params, cfg):
    b = StreamingIndexBuilder(path, shard_size=300, encode_chunk=256)
    b.prepare(jax.random.key(3), xb, params, cfg, n_total=len(xb),
              k_ivf=8, m_tilde=2, n_pair_books=4)
    return b


def test_builder_interrupted_resume_matches_uninterrupted(world, tmp_path):
    xb, cfg, params, _, q = world
    # run A: killed after 2 of 4 shards, then resumed by a fresh builder
    a = _make_builder(tmp_path / "a", xb, params, cfg)
    assert not a.build(xb, max_shards=2)
    assert not IndexStore(tmp_path / "a").manifest["complete"]
    a2 = _make_builder(tmp_path / "a", xb, params, cfg)   # fresh "process"
    assert a2.build(xb)
    # run B: uninterrupted
    b = _make_builder(tmp_path / "b", xb, params, cfg)
    assert b.build(xb)
    ia = IndexStore(tmp_path / "a").load()
    ib = IndexStore(tmp_path / "b").load()
    np.testing.assert_array_equal(np.asarray(ia.codes), np.asarray(ib.codes))
    np.testing.assert_array_equal(np.asarray(ia.ivf.assignments),
                                  np.asarray(ib.ivf.assignments))
    i1, s1 = search.search(ia, q, cfg=cfg, **SEARCH_KW)
    i2, s2 = search.search(ib, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_builder_rejects_unpackable_alphabet_early(tmp_path):
    """K > 256 must fail in milliseconds (before the fit phase), not at
    the first shard write hours later."""
    cfg = tiny(K=512)
    b = StreamingIndexBuilder(tmp_path / "k")
    with pytest.raises(ValueError, match="256"):
        b.prepare(jax.random.key(0), np.zeros((4, 16), np.float32), {},
                  cfg, n_total=4)


def test_partial_store_loads_completed_prefix(world, tmp_path):
    """allow_partial on a genuinely half-built store: the completed shard
    prefix loads and is searchable (regression: used to FileNotFoundError
    on the first missing shard)."""
    xb, cfg, params, _, _ = world
    a = _make_builder(tmp_path / "a", xb, params, cfg)
    assert not a.build(xb, max_shards=2)
    partial = IndexStore(tmp_path / "a").load(allow_partial=True)
    assert partial.codes.shape[0] == 600               # 2 shards x 300
    q = jnp.asarray(xb[:5] + 0.02)
    ids, _ = search.search(partial, q, cfg=cfg, **SEARCH_KW)
    assert np.asarray(ids).max() < 600                 # prefix ids only


def test_builder_m_tilde_zero_end_to_end(world, tmp_path):
    """m_tilde=0 (no centroid RQ codes) must survive build -> load ->
    search (regression: search() crashed on None centroid_codes)."""
    xb, cfg, params, _, q = world
    b = StreamingIndexBuilder(tmp_path / "z", shard_size=600,
                              encode_chunk=256)
    b.prepare(jax.random.key(5), xb, params, cfg, n_total=len(xb),
              k_ivf=8, m_tilde=0, n_pair_books=4)
    assert b.build(xb)
    idx0 = IndexStore(tmp_path / "z").load()
    assert idx0.ivf.centroid_codes is None
    assert idx0.ext_codes.shape[1] == cfg.M            # degrades to codes
    ids, dists = search.search(idx0, q, cfg=cfg, **SEARCH_KW)
    assert np.isfinite(np.asarray(dists)).all()


def test_builder_refuses_resume_on_different_database(world, tmp_path):
    """Resuming a half-built store against a different same-length dataset
    must fail instead of finalizing a mixed-content index."""
    xb, cfg, params, _, _ = world
    a = _make_builder(tmp_path / "a", xb, params, cfg)
    assert not a.build(xb, max_shards=1)
    other = np.asarray(xb) + 1.0                       # same shape, new data
    a2 = _make_builder(tmp_path / "a", xb, params, cfg)
    with pytest.raises(ValueError, match="different dataset"):
        a2.build(other)
    assert _make_builder(tmp_path / "a", xb, params, cfg).build(xb)


def test_builder_resume_survives_stale_cursor(world, tmp_path):
    """Killed between shard rename and cursor write: fill counts are
    rebuilt from the on-disk shards (disk is ground truth)."""
    xb, cfg, params, _, _ = world
    a = _make_builder(tmp_path / "a", xb, params, cfg)
    assert not a.build(xb, max_shards=2)
    IndexStore(tmp_path / "a").cursor_path.unlink()       # lose the cursor
    a2 = _make_builder(tmp_path / "a", xb, params, cfg)
    assert a2.build(xb)
    b = _make_builder(tmp_path / "b", xb, params, cfg)
    assert b.build(xb)
    ia = IndexStore(tmp_path / "a").load()
    ib = IndexStore(tmp_path / "b").load()
    np.testing.assert_array_equal(np.asarray(ia.codes), np.asarray(ib.codes))
    np.testing.assert_array_equal(np.asarray(ia.aq_norms),
                                  np.asarray(ib.aq_norms))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_search_server_matches_direct_search(world, tmp_path):
    from repro.launch.serve_search import SearchServer, synthetic_stream
    _, cfg, _, idx, q = world
    srv = SearchServer(idx, micro_batch=8, topk=3, n_probe=4,
                       n_short_aq=16, n_short_pw=8)
    ids, dists = srv.search_batch(np.asarray(q)[:5])      # partial batch
    ref_q = jnp.concatenate([q[:5], jnp.zeros((3, q.shape[1]))])
    ref_ids, ref_d = search.search(idx, ref_q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids)[:5])
    np.testing.assert_array_equal(dists, np.asarray(ref_d)[:5])
    stats = srv.serve_stream(*synthetic_stream(idx, 24, 2000.0))
    assert stats.n_queries == 24 and stats.n_batches >= 3
    assert stats.p99_ms >= stats.p50_ms > 0
    assert 0 < stats.mean_batch_occupancy <= 1
