"""AOT dry-run on a small placeholder mesh (subprocess: device-count env
must be set before jax initializes). Covers every family x kind on the
test mesh, single- and multi-pod."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow      # subprocess suite; skip via -m "not slow"

ROOT = Path(__file__).resolve().parent.parent
SRC = str(ROOT / "src")

CASES = [
    ("deepseek-coder-33b", "train_4k", "1"),
    ("deepseek-coder-33b", "decode_32k", "2"),
    ("dbrx-132b", "train_4k", "2"),
    ("mamba2-1.3b", "long_500k", "1"),
    ("zamba2-1.2b", "decode_32k", "1"),
    ("whisper-tiny", "prefill_32k", "1"),
]


@pytest.mark.parametrize("arch,shape,pods", CASES)
def test_dryrun_cell_on_test_mesh(arch, shape, pods, tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--pods", pods, "--mesh", "test",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    recs = list(tmp_path.glob("*.json"))
    assert recs, out.stdout
    rec = json.loads(recs[0].read_text())
    assert rec.get("error") is None, rec.get("error")
    assert rec["cost"].get("flops", 0) > 0
    assert rec["analytic"]["flops"] > 0
    assert rec["t_compute_s"] >= 0


def test_dryrun_records_collectives(tmp_path):
    """A TP+FSDP train cell on >1 device must show collectives."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen2.5-32b", "--shape", "train_4k", "--pods", "1", "--mesh",
         "test", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    rec = json.loads(next(tmp_path.glob("*.json")).read_text())
    assert rec["collective_wire_bytes"] > 0
    assert "all-reduce" in rec["collectives"] or \
        "reduce-scatter" in rec["collectives"]


def test_compressed_psum_two_pods_matches_exact(tmp_path):
    """Full-manual shard_map int8 exchange == fp32 psum (2 real devices)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax, jax.numpy as jnp
from repro.core import grad_compress as gc
mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(0)
g = {"w": jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32)),
     "b": jnp.asarray(rng.normal(size=(77,)).astype(np.float32))}
from jax.sharding import NamedSharding, PartitionSpec as P
gs = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P("pod"))), 
                  {"w": jnp.tile(g["w"], (2, 1)).reshape(2, 128, 16),
                   "b": jnp.tile(g["b"], 2).reshape(2, 77)})
# per-pod distinct grads: pod i gets g * (i+1)
per_pod = jax.tree.map(lambda a: a * jnp.arange(1, 3, dtype=a.dtype).reshape(
    (2,) + (1,) * (a.ndim - 1)), gs)
def strip(t):  # shard over pod then drop the leading axis inside shard_map
    return jax.tree.map(lambda a: a, t)
def inner(t):
    t = jax.tree.map(lambda a: a[0], t)
    out = {}
    for k, x in t.items():
        q, s = gc.quantize_int8(x)
        qg = jax.lax.all_gather(q, "pod")
        sg = jax.lax.all_gather(s, "pod")
        deq = jax.vmap(lambda qq, ss: gc.dequantize_int8(qq, ss, x.shape))(qg, sg)
        out[k] = jnp.sum(deq, 0)
    return out
from repro.parallel import compat
with compat.use_mesh(mesh):
    specs = jax.tree.map(lambda _: P("pod"), per_pod)
    out = compat.shard_map(inner, mesh=mesh, in_specs=(specs,),
                           out_specs=jax.tree.map(lambda _: P(), per_pod),
                           check_vma=False)(per_pod)
exact = jax.tree.map(lambda a: a * 3.0, g)   # 1x + 2x
for k in g:
    err = np.abs(np.asarray(out[k]) - np.asarray(exact[k]))
    rel = err.max() / (np.abs(np.asarray(exact[k])).max() + 1e-9)
    assert rel < 2e-2, (k, rel)
print("OK")
"""
    import subprocess, sys, os
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0 and "OK" in p.stdout, p.stdout + p.stderr


def test_pipeline_parallel_matches_sequential(tmp_path):
    """GPipe over 4 placeholder devices == sequential layer stack."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.pipeline import pipelined_forward
mesh = jax.make_mesh((4,), ("pod",))
S, M, mb, d = 4, 8, 2, 16
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(S, d, d)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))
body = lambda w, x: jnp.tanh(x @ w)
from repro.parallel import compat
with compat.use_mesh(mesh):
    out = pipelined_forward(body, W, x, mesh=mesh)
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ W[s])
assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
print("OK")
"""
    import subprocess, sys, os
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0 and "OK" in p.stdout, p.stdout + p.stderr


def test_elastic_reshard_restore(tmp_path):
    """A checkpoint saved on one mesh restores onto a different mesh
    (elastic down/up-scaling): 4-device sharded save -> 8-device restore."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

ckdir = sys.argv[1]
# "old mesh": 4 of the 8 devices
old_mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                             ("data", "model"))
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "s": jnp.float32(3.0)}
tree = {"w": jax.device_put(tree["w"],
                            NamedSharding(old_mesh, P("data", "model"))),
        "s": jax.device_put(tree["s"], NamedSharding(old_mesh, P()))}
mgr = CheckpointManager(ckdir)
mgr.save(7, tree, extra={"mesh": "2x2"})

# "new mesh": all 8 devices, different topology
new_mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4, 2),
                             ("data", "model"))
sh = {"w": NamedSharding(new_mesh, P("data", "model")),
      "s": NamedSharding(new_mesh, P())}
step, restored, extra = mgr.restore_latest(tree, shardings=sh)
assert step == 7 and extra["mesh"] == "2x2"
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
assert restored["w"].sharding.mesh.shape["data"] == 4
print("OK")
"""
    import subprocess, sys, os
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    p = subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=600)
    assert p.returncode == 0 and "OK" in p.stdout, p.stdout + p.stderr
