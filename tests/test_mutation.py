"""Live index mutation: delta shards, tombstoned deletes, compaction.

The contract under test (docs/INDEX_FORMAT.md "Mutation"):

- `IndexStore.append` seals delta shards through the builder's encode
  path and assigns contiguous global ids; a live `ShardedIndexView`
  picks them up with `refresh()` (no reopen) and serves them;
- `IndexStore.delete` writes a durable tombstone bitmap; deleted ids
  never surface in search results after a refresh, with coverage intact
  (masking happens inside the fused scan, not by dropping shards);
- a mutated view's search is bit-identical (scores, and ids through the
  survivor mapping) to a view over the compacted store, on both
  backends;
- compaction is byte-identical to `IndexStore.save`'s writer path over
  the survivor arrays, fsck-clean, resumable after a kill, and never
  unlinks — gc runs after the last pinned reader releases;
- concurrent append/delete/query threads never observe a deleted id
  once the delete published before their refresh (snapshot isolation).
"""
import json
import shutil
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import Compactor, IndexStore, ShardedIndexView
from repro.index.codes import PackedCodes
from repro.index.fsck import fsck_store

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)
SHARD_FILES = ("codes.u8", "assign.i32", "aq_norms.f32", "pw_norms.f32",
               "checksums.json")
# survivors of _mutate: avoid row 0 (bucket-table padding ids resolve to
# row 0, so deleting it would surface id 0 through starved shortlists)
DELETED = [5, 10, 600, 1100, 1200]


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Clustered database -> saved store (4 shards) + appendable rows."""
    rng = np.random.default_rng(21)
    xb = clustered(rng, 1100, 16, k=16)
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    xa = clustered(np.random.default_rng(7), 150, 16, k=16)
    q = jnp.asarray(xb[:13] + 0.02)
    return xb, xa, cfg, store_dir, q


def _copy(world, tmp_path, name="m"):
    _, _, _, store_dir, _ = world
    dst = tmp_path / name
    shutil.copytree(store_dir, dst)
    return dst


def _mutate(world, tmp_path):
    """Fresh copy of the base store with 150 appends + 5 deletes."""
    _, xa, _, _, _ = world
    d = _copy(world, tmp_path)
    store = IndexStore(d)
    gids = store.append(xa)
    np.testing.assert_array_equal(gids, np.arange(1100, 1250))
    assert store.delete(DELETED) == len(DELETED)
    return store


# ---------------------------------------------------------------------------
# append / delete / refresh on a live view
# ---------------------------------------------------------------------------


def test_append_is_searchable_after_refresh(world, tmp_path):
    xb, xa, cfg, _, q = world
    d = _copy(world, tmp_path)
    view = ShardedIndexView(d, max_resident_shards=2)
    base_ids = list(view.shard_ids)
    i0, s0 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)

    store = IndexStore(d)
    gids = store.append(xa)
    assert store.mutated and store.total_rows() == 1250
    assert view.n_rows == 1100                   # not visible until refresh
    assert view.refresh() is True
    assert view.refresh() is False               # idempotent
    assert view.n_rows == 1250
    assert view.shard_ids == sorted(base_ids + [-1])   # delta token

    # a query aimed at an appended vector finds its new global id
    qn = jnp.asarray(xa[3:4] + 0.01)
    ia, _ = search.search_sharded(view, qn, cfg=cfg, **SEARCH_KW)
    assert int(gids[3]) in np.asarray(ia)[0]
    # untouched queries: appended rows may only ADD candidates, and the
    # base rows' scores are unchanged — the old top-1 keeps its score
    i1, s1 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    keep = np.asarray(i1)[:, 0] == np.asarray(i0)[:, 0]
    np.testing.assert_array_equal(np.asarray(s1)[keep, 0],
                                  np.asarray(s0)[keep, 0])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_deleted_ids_never_returned(world, tmp_path, backend):
    _, _, cfg, _, q = world
    store = _mutate(world, tmp_path)
    view = ShardedIndexView(store.dir, max_resident_shards=2)
    assert view.n_alive == 1245
    ids, _, cov = search.search_sharded(
        view, q, cfg=cfg, backend=backend, return_coverage=True,
        n_probe=8, n_short_aq=64, n_short_pw=16, topk=10)
    assert not np.isin(np.asarray(ids), DELETED).any()
    np.testing.assert_array_equal(np.asarray(cov), 1.0)  # masked, not skipped


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_mutated_search_matches_compacted(world, tmp_path, backend):
    """Masked gross-rank scan over deltas+tombstones == the scan over the
    compacted store: scores bit-equal, ids equal through the survivor
    mapping. Compaction itself is byte-identical to a fresh write of the
    survivors (test below), so this transitively pins the mutated path
    to 'what a rebuilt store would answer'."""
    _, _, cfg, _, q = world
    store = _mutate(world, tmp_path)
    survivors = np.flatnonzero(~store.tombstone_bits())
    live = ShardedIndexView(store.dir, max_resident_shards=2)

    cdir = tmp_path / "compacted"
    shutil.copytree(store.dir, cdir)
    rep = Compactor(cdir).run()
    assert rep["compacted"] and rep["generation"] == 1
    cview = ShardedIndexView(cdir, max_resident_shards=2)

    kw = dict(cfg=cfg, backend=backend, **SEARCH_KW)
    i1, s1 = search.search_sharded(live, q, **kw)
    i2, s2 = search.search_sharded(cview, q, **kw)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    finite = np.asarray(s2) > -np.inf
    np.testing.assert_array_equal(np.asarray(i1)[finite],
                                  survivors[np.asarray(i2)[finite]])


def test_gather_rows_spans_deltas(world, tmp_path):
    store = _mutate(world, tmp_path)
    view = ShardedIndexView(store.dir)
    codes, assign, pw = view.gather_rows(np.array([[0, 1099, 1100, 1249]]))
    delta = store.open_delta(0)
    np.testing.assert_array_equal(codes[0, 2], delta["codes"][0])
    np.testing.assert_array_equal(codes[0, 3], delta["codes"][149])
    with pytest.raises(ValueError, match="beyond the served rows"):
        view.gather_rows(np.array([[1250]]))


# ---------------------------------------------------------------------------
# compaction: byte identity, kill/resume, gc
# ---------------------------------------------------------------------------


def test_compaction_byte_identical_to_fresh_write(world, tmp_path):
    _, _, cfg, _, _ = world
    store = _mutate(world, tmp_path)
    bits = store.tombstone_bits()
    surv = Compactor(store)._gather_survivors(bits)
    m0 = dict(store.manifest)
    rep = Compactor(store).run()
    assert rep == {"compacted": True, "generation": 1, "n_alive": 1245,
                   "rows_dropped": 5, "shards_written": 5, "shards_total": 5}

    # reference: the same writer path IndexStore.save uses, over the
    # survivor arrays
    ref = IndexStore(tmp_path / "ref")
    ref.initialize(cfg=cfg, global_tree=store.load_global_tree(),
                   n_total=len(surv["assign"]), shard_size=m0["shard_size"],
                   k_ivf=m0["k_ivf"], cap=m0["cap"],
                   pw_pairs=m0["pw_pairs"])
    for sid in range(ref.manifest["n_shards"]):
        lo = sid * m0["shard_size"]
        hi = lo + ref.shard_rows(sid)
        ref.write_shard(sid, codes=PackedCodes(surv["codes"][lo:hi],
                                               m0["K"]),
                        assign=surv["assign"][lo:hi],
                        aq_norms=surv["aq_norms"][lo:hi],
                        pw_norms=surv["pw_norms"][lo:hi])
    ref.finalize()
    gen = store.dir / "shards" / "gen_001"
    for sid in range(rep["shards_total"]):
        for f in SHARD_FILES:
            assert (gen / f"shard_{sid:05d}" / f).read_bytes() == \
                (ref.dir / "shards" / f"shard_{sid:05d}" / f).read_bytes(), \
                f"shard {sid} {f} diverged from the fresh-write reference"

    assert fsck_store(store.dir, log=lambda *a: None)["ok"]
    assert store.orphan_paths()                  # compactor never unlinks
    store.gc_orphans()
    assert store.orphan_paths() == []
    assert not store.mutated
    assert store.load().codes.shape[0] == 1245   # clean store loads again


def test_compaction_kill_resume(world, tmp_path):
    _, _, cfg, _, q = world
    store = _mutate(world, tmp_path)
    r1 = Compactor(store).run(max_shards=2)
    assert r1["partial"] and r1["shards_written"] == 2
    # mid-compaction: fsck clean (cursor warning only), still serveable
    rep = fsck_store(store.dir, log=lambda *a: None)
    assert rep["ok"] and any("in progress" in w for w in rep["warnings"])
    view = ShardedIndexView(store.dir, max_resident_shards=2)
    ids, _ = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    assert not np.isin(np.asarray(ids), DELETED).any()

    survivors = np.flatnonzero(~store.tombstone_bits())
    r2 = Compactor(store).run()                  # resume publishes the rest
    assert r2["compacted"] and r2["shards_written"] == 3
    assert store.read_compact_cursor() is None
    assert fsck_store(store.dir, log=lambda *a: None)["ok"]
    assert view.refresh() and view.generation == 1
    # compaction renumbers ids to survivor positions: map back before
    # asserting the deleted rows stayed gone
    ids2, s2 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    finite = np.asarray(s2) > -np.inf
    orig = survivors[np.asarray(ids2)[finite]]
    assert not np.isin(orig, DELETED).any()


def test_stale_cursor_restarts_cleanly(world, tmp_path):
    """More mutations landing between a partial run and its resume fold a
    different row set: the signature mismatch wipes the partial target
    generation instead of committing a mix."""
    _, xa, _, _, _ = world
    store = _mutate(world, tmp_path)
    Compactor(store).run(max_shards=1)
    store.delete([20])                           # mutation set moved on
    rep = Compactor(store).run()
    assert rep["compacted"] and rep["n_alive"] == 1244
    assert rep["shards_written"] == rep["shards_total"]  # nothing reused
    assert fsck_store(store.dir, log=lambda *a: None)["ok"]


def test_refresh_pins_snapshot_until_released(world, tmp_path):
    """A pinned pre-compaction state keeps reading its own generation's
    files; gc of the superseded generation waits for the unpin."""
    _, _, cfg, _, _ = world
    store = _mutate(world, tmp_path)
    view = ShardedIndexView(store.dir, max_resident_shards=2)
    owner0 = view._owner
    vst = view.pin()
    gids = np.array([[1, 700, 1149]])
    before = view.gather_rows(gids, vst)

    Compactor(store).run()
    assert view.refresh() and view.generation == 1
    assert view._owner != owner0                 # new pool namespace
    # the pinned snapshot still answers from the old generation's files
    after = [np.asarray(a) for a in view.gather_rows(gids, vst)]
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a), b)
    assert any(p.name.startswith("shard_")
               for p in store.orphan_paths())    # gc deferred while pinned
    view.unpin(vst)
    assert store.orphan_paths() == []            # unlink-after-release


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------


def test_load_refuses_mutated_store(world, tmp_path):
    store = _mutate(world, tmp_path)
    with pytest.raises(ValueError, match="uncompacted mutation state"):
        store.load()


def test_append_delete_validation(world, tmp_path):
    _, xa, _, _, _ = world
    d = _copy(world, tmp_path)
    store = IndexStore(d)
    with pytest.raises(ValueError, match="dim"):
        store.append(np.zeros((3, 5), np.float32))
    assert store.append(np.zeros((0, 16), np.float32)).size == 0
    with pytest.raises(ValueError, match="outside"):
        store.delete([1100])                     # no deltas yet: n=1100
    with pytest.raises(ValueError, match="outside"):
        store.delete([-1])
    # incomplete stores refuse mutation (builder still owns them)
    m = json.loads(store.manifest_path.read_text())
    store.manifest_path.write_text(json.dumps(dict(m, complete=False)))
    store.reload_manifest()
    with pytest.raises(ValueError, match="incomplete"):
        store.append(xa)


def test_fsck_flags_corrupt_delta_and_tombstone(world, tmp_path):
    store = _mutate(world, tmp_path)
    p = store.delta_dir(0) / "aq_norms.f32"
    b = bytearray(p.read_bytes())
    b[7] ^= 0xFF
    p.write_bytes(bytes(b))
    rep = fsck_store(store.dir, log=lambda *a: None)
    assert not rep["ok"] and rep["deltas_corrupt"] == [0]
    b[7] ^= 0xFF
    p.write_bytes(bytes(b))

    t = store.tombstone_path(0)
    raw = bytearray(t.read_bytes())
    raw[0] ^= 0xFF
    t.write_bytes(bytes(raw))
    rep = fsck_store(store.dir, log=lambda *a: None)
    assert not rep["ok"] and any("tombstone" in e for e in rep["errors"])


# ---------------------------------------------------------------------------
# concurrency: snapshot isolation under churn
# ---------------------------------------------------------------------------


def test_concurrent_mutation_never_resurrects_deletes(world, tmp_path):
    """Mutator thread appends + deletes while query threads refresh and
    search: an id whose delete published BEFORE a thread's refresh never
    appears in that thread's results. (Queries pinned to an older
    snapshot may legitimately still see fresher deletes' rows — that is
    snapshot isolation, not a bug.)"""
    _, xa, cfg, _, q = world
    d = _copy(world, tmp_path)
    view = ShardedIndexView(d, max_resident_shards=2)
    search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)  # warm the jit

    published = set()
    lock = threading.Lock()
    failures = []
    done = threading.Event()

    def mutator():
        store = IndexStore(d)
        rng = np.random.default_rng(3)
        base = 1100
        try:
            for step in range(4):
                store.append(xa[step * 30:(step + 1) * 30])
                base += 30
                victims = rng.integers(1, base, size=3).tolist()
                newly = store.delete(victims)
                assert newly >= 0
                with lock:                       # durable before visible
                    published.update(victims)
        except Exception as e:                   # surface in the main thread
            failures.append(f"mutator: {e!r}")
        finally:
            done.set()

    def querier(seed):
        try:
            final_pass = False
            while True:
                if done.is_set():
                    final_pass = True            # one sweep past the last
                with lock:                       # delete, then stop
                    must_miss = set(published)
                view.refresh()
                ids, _ = search.search_sharded(view, q, cfg=cfg,
                                               **SEARCH_KW)
                hit = set(np.asarray(ids).ravel().tolist()) & must_miss
                if hit:
                    failures.append(f"querier {seed}: deleted ids {hit} "
                                    f"returned")
                    return
                if final_pass:
                    return
        except Exception as e:
            failures.append(f"querier {seed}: {e!r}")

    threads = [threading.Thread(target=mutator)] + \
        [threading.Thread(target=querier, args=(s,)) for s in (11, 12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert failures == []

    # quiesce: compact + refresh; deletes stay gone (ids renumber to
    # survivor positions, so map back before checking)
    store = IndexStore(d)
    survivors = np.flatnonzero(~store.tombstone_bits())
    Compactor(store).run()
    assert view.refresh() and view.generation == 1
    ids, s = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    finite = np.asarray(s) > -np.inf
    orig = set(survivors[np.asarray(ids)[finite]].tolist())
    assert not (orig & published)
    assert fsck_store(d, log=lambda *a: None)["ok"]
