"""Search cascade: AQ / pairwise decoders, IVF, end-to-end recall,
distributed ADC merge."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import aq, ivf, pairwise as pw, search, training
from repro.core import encode as enc
from repro.kernels import ops, ref as kref

from conftest import clustered


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(3)
    xb = clustered(rng, 6000, 16, k=64)
    xq = xb[:48] + 0.05 * rng.normal(size=(48, 16)).astype(np.float32)
    gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)
    cfg = tiny(epochs=2)
    params, _ = training.train(jax.random.key(1), xb[:3000], cfg,
                               verbose=False)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=32, m_tilde=2, n_pair_books=8)
    return xb, xq, gt, cfg, params, idx


def test_aq_fit_reduces_error(world):
    xb, _, _, cfg, params, idx = world
    resid = ivf.residual_to_centroid(idx.ivf, jnp.asarray(xb),
                                     idx.ivf.assignments)
    recon = aq.aq_decode(idx.aq_books, idx.codes)
    mse = float(jnp.mean(jnp.sum((resid - recon) ** 2, -1)))
    base = float(jnp.mean(jnp.sum(resid ** 2, -1)))
    assert mse < base


def test_pairwise_beats_unitary(world):
    """Paper §3.3: the pairwise decoder is at least as good as unitary AQ."""
    xb, _, _, cfg, params, idx = world
    recon_aq = (aq.aq_decode(idx.aq_books, idx.codes)
                + idx.ivf.centroids[idx.ivf.assignments])
    mse_aq = float(jnp.mean(jnp.sum((jnp.asarray(xb) - recon_aq) ** 2, -1)))
    recon_pw = idx.pw.decode(idx.ext_codes)
    mse_pw = float(jnp.mean(jnp.sum((jnp.asarray(xb) - recon_pw) ** 2, -1)))
    assert mse_pw <= mse_aq + 1e-5


def test_optimized_pairs_beat_consecutive(world):
    """Table 4: optimized code-pairs > consecutive code-pairs."""
    xb, _, _, cfg, params, idx = world
    ext = idx.ext_codes
    cons = pw.consecutive_pairs_decoder(ext, jnp.asarray(xb), cfg.K)
    mse_cons = float(jnp.mean(jnp.sum(
        (jnp.asarray(xb) - cons.decode(ext)) ** 2, -1)))
    opt = pw.fit_pairwise(ext, jnp.asarray(xb), cfg.K, len(cons.pairs))
    mse_opt = float(jnp.mean(jnp.sum(
        (jnp.asarray(xb) - opt.decode(ext)) ** 2, -1)))
    assert mse_opt <= mse_cons + 1e-5


def test_cascade_recall(world):
    """Cascade recall close to the codec's own brute-force ceiling."""
    from repro.core import qinco
    xb, xq, gt, cfg, params, idx = world
    q = jnp.asarray(xq)
    ids, dists = search.search(idx, q, n_probe=8,
                               n_short_aq=64, n_short_pw=16, topk=1, cfg=cfg)
    r1 = float((np.asarray(ids[:, 0]) == gt).mean())
    # ceiling: exact rerank of ALL decoded db vectors (no shortlist)
    recon = (qinco.decode(params, idx.codes, cfg)
             + idx.ivf.centroids[idx.ivf.assignments])
    d2 = ((np.asarray(q)[:, None] - np.asarray(recon)[None]) ** 2).sum(-1)
    ceiling = float((np.argmin(d2, 1) == gt).mean())
    assert r1 >= 0.5 * ceiling and r1 > 0.2, (r1, ceiling)


def test_bigger_shortlists_help(world):
    """Bigger shortlists must not lose ground-truth candidates.

    Final r@1 after the neural re-rank is NOT monotone in shortlist size
    (a larger pool can surface a wrong neighbor whose *reconstruction* is
    closer than the true NN's), so assert the deterministic property
    instead: gt containment in the pre-rerank shortlist is monotone,
    because top-k candidate sets of the same scores nest as k grows."""
    xb, xq, gt, cfg, params, idx = world
    r = {}
    for ns in (4, 32):
        ids, _ = search.search(idx, jnp.asarray(xq), n_probe=8,
                               n_short_aq=max(ns, 8), n_short_pw=ns,
                               topk=ns, cfg=cfg)
        r[ns] = float((np.asarray(ids) == gt[:, None]).any(1).mean())
    assert r[32] >= r[4] - 1e-9
    assert r[32] > 0.2


def test_adc_kernel_in_cascade(world):
    """The Pallas ADC kernel scores == the cascade's jnp scoring."""
    xb, xq, gt, cfg, params, idx = world
    q = jnp.asarray(xq[:8])
    lut = aq.adc_lut(idx.aq_books, q)                     # (Q, M, K)
    scores_k = ops.adc_scores(idx.codes, lut, backend="pallas")
    scores_ref = kref.adc_ref(idx.codes, lut)
    np.testing.assert_allclose(np.asarray(scores_k), np.asarray(scores_ref),
                               rtol=1e-4, atol=1e-3)


def test_distributed_adc_matches_local(world):
    """shard_map per-shard top-k + merge == single-device top-k."""
    xb, xq, gt, cfg, params, idx = world
    from repro.parallel import compat
    mesh = jax.make_mesh((1,), ("model",))
    fn = search.make_distributed_adc(mesh, "model")
    q = jnp.asarray(xq[:4])
    lut = aq.adc_lut(idx.aq_books, q)
    norms = idx.aq_norms
    k = 8
    with compat.use_mesh(mesh):
        gids, gscores = fn(lut, idx.codes, norms, k)
    # reference: full scores, global top-k
    full = 2.0 * kref.adc_ref(idx.codes, lut) - norms[None]
    rs, ri = jax.lax.top_k(full, k)
    np.testing.assert_allclose(np.asarray(gscores), np.asarray(rs),
                               rtol=1e-4, atol=1e-3)


def test_ivf_probe_covers_assignment(world):
    """A vector's own bucket is found when probing enough buckets."""
    xb, _, _, cfg, params, idx = world
    x0 = jnp.asarray(xb[:16])
    top, cand, mask = ivf.probe(idx.ivf, x0, n_probe=8)
    own = np.asarray(idx.ivf.assignments[:16])
    hit = (np.asarray(top) == own[:, None]).any(1).mean()
    assert hit > 0.9
