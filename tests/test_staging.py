"""The latency-hiding staging contract:

- prefetched `search_sharded` (the default) is bitwise identical to the
  sequential (`prefetch=False`) scan AND to resident `search()` on both
  dispatch backends;
- probe-aware scheduling skips shards with zero probed buckets and
  orders resident shards first — with identical results, and the skip
  counter proving it actually fired;
- the budget bound survives the prefetch pipeline: never more than
  `max_resident_shards` staged entries allocated, even with a stage in
  flight (evict-at-issue);
- several views share one `StagingPool` under a single byte budget,
  including under concurrent queries from separate threads;
- the host cache of assembled shards turns an evict -> re-stage cycle
  into a device_put (host_hits), not a fresh assembly;
- prefetched staging is the DEFAULT serving path (`search_sharded`
  signature + `SearchServer --out-of-core`), and `ServeStats` splits
  service time into staging-stall vs compute.
"""
import inspect
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore, ShardedIndexView, StagingPool

from conftest import clustered


SEARCH_KW = dict(n_probe=4, n_short_aq=16, n_short_pw=8, topk=3)


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Clustered database -> resident index -> saved store (4 shards)."""
    rng = np.random.default_rng(7)
    xb = clustered(rng, 1100, 16, k=16)
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), xb[:400], cfg)
    idx = search.build_index(jax.random.key(2), jnp.asarray(xb), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("store") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    q = jnp.asarray(xb[:13] + 0.02)
    return xb, cfg, params, store_dir, q


@pytest.fixture(scope="module")
def resident(world):
    _, _, _, store_dir, _ = world
    return IndexStore(store_dir).load()


@pytest.fixture(scope="module")
def sorted_world(world, tmp_path_factory):
    """The same database re-ordered by IVF bucket, so shards have
    DISJOINT-ish bucket occupancy and probe-aware skipping actually
    fires (a randomly-ordered store touches every bucket per shard)."""
    xb, cfg, params, _, _ = world
    probe = search.build_index(jax.random.key(2), jnp.asarray(xb), params,
                               cfg, k_ivf=8, m_tilde=2, n_pair_books=4,
                               encode_chunk=512)
    order = np.argsort(np.asarray(probe.ivf.assignments), kind="stable")
    xs = xb[order]
    idx = search.build_index(jax.random.key(2), jnp.asarray(xs), params, cfg,
                             k_ivf=8, m_tilde=2, n_pair_books=4,
                             encode_chunk=512)
    store_dir = tmp_path_factory.mktemp("sorted") / "idx"
    IndexStore.save(store_dir, idx, shard_size=300)
    return xs, cfg, idx, store_dir


# ---------------------------------------------------------------------------
# prefetch pipeline: parity + budget bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_prefetch_parity_both_backends(world, resident, backend):
    """Prefetched (default), sequential, and resident all bit-identical;
    the background worker really ran (prefetch_issued)."""
    _, cfg, _, store_dir, q = world
    i0, s0 = search.search(resident, q, cfg=cfg, backend=backend,
                           **SEARCH_KW)
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    i1, s1 = search.search_sharded(view, q, cfg=cfg, backend=backend,
                                   **SEARCH_KW)          # prefetch default
    assert view.pool.stats()["prefetch_issued"] > 0
    i2, s2 = search.search_sharded(view, q, cfg=cfg, backend=backend,
                                   prefetch=False, **SEARCH_KW)
    for i, s in ((i1, s1), (i2, s2)):
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s))


@pytest.mark.parametrize("max_resident", [1, 2])
def test_budget_bound_under_prefetch(world, resident, max_resident):
    """Evict-at-issue: even with a prefetch in flight, never more than
    max_resident_shards entries (or their bytes) allocated. At budget 1
    the pipeline degrades to sequential (prefetch_skipped) rather than
    over-allocating."""
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=max_resident)
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    for _ in range(2):                       # second pass re-stages evicted
        i1, s1 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert view.pool.peak_resident_entries <= max_resident
    assert view.peak_resident_bytes <= view.budget_bytes
    if max_resident == 1:
        assert view.pool.stats()["prefetch_skipped"] > 0


def test_host_cache_avoids_reassembly(world, resident):
    """With a 1-shard device budget over 4 shards but a host cache that
    covers the store, the second search replays every stage from the
    host cache (host_hits) instead of re-assembling from the mmaps — and
    stays bit-identical. (The default host cache is only 2x the device
    budget; a cyclic scan larger than that thrashes it, hence the
    explicit sizing here.)"""
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=1,
                            host_cache_bytes=1 << 30)
    search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    assert view.pool.stats()["host_hits"] == 0           # first pass: cold
    i1, s1 = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
    assert view.pool.stats()["host_hits"] > 0
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# ---------------------------------------------------------------------------
# probe-aware scheduling
# ---------------------------------------------------------------------------


def test_schedule_skips_and_orders_resident_first(world):
    _, cfg, _, store_dir, _ = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    all_buckets = np.arange(view.k_ivf)[None]
    view.staged(2)                                       # make 2 resident
    sched = view.schedule_shards(all_buckets)
    assert sched[0] == 2                                 # resident first
    assert sorted(sched) == view.shard_ids               # nothing dropped
    # a bucket no shard contains -> everything skipped
    missing = np.asarray(view.bucket_fill) == 0
    if missing.any():
        b = int(np.argmax(missing))
        before = view.skipped_shards_total
        assert view.schedule_shards(np.array([[b]])) == []
        assert view.skipped_shards_total == before + len(view.shard_ids)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_shard_skip_parity(sorted_world, backend):
    """Over a bucket-sorted store a single-bucket probe hits only the
    shard(s) holding that bucket's contiguous run: shards ARE skipped
    (counter grows) and results stay bit-identical to resident."""
    xs, cfg, idx, store_dir = sorted_world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    # a bucket some shard lacks is guaranteed by the sorted layout
    absent = [(s, b) for s in view.shard_ids for b in range(view.k_ivf)
              if not view._bucket_hit[s][b]]
    assert absent, "sorted store still has every bucket in every shard"
    kw = dict(n_probe=1, n_short_aq=16, n_short_pw=8, topk=3, cfg=cfg,
              backend=backend)
    q1 = jnp.asarray(xs[:9] + 0.02)
    i0, s0 = search.search(idx, q1, **kw)
    before = view.skipped_shards_total
    i1, s1 = search.search_sharded(view, q1, **kw)
    assert view.skipped_shards_total > before
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# ---------------------------------------------------------------------------
# shared pool
# ---------------------------------------------------------------------------


def test_two_views_share_one_pool_concurrently(world, resident):
    """Two views split ONE byte budget (2 worst-case shards), queried
    from two threads at once: both bit-identical to resident, pool never
    over its entry/byte bound. Budget rule: >= one worst-case shard per
    concurrent searcher (each thread pins at most one)."""
    _, cfg, _, store_dir, q = world
    sizer = ShardedIndexView(store_dir, max_resident_shards=1)
    worst = max(sizer.shard_staged_bytes(s) for s in sizer.shard_ids)
    pool = StagingPool(2 * worst, max_entries=2)
    v1 = ShardedIndexView(store_dir, pool=pool)
    v2 = ShardedIndexView(store_dir, pool=pool)
    assert v1._owner != v2._owner
    i0, s0 = search.search(resident, q, cfg=cfg, **SEARCH_KW)
    out, errs = {}, []

    def worker(name, view):
        try:
            for _ in range(2):
                out[name] = search.search_sharded(view, q, cfg=cfg,
                                                  **SEARCH_KW)
        except BaseException as e:                       # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(n, v))
               for n, v in (("a", v1), ("b", v2))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs and len(out) == 2
    for i1, s1 in out.values():
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    assert pool.peak_resident_entries <= 2
    assert pool.peak_resident_bytes <= pool.budget_bytes


def test_pool_unit_contract():
    """StagingPool mechanics without a store: pins block eviction,
    duplicate prefetch is a no-op, oversized shards are rejected,
    drop_owner frees an owner's lines."""
    mk = lambda: {"x": np.ones(8, np.float32)}           # 32 B
    pool = StagingPool(64, prefetch=False)
    with pytest.raises(ValueError, match="exceeds the staging"):
        pool.acquire(("o", 0), lambda: {"x": np.ones(32, np.float32)}, 128)
    pool.acquire(("o", 0), mk, 32)                       # pinned
    pool.acquire(("o", 1), mk, 32)                       # pool full, pinned
    assert pool.prefetch(("o", 2), mk, 32) is False      # disabled
    pool.prefetch_enabled = True
    assert pool.prefetch(("o", 0), mk, 32) is False      # already resident
    assert pool.prefetch(("o", 2), mk, 32) is False      # all pinned: skip
    assert pool.stats()["prefetch_skipped"] == 1
    pool.release(("o", 0))
    assert pool.prefetch(("o", 2), mk, 32) is True       # evicts ("o", 0)
    pool.acquire(("o", 2), mk, 32)                       # waits for worker
    assert pool.stats()["prefetch_hits"] == 1
    assert ("o", 0) not in pool.resident_keys()
    assert pool.peak_resident_bytes <= pool.budget_bytes
    pool.release(("o", 1)), pool.release(("o", 2))
    pool.drop_owner("o")
    assert pool.resident_keys() == [] and pool.resident_bytes == 0


# ---------------------------------------------------------------------------
# serving defaults + observability
# ---------------------------------------------------------------------------


def test_prefetch_is_the_default_serving_path(world, resident):
    """Tier-1 guard: `search_sharded(prefetch=True)` is the default, and
    `SearchServer --out-of-core` actually drives the prefetch pipeline
    (issued > 0 after a stream) with resident-identical results."""
    from repro.launch.serve_search import SearchServer, synthetic_stream
    assert (inspect.signature(search.search_sharded)
            .parameters["prefetch"].default is True)
    _, cfg, _, store_dir, q = world
    view = ShardedIndexView(store_dir, max_resident_shards=2)
    srv = SearchServer(view, micro_batch=8, topk=3, n_probe=4,
                       n_short_aq=16, n_short_pw=8)
    ids, dists = srv.search_batch(np.asarray(q)[:5])
    ref_q = jnp.concatenate([q[:5], jnp.zeros((3, q.shape[1]))])
    ref_ids, ref_d = search.search(resident, ref_q, cfg=cfg, **SEARCH_KW)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids)[:5])
    np.testing.assert_array_equal(dists, np.asarray(ref_d)[:5])
    stats = srv.serve_stream(*synthetic_stream(view, 24, 2000.0))
    assert view.pool.stats()["prefetch_issued"] > 0
    assert stats.stall_ms >= 0.0 and stats.compute_ms > 0.0
    assert f"stall={stats.stall_ms:.1f}ms" in stats.row()


def test_serve_stats_stall_zero_for_resident(world, resident):
    from repro.launch.serve_search import SearchServer, synthetic_stream
    srv = SearchServer(resident, micro_batch=8, topk=3, n_probe=4,
                       n_short_aq=16, n_short_pw=8)
    stats = srv.serve_stream(*synthetic_stream(resident, 16, 2000.0))
    assert stats.stall_ms == 0.0 and stats.compute_ms > 0.0
