"""The fused beam-step dispatch contract (`ops.f_theta_err` /
`ops.preselect_topk`):

- `ops.f_theta_err` (expansion + in-VMEM scoring + flat top-B) and
  `ops.preselect_topk` (g_phi + L2 + top-A) are BIT-identical to the
  unfused composites they replace, on the xla backend AND in
  interpret-mode pallas — values and `lax.top_k` tie-breaks, including
  the all-+inf unpopulated-beam case a bare masked-argmax loop gets
  wrong;
- `encode(fused=True)` == `encode(fused=False)` bit-for-bit across
  QINCo1-greedy (A=K, B=1), pre-selection (A<K, B=1), and beam (B>1)
  modes, uint8 and int32 candidate indices, on both backends — and
  reproduces the pre-refactor goldens;
- both new ops survive empty inputs;
- the committed tile-table artifact (`benchmarks/tile_tables/`) loads
  through `serve_search.SearchServer(tile_table=)` and
  `index.builder.StreamingIndexBuilder(tile_table=)`;
- `search()` clamps shortlist sizes to the probed candidate count
  instead of failing at trace time.
"""
import json
import pathlib
import zlib
from functools import partial

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, search, training
from repro.kernels import beam_topk, ops, ref

from conftest import clustered

GOLDEN = pathlib.Path(__file__).parent / "golden" / "qinco_golden.npz"
TILE_TABLE = (pathlib.Path(__file__).parent.parent / "benchmarks"
              / "tile_tables" / "interpret_cpu.json")


def _step_params(rng, d, de, dh, L, proj):
    p = {
        "concat_w": jnp.asarray(
            rng.normal(size=(d + de, de)).astype(np.float32) * 0.1),
        "concat_b": jnp.asarray(
            rng.normal(size=(de,)).astype(np.float32) * 0.1),
        "blocks_w1": jnp.asarray(
            rng.normal(size=(L, de, dh)).astype(np.float32) * 0.2),
        "blocks_w2": jnp.asarray(
            rng.normal(size=(L, dh, de)).astype(np.float32) * 0.2),
    }
    if proj:
        p["in_proj"] = jnp.asarray(
            rng.normal(size=(d, de)).astype(np.float32) * 0.2)
        p["out_proj"] = jnp.asarray(
            rng.normal(size=(de, d)).astype(np.float32) * 0.2)
    return p


def _beam_inputs(rng, N, B, A, K, d, n_valid=None):
    """Random beam state; beams >= n_valid carry err = +inf (unpopulated)."""
    xh = jnp.asarray(rng.normal(size=(N, B, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, K, size=(N, B, A)).astype(np.int32))
    x = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    err = (rng.normal(size=(N, B)) ** 2).astype(np.float32)
    if n_valid is not None:
        err[:, n_valid:] = np.inf
    return xh, idx, x, jnp.asarray(err)


# ---------------------------------------------------------------------------
# masked_topk: the shared selection primitive == lax.top_k, always
# ---------------------------------------------------------------------------


def test_masked_topk_matches_lax_top_k_incl_inf_ties():
    """The taken-mask selection must reproduce lax.top_k even when the
    surviving candidates tie at -inf (ascending positions — the case a
    destructive -inf mask collapses to position 0)."""
    neg = jnp.asarray(np.array([
        [-np.inf, -np.inf, 3.0, -np.inf],
        [1.0, 1.0, 1.0, 1.0],
        [2.0, -np.inf, 2.0, 5.0],
        [-np.inf, -np.inf, -np.inf, -np.inf],
    ], np.float32))
    for k in (1, 2, 3, 4):
        want_v, want_i = lax.top_k(neg, k)
        got_v, got_i = beam_topk.masked_topk(neg, k)
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_masked_topk_index_map():
    rng = np.random.default_rng(0)
    neg = jnp.asarray(rng.normal(size=(5, 12)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 999, size=(5, 12)).astype(np.int32))
    want_v, pos = lax.top_k(neg, 4)
    got_v, got_i = beam_topk.masked_topk(neg, 4, idx=idx)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i),
                                  np.take_along_axis(np.asarray(idx),
                                                     np.asarray(pos), 1))


# ---------------------------------------------------------------------------
# f_theta_err: fused == unfused composite, bitwise, per backend
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend",))
def _unfused_beam_composite(p, cb, xh, idx, x, err, backend):
    """The pre-fusion `_beam_step` selection math, on a given backend.

    Jitted as one computation, like the encode scan that used to inline
    it: the bitwise contract holds under a common jit (eager op-by-op
    dispatch fuses the error reduction differently)."""
    N, B, d = xh.shape
    A = idx.shape[-1]
    f_out = ops.f_theta(p, cb, xh, idx=idx, backend=backend)
    new_xhat = xh[..., None, :] + f_out
    new_err = jnp.sum(jnp.square(x[:, None, None, :] - new_xhat), -1)
    new_err = jnp.where(jnp.isinf(err)[..., None], jnp.inf, new_err)
    top_err, flat_idx = lax.top_k(-new_err.reshape(N, B * A), B)
    sel = jnp.take_along_axis(new_xhat.reshape(N, B * A, d),
                              flat_idx[..., None], axis=1)
    return -top_err, flat_idx.astype(jnp.int32), sel


@pytest.mark.parametrize("proj", [True, False])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_f_theta_err_bitwise(proj, backend):
    rng = np.random.default_rng(7 + proj)
    d, de, dh, L, K, N, B, A = 16, 24 if proj else 16, 32, 2, 16, 23, 4, 5
    p = _step_params(rng, d, de, dh, L, proj)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh, idx, x, err = _beam_inputs(rng, N, B, A, K, d, n_valid=2)
    want = _unfused_beam_composite(p, cb, xh, idx, x, err, backend)
    got = ops.f_theta_err(p, cb, xh, idx, x, err, backend=backend, tile_n=4)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_f_theta_err_all_inf_beam_ties(backend):
    """B > valid*A at step 0: the flat top-B must pad with +inf slots in
    ascending flat order, exactly as lax.top_k does."""
    rng = np.random.default_rng(3)
    d, K, N, B, A = 8, 8, 9, 4, 2
    p = _step_params(rng, d, 12, 16, 1, True)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh, idx, x, err = _beam_inputs(rng, N, B, A, K, d, n_valid=1)
    want = _unfused_beam_composite(p, cb, xh, idx, x, err, backend)
    got = ops.f_theta_err(p, cb, xh, idx, x, err, backend=backend, tile_n=2)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_f_theta_err_packed_uint8_indices():
    rng = np.random.default_rng(11)
    d, K, N, B, A = 8, 16, 13, 2, 4
    p = _step_params(rng, d, 12, 16, 1, True)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh, idx, x, err = _beam_inputs(rng, N, B, A, K, d, n_valid=1)
    for backend in ("xla", "pallas"):
        a = ops.f_theta_err(p, cb, xh, idx.astype(jnp.uint8), x, err,
                            backend=backend, tile_n=4)
        b = ops.f_theta_err(p, cb, xh, idx.astype(jnp.int32), x, err,
                            backend=backend, tile_n=4)
        for ai, bi in zip(a, b):
            np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))


def test_f_theta_err_cross_backend_bitwise():
    rng = np.random.default_rng(5)
    d, K, N, B, A = 12, 16, 17, 3, 4
    p = _step_params(rng, d, 16, 16, 2, True)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh, idx, x, err = _beam_inputs(rng, N, B, A, K, d, n_valid=2)
    ax = ops.f_theta_err(p, cb, xh, idx, x, err, backend="xla")
    ap = ops.f_theta_err(p, cb, xh, idx, x, err, backend="pallas", tile_n=8)
    for xi, pi in zip(ax, ap):
        np.testing.assert_array_equal(np.asarray(xi), np.asarray(pi))


# ---------------------------------------------------------------------------
# preselect_topk: fused == unfused composite, bitwise, per backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proj", [True, False])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_preselect_topk_bitwise(proj, backend):
    rng = np.random.default_rng(13 + proj)
    d, de, Ls, K, N, B, A = 12, 16 if proj else 12, 2, 16, 9, 3, 4
    p = _step_params(rng, d, de, de, Ls, proj)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh = jnp.asarray(rng.normal(size=(N, B, d)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(N, B, d)).astype(np.float32))

    @partial(jax.jit, static_argnames=("backend",))   # one computation,
    def composite(p, cb, xh, r, backend):             # like the encode scan
        cand = ops.f_theta(p, cb, xh[..., None, :], backend=backend)
        d2 = jnp.sum(jnp.square(r[..., None, :] - cand), axis=-1)
        neg, idx = lax.top_k(-d2, A)
        return idx, -neg

    want_i, want_d2 = composite(p, cb, xh, r, backend)
    got_i, got_d2 = ops.preselect_topk(p, cb, xh, r, A, backend=backend,
                                       tile_n=4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d2), np.asarray(want_d2))


def test_preselect_topk_duplicate_codewords_tie_break():
    """Duplicate pre-codebook rows score identically: both backends must
    select the earliest copies in index order (the top_k contract)."""
    rng = np.random.default_rng(1)
    d, Ls, N = 8, 1, 7
    p = _step_params(rng, d, 12, 12, Ls, True)
    base = rng.normal(size=(4, d)).astype(np.float32)
    cb = jnp.asarray(np.tile(base, (4, 1)))               # 4 copies each
    xh = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    ix, _ = ops.preselect_topk(p, cb, xh, r, 8, backend="xla")
    ip, _ = ops.preselect_topk(p, cb, xh, r, 8, backend="pallas", tile_n=2)
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))


# ---------------------------------------------------------------------------
# encode: fused == unfused end to end, all three modes, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cfg_kw,A,B", [
    ("qinco1-greedy", dict(d=8, de=8, dh=16, M=3, K=8, qinco1_mode=True),
     8, 1),
    ("preselect", {}, 4, 1),
    ("beam", {}, 8, 8),
    ("beam-narrow", {}, 2, 4),         # A < B: +inf ties in the flat top-B
    ("beam-ls1", dict(d=12, de=16, dh=16, M=3, K=16, Ls=1), 4, 4),
])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_encode_fused_equals_unfused(mode, cfg_kw, A, B, backend):
    rng = np.random.default_rng(zlib.crc32(mode.encode()))  # stable seed
    cfg = tiny(**cfg_kw)
    x = jnp.asarray(clustered(rng, 48, cfg.d))
    params = training.init_qinco2(jax.random.key(0), x, cfg)
    cf, xf, mf = enc.encode(params, x, cfg, A, B, backend=backend,
                            fused=True)
    cu, xu, mu = enc.encode(params, x, cfg, A, B, backend=backend,
                            fused=False)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cu))
    np.testing.assert_array_equal(np.asarray(xf), np.asarray(xu))
    assert float(mf) == float(mu)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fused_encode_matches_golden_qinco2(golden, backend):
    x = golden["q2_x"]
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4,
                                backend=backend, fused=True)
    np.testing.assert_array_equal(np.asarray(codes), golden["q2_codes"])
    np.testing.assert_array_equal(np.asarray(xhat), golden["q2_xhat"])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fused_encode_matches_golden_preselector(golden, backend):
    x = golden["ls_x"]
    cfg = tiny(d=12, de=16, dh=16, M=3, K=16, Ls=1)
    params = training.init_qinco2(jax.random.key(3), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4,
                                backend=backend, fused=True)
    np.testing.assert_array_equal(np.asarray(codes), golden["ls_codes"])
    np.testing.assert_array_equal(np.asarray(xhat), golden["ls_xhat"])


def test_exhaustive_preselect_ships_packed_uint8():
    """A >= K: the identity candidate list is packed uint8 when the
    alphabet fits a byte (4x less pre-selector wire than int32)."""
    cfg = tiny()
    idx = enc.preselect(None, jnp.zeros((3, 2, cfg.d)),
                        jnp.zeros((3, 2, cfg.d)), None, cfg.K, cfg)
    assert idx.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(idx),
        np.broadcast_to(np.arange(cfg.K), (3, 2, cfg.K)))


# ---------------------------------------------------------------------------
# empty inputs + input validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_empty_inputs_fused_beam_ops(backend):
    rng = np.random.default_rng(0)
    f32 = np.float32
    p = _step_params(rng, 8, 12, 16, 1, True)
    cb = jnp.asarray(rng.normal(size=(16, 8)).astype(f32))
    # f_theta_err: empty batch and empty beam
    e, i, xh = ops.f_theta_err(
        p, cb, jnp.zeros((0, 3, 8), f32), jnp.zeros((0, 3, 4), np.int32),
        jnp.zeros((0, 8), f32), jnp.zeros((0, 3), f32), backend=backend)
    assert e.shape == (0, 3) and i.shape == (0, 3) and xh.shape == (0, 3, 8)
    e, i, xh = ops.f_theta_err(
        p, cb, jnp.zeros((5, 0, 8), f32), jnp.zeros((5, 0, 4), np.int32),
        jnp.zeros((5, 8), f32), jnp.zeros((5, 0), f32), backend=backend)
    assert e.shape == (5, 0) and i.shape == (5, 0) and xh.shape == (5, 0, 8)
    # preselect_topk: empty rows
    ix, d2 = ops.preselect_topk(p, cb, jnp.zeros((0, 2, 8), f32),
                                jnp.zeros((0, 2, 8), f32), 4,
                                backend=backend)
    assert ix.shape == (0, 2, 4) and d2.shape == (0, 2, 4)


def test_f_theta_err_rejects_bad_shapes():
    rng = np.random.default_rng(0)
    p = _step_params(rng, 8, 12, 16, 1, True)
    cb = jnp.zeros((16, 8), np.float32)
    with pytest.raises(ValueError):
        ops.f_theta_err(p, cb, jnp.zeros((3, 2, 8), np.float32),
                        jnp.zeros((4, 2, 5), np.int32),
                        jnp.zeros((3, 8), np.float32),
                        jnp.zeros((3, 2), np.float32))
    with pytest.raises(ValueError):
        ops.f_theta_err(p, cb, jnp.zeros((3, 2, 8), np.float32),
                        jnp.zeros((3, 2, 0), np.int32),
                        jnp.zeros((3, 8), np.float32),
                        jnp.zeros((3, 2), np.float32))


# ---------------------------------------------------------------------------
# search shortlist clamping (regression: top_k wider than its input)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_index():
    rng = np.random.default_rng(0)
    cfg = tiny(epochs=1)
    xb = clustered(rng, 300, cfg.d)
    params = training.init_qinco2(jax.random.key(0), xb[:128], cfg)
    return search.build_index(jax.random.key(1), jnp.asarray(xb), params,
                              cfg, k_ivf=8, m_tilde=2, n_pair_books=4), cfg


def test_search_clamps_oversized_shortlists(tiny_index):
    """n_short_aq / n_short_pw / topk larger than the probed candidate
    count used to fail at trace time; now they clamp to it."""
    index, cfg = tiny_index
    q = jnp.asarray(np.asarray(index.ivf.centroids[:5]) + 0.01)
    C = index.ivf.buckets.shape[1] * 2                    # n_probe = 2
    ids, dists = search.search(index, q, n_probe=2, n_short_aq=10_000,
                               n_short_pw=5_000, topk=2_000, cfg=cfg)
    assert ids.shape == (5, C) and dists.shape == (5, C)
    want_ids, want_d = search.search(index, q, n_probe=2, n_short_aq=C,
                                     n_short_pw=C, topk=C, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want_ids))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(want_d))


def test_search_clamp_chain_topk_only(tiny_index):
    """topk > n_short_pw clamps to it (the chain clamps stepwise)."""
    index, cfg = tiny_index
    q = jnp.asarray(np.asarray(index.ivf.centroids[:3]) + 0.01)
    ids, dists = search.search(index, q, n_probe=4, n_short_aq=16,
                               n_short_pw=8, topk=64, cfg=cfg)
    assert ids.shape == (3, 8) and dists.shape == (3, 8)


# ---------------------------------------------------------------------------
# tile-table artifact: committed sweep loads through the entry points
# ---------------------------------------------------------------------------


def test_tile_table_artifact_exists_and_covers_beam_ops():
    data = json.loads(TILE_TABLE.read_text())
    assert "f_theta_err" in data and "preselect_topk" in data
    for op, sizes in data.items():
        for name, v in sizes.items():
            assert isinstance(v, int) and v >= 1, (op, name, v)


def test_tile_table_loads_via_builder(tmp_path):
    from repro.index.builder import StreamingIndexBuilder
    from repro.kernels import tuning
    want = json.loads(TILE_TABLE.read_text())
    try:
        tuning.reset()
        StreamingIndexBuilder(tmp_path / "store", tile_table=TILE_TABLE)
        for op, sizes in want.items():
            assert tuning.tiles(op) == sizes
    finally:
        tuning.reset()


def test_tile_table_loads_via_serve_search(tiny_index):
    from repro.kernels import tuning
    from repro.launch.serve_search import SearchServer
    index, _ = tiny_index
    want = json.loads(TILE_TABLE.read_text())
    try:
        tuning.reset()
        srv = SearchServer(index, micro_batch=4, n_probe=2, n_short_aq=8,
                           n_short_pw=4, topk=2, tile_table=TILE_TABLE)
        for op, sizes in want.items():
            assert tuning.tiles(op) == sizes
        ids, _ = srv.search_batch(np.zeros((2, srv.d), np.float32))
        assert ids.shape == (2, 2)
    finally:
        tuning.reset()
