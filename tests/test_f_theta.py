"""The fused step-network dispatch contract (`ops.f_theta` / `ops.adc_topk`):

- `ops.f_theta` is BIT-identical to the pre-refactor `qinco.f_apply` jnp
  math on the xla backend AND in interpret-mode pallas (same primitive
  sequence per row; the one-hot in-kernel gather is exact), across
  de != d (projections), qinco1_mode (no projections), the L_s >= 1
  pre-selector broadcast shape, and the indexed beam-expansion form;
- encode / decode / search reproduce golden outputs captured from the
  pre-refactor tree (tests/golden/make_golden.py) bit-for-bit;
- `ops.adc_topk` fused scoring+shortlist == unfused `adc_scores` +
  `lax.top_k` bit-identically on each backend (values AND tie-breaks);
- every ops entry point survives empty inputs (the degenerate-shape
  guard: no `Np // 0` grids).
"""
import pathlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, search, training
from repro.kernels import ops, ref

from conftest import clustered

GOLDEN = pathlib.Path(__file__).parent / "golden" / "qinco_golden.npz"


def _f_apply_pre_refactor(step_params, c, xhat, d):
    """Verbatim copy of the pre-refactor `qinco.f_apply` (PR 2 tree): the
    bitwise oracle this suite holds `ops.f_theta` to."""
    p = step_params
    if "in_proj" in p:
        c_emb = c @ p["in_proj"]
    else:
        c_emb = c
    bshape = jnp.broadcast_shapes(c_emb.shape[:-1], xhat.shape[:-1])
    c_emb = jnp.broadcast_to(c_emb, bshape + c_emb.shape[-1:])
    xb = jnp.broadcast_to(xhat, bshape + (d,))
    v = c_emb + jnp.concatenate([c_emb, xb], axis=-1) @ p["concat_w"] \
        + p["concat_b"]

    def block(v, wb):
        w1, w2 = wb
        return v + jax.nn.relu(v @ w1) @ w2, None

    v, _ = lax.scan(block, v, (p["blocks_w1"], p["blocks_w2"]))
    if "out_proj" in p:
        return c + v @ p["out_proj"]
    return c + v


def _step_params(rng, d, de, dh, L, proj):
    p = {
        "concat_w": jnp.asarray(
            rng.normal(size=(d + de, de)).astype(np.float32) * 0.1),
        "concat_b": jnp.asarray(
            rng.normal(size=(de,)).astype(np.float32) * 0.1),
        "blocks_w1": jnp.asarray(
            rng.normal(size=(L, de, dh)).astype(np.float32) * 0.2),
        "blocks_w2": jnp.asarray(
            rng.normal(size=(L, dh, de)).astype(np.float32) * 0.2),
    }
    if proj:
        p["in_proj"] = jnp.asarray(
            rng.normal(size=(d, de)).astype(np.float32) * 0.2)
        p["out_proj"] = jnp.asarray(
            rng.normal(size=(de, d)).astype(np.float32) * 0.2)
    return p


# ---------------------------------------------------------------------------
# f_theta: bitwise vs the pre-refactor math
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,de,dh,L,proj", [
    (16, 24, 32, 1, True),      # de != d: in/out projections (qinco2)
    (12, 12, 16, 3, False),     # qinco1_mode: identity projections
    (8, 48, 16, 2, True),       # deeper chain, wide embed
])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_f_theta_gathered_bitwise(d, de, dh, L, proj, backend):
    rng = np.random.default_rng(d * L)
    p = _step_params(rng, d, de, dh, L, proj)
    c = jnp.asarray(rng.normal(size=(37, d)).astype(np.float32))
    xh = jnp.asarray(rng.normal(size=(37, d)).astype(np.float32))
    want = _f_apply_pre_refactor(p, c, xh, d)
    got = ops.f_theta(p, c, xh, backend=backend, tile_n=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_f_theta_preselector_broadcast_shape(backend):
    """L_s >= 1 shape: shared (K, d) candidates against a (N, B, 1, d)
    beam — the in-projection must run BEFORE the broadcast."""
    rng = np.random.default_rng(9)
    d, de, K, N, B = 12, 16, 16, 11, 3
    p = _step_params(rng, d, de, de, 1, True)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    xh = jnp.asarray(rng.normal(size=(N, B, 1, d)).astype(np.float32))
    want = _f_apply_pre_refactor(p, cb, xh, d)            # (N, B, K, d)
    got = ops.f_theta(p, cb, xh, backend=backend, tile_n=32)
    assert got.shape == (N, B, K, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("proj", [True, False])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_f_theta_indexed_bitwise(proj, backend):
    """Indexed form (in-kernel codebook gather) == gather-then-apply."""
    rng = np.random.default_rng(3 + proj)
    d, de, dh, L, K, N, B, A = 16, 24 if proj else 16, 32, 2, 16, 9, 4, 5
    p = _step_params(rng, d, de, dh, L, proj)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, K, size=(N, B, A)).astype(np.int32))
    xh = jnp.asarray(rng.normal(size=(N, B, d)).astype(np.float32))
    want = _f_apply_pre_refactor(p, cb[idx], xh[..., None, :], d)
    got = ops.f_theta(p, cb, xh, idx=idx, backend=backend, tile_n=4)
    assert got.shape == (N, B, A, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_f_theta_indexed_packed_uint8():
    """Packed uint8 indices are the wire format; results match int32."""
    rng = np.random.default_rng(11)
    d, K, N = 8, 16, 21
    p = _step_params(rng, d, 12, 16, 1, True)
    cb = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    idx = rng.integers(0, K, size=(N, 1))
    xh = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    for backend in ("xla", "pallas"):
        a = ops.f_theta(p, cb, xh, idx=jnp.asarray(idx.astype(np.uint8)),
                        backend=backend, tile_n=8)
        b = ops.f_theta(p, cb, xh, idx=jnp.asarray(idx.astype(np.int32)),
                        backend=backend, tile_n=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_f_apply_routes_through_dispatch():
    """qinco.f_apply is now a thin shim over ops.f_theta (same bits)."""
    rng = np.random.default_rng(1)
    cfg = tiny()
    x = clustered(rng, 64, cfg.d)
    params = training.init_qinco2(jax.random.key(0), x, cfg)
    fm = qinco.step_params_at(params, 0)
    c = jnp.asarray(rng.normal(size=(64, cfg.d)).astype(np.float32))
    xh = jnp.asarray(rng.normal(size=(64, cfg.d)).astype(np.float32))
    got = qinco.f_apply(fm, c, xh, cfg, backend="pallas")
    want = _f_apply_pre_refactor(fm, c, xh, cfg.d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# end-to-end golden equivalence (outputs captured pre-refactor)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_encode_decode_match_golden_qinco2(golden, backend):
    x = golden["q2_x"]
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4,
                                backend=backend)
    np.testing.assert_array_equal(np.asarray(codes), golden["q2_codes"])
    np.testing.assert_array_equal(np.asarray(xhat), golden["q2_xhat"])
    recon = qinco.decode(params, codes, cfg, backend=backend)
    np.testing.assert_array_equal(np.asarray(recon), golden["q2_recon"])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_encode_decode_match_golden_qinco1(golden, backend):
    x = golden["q1_x"]
    cfg = tiny(d=8, de=8, dh=16, M=3, K=8, qinco1_mode=True)
    params = training.init_qinco2(jax.random.key(2), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, cfg.K, 1,
                                backend=backend)
    np.testing.assert_array_equal(np.asarray(codes), golden["q1_codes"])
    np.testing.assert_array_equal(np.asarray(xhat), golden["q1_xhat"])
    recon = qinco.decode(params, codes, cfg, backend=backend)
    np.testing.assert_array_equal(np.asarray(recon), golden["q1_recon"])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_encode_match_golden_preselector(golden, backend):
    x = golden["ls_x"]
    cfg = tiny(d=12, de=16, dh=16, M=3, K=16, Ls=1)
    params = training.init_qinco2(jax.random.key(3), x, cfg)
    codes, xhat, _ = enc.encode(params, jnp.asarray(x), cfg, 4, 4,
                                backend=backend)
    np.testing.assert_array_equal(np.asarray(codes), golden["ls_codes"])
    np.testing.assert_array_equal(np.asarray(xhat), golden["ls_xhat"])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_search_matches_golden(golden, backend):
    cfg = tiny(epochs=1)
    params = training.init_qinco2(jax.random.key(1), golden["q2_x"], cfg)
    xb = golden["srch_xb"]
    idx = search.build_index(jax.random.key(4), jnp.asarray(xb), params,
                             cfg, k_ivf=8, m_tilde=2, n_pair_books=4)
    q = jnp.asarray(xb[:7] + 0.01)
    ids, dists = search.search(idx, q, n_probe=4, n_short_aq=16,
                               n_short_pw=8, topk=3, cfg=cfg,
                               backend=backend)
    np.testing.assert_array_equal(np.asarray(ids), golden["srch_ids"])
    np.testing.assert_array_equal(np.asarray(dists), golden["srch_dists"])


# ---------------------------------------------------------------------------
# adc_topk: fused == unfused, bit-identically, per backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,tiles", [
    ("xla", {}),
    ("pallas", dict(tile_q=4, tile_n=32)),
])
@pytest.mark.parametrize("with_norms", [True, False])
def test_adc_topk_fused_equals_unfused(backend, tiles, with_norms):
    """The fusion must not change a bit vs the same backend's adc_scores
    + lax.top_k — values AND tie-break order (lowest index first)."""
    rng = np.random.default_rng(42)
    N, M, K, Q, k = 137, 4, 16, 9, 10
    codes = jnp.asarray(rng.integers(0, K, size=(N, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(Q, M, K)).astype(np.float32))
    norms = (jnp.asarray((rng.normal(size=(N,)) ** 2).astype(np.float32))
             if with_norms else None)
    s = ops.adc_scores(codes, lut, norms=norms, backend=backend, **tiles)
    v0, i0 = lax.top_k(s, k)
    v1, i1 = ops.adc_topk(codes, lut, k, norms=norms, backend=backend,
                          **tiles)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_adc_topk_tie_break_lowest_index():
    """Duplicate database rows score identically — both backends must
    shortlist the earliest copies, in index order (the top_k contract)."""
    rng = np.random.default_rng(0)
    base = rng.integers(0, 8, size=(5, 3)).astype(np.int32)
    codes = jnp.asarray(np.tile(base, (8, 1)))            # 8 copies each
    lut = jnp.asarray(rng.normal(size=(3, 3, 8)).astype(np.float32))
    vx, ix = ops.adc_topk(codes, lut, 12, backend="xla")
    vp, ip = ops.adc_topk(codes, lut, 12, backend="pallas", tile_q=2,
                          tile_n=8)
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp),
                               rtol=1e-5, atol=1e-5)


def test_adc_topk_cross_backend_ids_agree():
    rng = np.random.default_rng(6)
    codes = jnp.asarray(rng.integers(0, 16, size=(200, 4)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(7, 4, 16)).astype(np.float32))
    norms = jnp.asarray((rng.normal(size=(200,)) ** 2).astype(np.float32))
    vx, ix = ops.adc_topk(codes, lut, 16, norms=norms, backend="xla")
    vp, ip = ops.adc_topk(codes, lut, 16, norms=norms, backend="pallas",
                          tile_q=4, tile_n=64)
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp),
                               rtol=1e-5, atol=1e-5)


def test_adc_topk_k_clamped_to_n():
    codes = jnp.asarray(np.zeros((3, 2), np.int32))
    lut = jnp.ones((2, 2, 4), jnp.float32)
    for backend in ("xla", "pallas"):
        v, i = ops.adc_topk(codes, lut, 10, backend=backend)
        assert v.shape == (2, 3) and i.shape == (2, 3)


# ---------------------------------------------------------------------------
# pairwise_buckets: vectorized gather == per-pair slices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(41, 6), (5, 9, 6), (0, 6)])
def test_pairwise_buckets_matches_slice_reference(shape):
    rng = np.random.default_rng(sum(shape))
    K = 16
    pairs = ((0, 3), (1, 5), (4, 2), (3, 3))
    codes = jnp.asarray(rng.integers(0, K, size=shape).astype(np.uint8))
    got = ops.pairwise_buckets(codes, pairs, K)
    c32 = codes.astype(jnp.int32)
    want = jnp.stack([c32[..., i] * K + c32[..., j] for i, j in pairs], -1)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pairwise_buckets_empty_pairs():
    codes = jnp.zeros((7, 4), jnp.int32)
    got = ops.pairwise_buckets(codes, (), 16)
    assert got.shape == (7, 0)


# ---------------------------------------------------------------------------
# empty-input guards (the resmlp_chain N == 0 crash class)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_empty_inputs_all_ops(backend):
    f32 = np.float32
    # resmlp_chain: the original Np // tile_n == 0 crash
    v = jnp.zeros((0, 8), f32)
    w1 = jnp.zeros((2, 8, 16), f32)
    w2 = jnp.zeros((2, 16, 8), f32)
    assert ops.resmlp_chain(v, w1, w2, backend=backend).shape == (0, 8)
    # l2_topk
    i, d2 = ops.l2_topk(jnp.zeros((0, 8), f32), jnp.zeros((16, 8), f32), 4,
                        backend=backend)
    assert i.shape == (0, 4) and d2.shape == (0, 4)
    # adc_scores, shared + batched
    lut = jnp.zeros((3, 4, 16), f32)
    assert ops.adc_scores(jnp.zeros((0, 4), np.int32), lut,
                          backend=backend).shape == (3, 0)
    assert ops.adc_scores(jnp.zeros((3, 0, 4), np.int32), lut,
                          backend=backend).shape == (3, 0)
    # adc_topk
    v, i = ops.adc_topk(jnp.zeros((0, 4), np.int32), lut, 5,
                        backend=backend)
    assert v.shape == (3, 0) and i.shape == (3, 0)
    # pairwise_scores with empty codes
    plut = jnp.zeros((3, 2, 256), f32)
    s = ops.pairwise_scores(jnp.zeros((0, 6), np.int32), plut,
                            ((0, 1), (2, 3)), 16, backend=backend)
    assert s.shape == (3, 0)
    # f_theta, gathered + indexed
    rng = np.random.default_rng(0)
    p = _step_params(rng, 8, 12, 16, 1, True)
    out = ops.f_theta(p, jnp.zeros((0, 8), f32), jnp.zeros((0, 8), f32),
                      backend=backend)
    assert out.shape == (0, 8)
    out = ops.f_theta(p, jnp.zeros((16, 8), f32), jnp.zeros((0, 8), f32),
                      idx=jnp.zeros((0, 4), np.int32), backend=backend)
    assert out.shape == (0, 4, 8)
    # kv_dequant_attn with an empty batch
    q = jnp.zeros((0, 1, 2, 8), f32)
    ck = jnp.zeros((0, 16, 1, 2), np.int32)
    cb = jnp.zeros((1, 2, 8, 8), f32)
    assert ops.kv_dequant_attn(q, ck, ck, cb, cb, 4,
                               backend=backend).shape == q.shape


# ---------------------------------------------------------------------------
# tuning table
# ---------------------------------------------------------------------------


def test_tuning_table_resolution(tmp_path):
    from repro.kernels import tuning
    try:
        assert tuning.tile("adc_scores", "tile_q") == 64
        assert tuning.tile("adc_scores", "tile_q", 16) == 16  # explicit wins
        with tuning.overridden("adc_scores", tile_q=32):
            assert tuning.tile("adc_scores", "tile_q") == 32
        assert tuning.tile("adc_scores", "tile_q") == 64
        # save -> load round trip, applied to the live table
        tuning.set_tiles("f_theta", tile_n=64)
        p = tmp_path / "tiles.json"
        tuning.save(p)
        tuning.reset()
        assert tuning.tile("f_theta", "tile_n") == 128
        tuning.load(p)
        assert tuning.tile("f_theta", "tile_n") == 64
        # stale artifacts fail loudly
        with pytest.raises(KeyError):
            tuning.set_tiles("no_such_op", tile_n=8)
        with pytest.raises(KeyError):
            tuning.set_tiles("adc_scores", tile_z=8)
        with pytest.raises(ValueError):
            tuning.set_tiles("adc_scores", tile_q=0)
    finally:
        tuning.reset()


def test_set_tiles_applies_after_first_compile(monkeypatch):
    """Tile resolution lives in the non-jitted facade wrapper: a table
    change AFTER an op has compiled must reach the kernel on the next
    call (fresh jit key), not replay the stale executable."""
    from repro.kernels import resmlp as rm
    from repro.kernels import tuning
    seen = []
    orig = rm.resmlp_chain

    def spy(v, w1, w2, *, tile_n, interpret):
        seen.append(tile_n)
        return orig(v, w1, w2, tile_n=tile_n, interpret=interpret)

    monkeypatch.setattr(rm, "resmlp_chain", spy)
    v = jnp.ones((16, 8), np.float32)
    w1 = jnp.zeros((1, 8, 8), np.float32)
    w2 = jnp.zeros((1, 8, 8), np.float32)
    try:
        ops.resmlp_chain(v, w1, w2, backend="pallas")
        tuning.set_tiles("resmlp_chain", tile_n=4)
        ops.resmlp_chain(v, w1, w2, backend="pallas")
        assert seen == [256, 4], seen
    finally:
        tuning.reset()


def test_tuning_load_is_atomic(tmp_path):
    """A partially-bad artifact must fail without half-applying."""
    import json
    from repro.kernels import tuning
    p = tmp_path / "stale.json"
    p.write_text(json.dumps({"adc_scores": {"tile_q": 32},
                             "bogus_op": {"tile_n": 8}}))
    try:
        with pytest.raises(KeyError):
            tuning.load(p)
        assert tuning.tile("adc_scores", "tile_q") == 64  # untouched
    finally:
        tuning.reset()


def test_tuning_table_drives_dispatch():
    """An op picks up table overrides when the caller passes no tiles."""
    from repro.kernels import tuning
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 8, size=(40, 3)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(5, 3, 8)).astype(np.float32))
    want = ref.adc_ref(codes, lut)
    try:
        with tuning.overridden("adc_scores", tile_q=2, tile_n=16):
            got = ops.adc_scores(codes, lut, backend="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
    finally:
        tuning.reset()


def test_encode_empty_batch():
    """The full encoder path survives N == 0 (regression for the
    degenerate-shape crash class)."""
    rng = np.random.default_rng(2)
    cfg = tiny()
    params = training.init_qinco2(
        jax.random.key(0), clustered(rng, 64, cfg.d), cfg)
    codes, xhat, _ = enc.encode(params, jnp.zeros((0, cfg.d), np.float32),
                                cfg, 4, 4, backend="pallas")
    assert codes.shape == (0, cfg.M) and xhat.shape == (0, cfg.d)
