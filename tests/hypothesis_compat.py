"""Degrade gracefully when `hypothesis` (requirements-dev.txt) is absent.

Importing this module never fails: with hypothesis installed it re-exports
the real `given` / `settings` / `st`; without it, `@given(...)` turns the
test into a skip (equivalent to `pytest.importorskip` scoped to just the
property tests, so the rest of the module still runs).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stub for `strategies`: every strategy constructor returns None
        (only ever consumed by the stub `given` below)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            # deliberately NOT functools.wraps: the stub must expose a
            # zero-arg signature or pytest treats the hypothesis-supplied
            # params as fixtures
            def stub():
                pytest.skip("hypothesis not installed "
                            "(see requirements-dev.txt)")
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco
