"""QINCo2 encode/decode invariants (paper §3.2) + hypothesis properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, rq, training
from repro.models.common import init_params

from conftest import clustered


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    x = clustered(rng, 2048, 16)
    cfg = tiny()
    params = training.init_qinco2(jax.random.key(0), x, cfg)
    return cfg, params, jnp.asarray(x)


def _mse(params, x, cfg, A, B):
    return float(enc.reconstruction_mse(params, x, cfg, A, B))


def test_encode_decode_consistency(setup):
    """decode(params, codes) must equal the encoder's xhat."""
    cfg, params, x = setup
    codes, xhat, _ = enc.encode(params, x[:128], cfg, A=4, B=4)
    recon = qinco.decode(params, codes, cfg)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(xhat),
                               rtol=1e-4, atol=1e-4)


def test_beam_monotone(setup):
    """Larger beams never hurt (Fig. S5): MSE(B=8) <= MSE(B=2) <= MSE(B=1)."""
    cfg, params, x = setup
    m1 = _mse(params, x[:512], cfg, 8, 1)
    m2 = _mse(params, x[:512], cfg, 8, 2)
    m8 = _mse(params, x[:512], cfg, 8, 8)
    assert m2 <= m1 + 1e-5
    assert m8 <= m2 + 1e-5


def test_preselection_approximates_exhaustive(setup):
    """A=K is exhaustive; small A should degrade gracefully (Fig. S4)."""
    cfg, params, x = setup
    exhaustive = _mse(params, x[:512], cfg, cfg.K, 1)
    a_half = _mse(params, x[:512], cfg, cfg.K // 2, 1)
    a_quarter = _mse(params, x[:512], cfg, cfg.K // 4, 1)
    assert exhaustive <= a_half + 1e-5
    assert a_half <= a_quarter + 1e-5


def test_dynamic_rates_monotone(setup):
    """MSE after m steps decreases with m (Fig. S3)."""
    cfg, params, x = setup
    codes, _, _ = enc.encode(params, x[:256], cfg, A=8, B=4)
    traj = qinco.decode_partial(params, codes, cfg)        # (N, M, d)
    errs = jnp.mean(jnp.sum((x[:256, None] - traj) ** 2, -1), axis=0)
    assert bool(jnp.all(errs[1:] <= errs[:-1] + 1e-5))


def test_train_forward_differentiable(setup):
    cfg, params, x = setup
    codes, _, _ = enc.encode(params, x[:64], cfg, A=4, B=2)
    (loss, _), grads = jax.value_and_grad(
        lambda p: enc.train_forward(p, x[:64], codes, cfg),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_training_improves_over_rq():
    rng = np.random.default_rng(1)
    x = clustered(rng, 3072, 16)
    cfg = tiny(epochs=4)
    cbs = rq.rq_train(jax.random.key(0), jnp.asarray(x[:2048]), cfg.M,
                      cfg.K, 15)
    _, xhat = rq.rq_encode(cbs, jnp.asarray(x[2048:]), B=1)
    rq_mse = float(jnp.mean(jnp.sum((x[2048:] - np.asarray(xhat)) ** 2, -1)))
    params, hist = training.train(jax.random.key(1), x[:2048], cfg,
                                  x_val=x[2048:], verbose=False)
    assert hist[-1]["val_mse"] < rq_mse


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(0, 1000))
def test_beam_monotone_property(beam, seed):
    """Hypothesis: for random data/params, B+1 beams never lose to B."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    cfg = tiny(d=8, M=3, K=8, de=8, dh=8, L=1)
    params = init_params(qinco.param_specs(cfg), jax.random.key(seed))
    m_small = float(enc.reconstruction_mse(params, x, cfg, 4, beam))
    m_big = float(enc.reconstruction_mse(params, x, cfg, 4, beam + 1))
    assert m_big <= m_small + 1e-4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_decode_is_deterministic(seed):
    rng = np.random.default_rng(seed)
    cfg = tiny(d=8, M=3, K=8, de=8, dh=8, L=1)
    params = init_params(qinco.param_specs(cfg), jax.random.key(seed))
    codes = jnp.asarray(rng.integers(0, cfg.K, size=(32, cfg.M))
                        .astype(np.int32))
    r1 = qinco.decode(params, codes, cfg)
    r2 = qinco.decode(params, codes, cfg)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_preselection_with_neural_g():
    """L_s >= 1: the neural pre-selector path (paper Fig. 4-left)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(128, 8)).astype(np.float32))
    cfg = tiny(d=8, M=3, K=8, de=8, dh=8, L=1, Ls=1)
    params = init_params(qinco.param_specs(cfg), jax.random.key(0))
    assert "g" in params
    codes, xhat, mse = enc.encode(params, x, cfg, A=4, B=2)
    assert codes.shape == (128, 3)
    assert np.isfinite(float(mse))
    recon = qinco.decode(params, codes, cfg)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(xhat),
                               rtol=1e-4, atol=1e-4)


def test_sp_decode_merge_exact():
    """Sequence-parallel softmax merge == monolithic attention (long_500k)."""
    from repro.parallel.collectives import sp_decode_merge
    rng = np.random.default_rng(0)
    H, T, D, shards = 4, 64, 8, 4
    q = jnp.asarray(rng.normal(size=(H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    s = q @ k.T                                       # (H, T)
    ref = jax.nn.softmax(s, -1) @ v
    # emulate the per-shard partials + merge math (pure-fn form)
    tl = T // shards
    ms, ds, accs = [], [], []
    for i in range(shards):
        sl = s[:, i * tl:(i + 1) * tl]
        m = jnp.max(sl, -1)
        p = jnp.exp(sl - m[:, None])
        ms.append(m); ds.append(jnp.sum(p, -1))
        accs.append(p @ v[i * tl:(i + 1) * tl])
    m_glob = jnp.max(jnp.stack(ms), 0)
    corr = [jnp.exp(m - m_glob) for m in ms]
    denom = sum(d * c for d, c in zip(ds, corr))
    acc = sum(a * c[:, None] for a, c in zip(accs, corr))
    out = acc / denom[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
