"""repro: QINCo2 (ICLR'25) vector compression + search, and a multi-pod
JAX training/serving substrate for the assigned architecture pool."""
__version__ = "1.0.0"
