"""Unified telemetry: metrics registry, per-query tracing, exporters.

The observability layer for the serving/search/build pipeline
(docs/OBSERVABILITY.md). Three pieces, one import:

    metrics.py   process-global `MetricsRegistry` of counters, gauges,
                 and fixed-bucket histograms — cheap thread-safe
                 increments, label support, and a TRUE no-op mode
                 (`obs.disable()`: mutators return on one flag check;
                 search results are bitwise unchanged either way).
    tracing.py   `span()` / `query_trace()` structured stage timing
                 with jit-aware fencing (`block_until_ready` at span
                 boundaries ONLY while tracing is on) and an optional
                 `jax.profiler.trace` deep-dive hook. Off by default.
    export.py    Prometheus text + JSON snapshot renderers and the
                 `start_metrics_server` scrape endpoint
                 (`serve_search --metrics-port`).

Typical instrumentation site:

    from repro import obs
    _STAGED = obs.counter("staging_staged_total", "shards staged")
    ...
    _STAGED.inc()
    with obs.span("search/fold") as sp:
        state = fold(...)
        sp.fence(state)          # device-honest timing when tracing on

Metrics default ON (per-shard/per-batch counters; the bench gate pins
the cost at unmeasurable), tracing defaults OFF (fencing serializes the
prefetch pipeline by design — see docs/KERNELS.md).
"""
from repro.obs import export, metrics, tracing  # noqa: F401
from repro.obs.export import (MetricsServer, render_prometheus,  # noqa: F401
                              series_value, snapshot, snapshot_delta,
                              start_metrics_server)
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS,  # noqa: F401
                               REGISTRY, MetricsRegistry, exp_buckets)
from repro.obs.tracing import (Span, query_trace, recent_traces,  # noqa: F401
                               span, tracing as tracing_scope)

# registry conveniences bound to the process-global default registry
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
get_metric = REGISTRY.get
reset = REGISTRY.reset


def enable() -> None:
    """Turn metric collection on (the default state)."""
    REGISTRY.enable()


def disable() -> None:
    """True no-op mode: metric mutators return on one flag check, no
    locks, no allocation; values freeze at their current state."""
    REGISTRY.disable()


def enabled() -> bool:
    return REGISTRY.enabled


enable_tracing = tracing.enable
disable_tracing = tracing.disable
tracing_enabled = tracing.enabled
