"""Exporters: Prometheus text format, JSON snapshot, HTTP endpoint.

Stdlib only. Three consumers, three shapes:

  - `render_prometheus(registry)` — the text exposition format
    (`# TYPE` headers, `_bucket{le=...}` cumulative histogram series)
    a Prometheus scraper ingests; served at ``/metrics``.
  - `snapshot(registry)` — a plain JSON-able dict (schema below) that
    benchmarks and CI consume programmatically; served at
    ``/metrics.json``. `snapshot_delta(a, b)` subtracts counter /
    histogram state so a caller can attribute activity to one window
    (how BENCH_search.json rows carry per-row staging deltas).
  - `start_metrics_server(port)` — a daemon-threaded
    `http.server` exposing both, plus ``/traces.json`` (the recent
    per-query trace ring from `repro.obs.tracing`). Port 0 binds an
    ephemeral port (tests / CI); `.port` is the bound port and
    `.close()` shuts it down. This is what
    ``serve_search --metrics-port`` starts.

Snapshot schema (stable; tests pin it):

    {"enabled": bool,
     "metrics": {name: {"type": "counter"|"gauge"|"histogram",
                        "help": str,
                        "series": [{"labels": {k: v},    # {} = unlabeled
                                    "value": float}      # counter/gauge
                                   | {"labels": {...},   # histogram
                                      "buckets": [[ub, count], ...],
                                      "sum": float, "count": int}]}}}
"""
from __future__ import annotations

import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs import tracing as _tracing
from repro.obs.metrics import (REGISTRY, MetricsRegistry, _HistogramSeries)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labelstr(kv, extra=()) -> str:
    items = list(kv) + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def render_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """The Prometheus text exposition of every declared series."""
    reg = registry or REGISTRY
    out = []
    for m in reg.metrics():
        series = m.series()
        if not series:
            continue
        if m.help:
            out.append(f"# HELP {m.name} {m.help}")
        out.append(f"# TYPE {m.name} {m.type}")
        for s in series:
            if isinstance(s, _HistogramSeries):
                snap = s.collect()
                acc = 0
                for ub, c in zip(list(s.bounds) + [math.inf],
                                 snap["counts"]):
                    acc += c
                    out.append(f"{m.name}_bucket"
                               f"{_labelstr(s.labels_kv, [('le', _fmt(ub))])}"
                               f" {acc}")
                out.append(f"{m.name}_sum{_labelstr(s.labels_kv)} "
                           f"{_fmt(snap['sum'])}")
                out.append(f"{m.name}_count{_labelstr(s.labels_kv)} "
                           f"{snap['count']}")
            else:
                out.append(f"{m.name}{_labelstr(s.labels_kv)} "
                           f"{_fmt(s.value)}")
    return "\n".join(out) + "\n"


def snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    """JSON-able snapshot of the registry (schema in the module doc)."""
    reg = registry or REGISTRY
    metrics = {}
    for m in reg.metrics():
        series = []
        for s in m.series():
            labels = dict(s.labels_kv)
            if isinstance(s, _HistogramSeries):
                snap = s.collect()
                series.append({
                    "labels": labels,
                    "buckets": [[ub, c] for ub, c in
                                zip(list(s.bounds) + [math.inf],
                                    snap["counts"])],
                    "sum": snap["sum"], "count": snap["count"]})
            else:
                series.append({"labels": labels, "value": s.value})
        if series:
            metrics[m.name] = {"type": m.type, "help": m.help,
                               "series": series}
    return {"enabled": reg.enabled, "metrics": metrics}


def snapshot_delta(before: dict, after: dict) -> dict:
    """``after - before`` for every monotone series (counters and
    histograms; gauges pass through as their ``after`` value). Series
    new in ``after`` keep their full value. The windowing primitive for
    attributing metric movement to one benchmark rep / serve stream."""
    def _series_key(s):
        return tuple(sorted(s["labels"].items()))

    out = {"enabled": after["enabled"], "metrics": {}}
    for name, ma in after["metrics"].items():
        mb = before["metrics"].get(name)
        prior = ({_series_key(s): s for s in mb["series"]}
                 if mb and mb["type"] == ma["type"] else {})
        series = []
        for s in ma["series"]:
            p = prior.get(_series_key(s))
            if ma["type"] == "histogram":
                if p is None:
                    series.append(dict(s))
                    continue
                pc = {ub: c for ub, c in p["buckets"]}
                series.append({
                    "labels": s["labels"],
                    "buckets": [[ub, c - pc.get(ub, 0)]
                                for ub, c in s["buckets"]],
                    "sum": s["sum"] - p["sum"],
                    "count": s["count"] - p["count"]})
            elif ma["type"] == "counter":
                series.append({"labels": s["labels"],
                               "value": s["value"]
                               - (p["value"] if p else 0.0)})
            else:                                    # gauge: last value
                series.append(dict(s))
        out["metrics"][name] = {"type": ma["type"], "help": ma["help"],
                                "series": series}
    return out


def series_value(snap: dict, name: str, **labels) -> float:
    """Sum of a counter/gauge's series matching ``labels`` (subset
    match; no labels = every series) in a `snapshot()` dict. The
    convenience CI and benchmarks assert against."""
    m = snap["metrics"].get(name)
    if m is None:
        return 0.0
    want = {k: str(v) for k, v in labels.items()}
    return sum(s["value"] for s in m["series"]
               if all(s["labels"].get(k) == v for k, v in want.items()))


# ---------------------------------------------------------------------------
# the endpoint
# ---------------------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry = None      # set per-server via subclass dict
    routes: dict = {}                     # path -> fn() -> (code, ct, body)

    def do_GET(self):                                     # noqa: N802
        path = self.path.split("?")[0]
        route = self.routes.get(path)
        if route is not None:
            # extra routes (health/readiness probes): the dict is shared
            # with the owning MetricsServer, so `add_route` after start
            # is visible immediately
            code, ctype, body = route()
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
            return
        if path in ("/metrics", "/"):
            body = render_prometheus(self.registry).encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/metrics.json":
            body = json.dumps(snapshot(self.registry)).encode()
            ctype = "application/json"
        elif path == "/traces.json":
            body = json.dumps(_tracing.recent_traces()).encode()
            ctype = "application/json"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):                         # quiet
        pass


class MetricsServer:
    """A daemon-threaded scrape endpoint over one registry."""

    def __init__(self, port: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1"):
        reg = registry or REGISTRY
        self._routes: dict = {}
        handler = type("_BoundHandler", (_Handler,),
                       {"registry": reg, "routes": self._routes})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def add_route(self, path: str, fn) -> None:
        """Register an extra GET route: ``fn() -> (status_code, content_
        type, body_bytes)``. How the search front door hangs its
        ``/healthz`` / ``/readyz`` probes off the existing obs endpoint
        instead of opening another port (docs/SERVING.md)."""
        self._routes[path] = fn

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()


def start_metrics_server(port: int = 0,
                         registry: Optional[MetricsRegistry] = None
                         ) -> MetricsServer:
    """Serve ``/metrics`` (Prometheus text), ``/metrics.json`` and
    ``/traces.json`` on ``port`` (0 = ephemeral; see `.port`)."""
    return MetricsServer(port, registry)
