"""Structured per-query tracing: spans over pipeline stages.

A *span* times one stage of a host-driven pipeline (the probe /
schedule / acquire / fold / rerank stages of `search_sharded`, the
admission / batch / dispatch stages of `SearchServer`). A *query trace*
groups the spans of one query (or micro-batch) into an ordered record.

Tracing is OFF by default and costs one module-flag check per span when
off — the serving hot path stays unperturbed. The interesting part is
what happens when it is ON:

  **jit-aware fencing.** Wall-clocking a stage that ends in an async
  jax dispatch measures only the host-side enqueue — the device work
  lands in whichever later stage happens to block first, so per-stage
  numbers lie. A span therefore accepts a *fence*: the arrays the stage
  produced (`span.fence(*arrays)`), on which it calls
  `jax.block_until_ready` at span exit — but ONLY while tracing is
  enabled. The traced path measures honest device-inclusive stage
  times; the untraced path keeps its async pipelining bit-for-bit (the
  fence is a synchronization point, never a value change, so results
  are bitwise identical either way — tested). docs/KERNELS.md covers
  the caveat in detail: fencing serializes overlap, so traced
  *aggregate* throughput is pessimistic by exactly the overlap the
  pipeline normally hides. That is the point — the stall becomes
  attributable — but do not read traced QPS as serving QPS.

  **Stage histograms.** Every span duration lands in the registry as
  `<family>_stage_seconds{stage=<name>}` where the span name is
  `"<family>/<stage>"` (`"search/probe"`, `"serve/dispatch"`), so the
  Prometheus endpoint exposes per-stage latency distributions without
  any per-query storage.

  **Recent-trace ring.** Completed query traces (name, per-span
  offsets/durations, metadata) land in a bounded ring buffer —
  `recent_traces()` — which the JSON exporter serves for "why was THIS
  query slow" forensics at O(ring) memory.

  **Deep-dive hook.** `enable(profile_dir=...)` additionally starts
  `jax.profiler.trace` into that directory and wraps every span in a
  `jax.profiler.TraceAnnotation`, so spans line up with device timelines
  in TensorBoard/Perfetto. Purely optional; plain tracing never imports
  the profiler machinery.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

from repro.obs import metrics as _metrics

_TRACE_RING_LEN = 64

_lock = threading.Lock()
_enabled = False
_profile_dir: Optional[str] = None
_recent: "deque[dict]" = deque(maxlen=_TRACE_RING_LEN)
_tls = threading.local()


def enabled() -> bool:
    return _enabled


def enable(profile_dir: Optional[str] = None) -> None:
    """Turn span timing on (and, with ``profile_dir``, start a
    `jax.profiler.trace` capture that spans annotate into)."""
    global _enabled, _profile_dir
    with _lock:
        if profile_dir is not None and _profile_dir is None:
            import jax
            jax.profiler.start_trace(profile_dir)
            _profile_dir = profile_dir
        _enabled = True


def disable() -> None:
    global _enabled, _profile_dir
    with _lock:
        if _profile_dir is not None:
            import jax
            jax.profiler.stop_trace()
            _profile_dir = None
        _enabled = False


@contextmanager
def tracing(profile_dir: Optional[str] = None):
    """Scoped enable: `with obs.tracing(): ...` (restores prior state)."""
    was = _enabled
    enable(profile_dir)
    try:
        yield
    finally:
        if not was:
            disable()


def recent_traces() -> list:
    """Most-recent completed query traces, oldest first (bounded ring)."""
    with _lock:
        return list(_recent)


class Span:
    """One live stage timing. `fence(*arrays)` registers device values
    to `jax.block_until_ready` at exit, so the recorded duration
    includes the stage's device work instead of just its dispatch."""

    __slots__ = ("name", "t0", "_fence")

    def __init__(self, name: str):
        self.name = name
        self.t0 = time.perf_counter()
        self._fence = None

    def fence(self, *arrays) -> None:
        self._fence = arrays


class _NullSpan:
    """The disabled-path span: every method a no-op (shared singleton,
    so `span()` allocates nothing when tracing is off)."""

    __slots__ = ()

    def fence(self, *arrays) -> None:
        pass


_NULL_SPAN = _NullSpan()


def _family_stage(name: str):
    fam, _, stage = name.partition("/")
    return (fam, stage) if stage else ("span", fam)


@contextmanager
def span(name: str, registry: Optional[_metrics.MetricsRegistry] = None):
    """Time one pipeline stage. ``name`` is `"<family>/<stage>"`; the
    duration lands in `<family>_stage_seconds{stage=<stage>}` and in the
    enclosing `query_trace` (if any). No-op (one flag check, shared
    null span) while tracing is disabled."""
    if not _enabled:
        yield _NULL_SPAN
        return
    reg = registry or _metrics.REGISTRY
    ann = None
    if _profile_dir is not None:
        import jax
        ann = jax.profiler.TraceAnnotation(name)
        ann.__enter__()
    sp = Span(name)
    try:
        yield sp
    finally:
        if sp._fence is not None:
            import jax
            jax.block_until_ready(sp._fence)
        dt = time.perf_counter() - sp.t0
        if ann is not None:
            ann.__exit__(None, None, None)
        fam, stage = _family_stage(name)
        reg.histogram(f"{fam}_stage_seconds",
                      "span durations by pipeline stage"
                      ).labels(stage=stage).observe(dt)
        qt = getattr(_tls, "trace", None)
        if qt is not None:
            qt.spans.append({"stage": name,
                             "start_s": round(sp.t0 - qt.t0, 9),
                             "dur_s": round(dt, 9)})


class QueryTrace:
    """Ordered span record for one query / micro-batch."""

    __slots__ = ("name", "meta", "t0", "spans", "total_s")

    def __init__(self, name: str, meta: dict):
        self.name = name
        self.meta = meta
        self.t0 = time.perf_counter()
        self.spans = []
        self.total_s = 0.0

    def to_dict(self) -> dict:
        return {"name": self.name, "total_s": round(self.total_s, 9),
                "spans": self.spans, **({"meta": self.meta}
                                        if self.meta else {})}


class _NullTrace:
    __slots__ = ()
    name = None
    spans = ()
    total_s = 0.0


_NULL_TRACE = _NullTrace()


@contextmanager
def query_trace(name: str = "query", **meta):
    """Group the spans opened inside into one per-query record, pushed
    to the recent-trace ring at exit. Nesting restores the outer trace.
    No-op while tracing is disabled."""
    if not _enabled:
        yield _NULL_TRACE
        return
    qt = QueryTrace(name, meta)
    prev = getattr(_tls, "trace", None)
    _tls.trace = qt
    try:
        yield qt
    finally:
        _tls.trace = prev
        qt.total_s = time.perf_counter() - qt.t0
        with _lock:
            _recent.append(qt.to_dict())
