"""Process-global metrics registry: counters, gauges, histograms.

Dependency-free (stdlib only) and cheap by construction:

  - **No-op mode is a real guarantee**: every mutator (`inc`/`set`/
    `observe`) checks one registry flag and returns before touching a
    lock when the registry is disabled — a handful of ns, no allocation,
    no lock traffic, and (because metrics never feed back into any
    computation) search results are bitwise identical either way. The
    default registry ships ENABLED: the counters on the serving path are
    per-shard / per-batch, not per-element, so always-on costs nothing
    measurable (asserted by the bench-regression gate); `disable()` is
    the belt-and-braces escape for overhead-critical runs.
  - **Thread-safe increments**: one `threading.Lock` per metric series,
    taken only when enabled. Metric *creation* is serialized by a
    registry lock and get-or-create idempotent, so modules can declare
    their metrics at call sites without import-order coupling.
  - **Labels without cardinality machinery**: `metric.labels(pool="3")`
    returns a child series (cached per label set) sharing the parent's
    name/type — how per-`StagingPool` counters coexist in one registry.
    Keep label sets tiny and bounded (pool ids, stage names); there is
    deliberately no eviction.
  - **Fixed-bucket histograms**: log-spaced upper bounds chosen at
    declaration (`exp_buckets`), O(len(buckets)) memory forever, with
    quantile estimates interpolated from the bucket counts —
    `ServeStats` p50/p99 derive from these, not from an unbounded
    per-query latency array. `collect()` snapshots support windowed
    (per-run) quantiles via ``since=``.

Naming scheme (docs/OBSERVABILITY.md): `<subsystem>_<what>_<unit>`,
counters end in `_total` (`staging_staged_total`,
`build_rows_total`), durations are `_seconds` floats
(`staging_stall_seconds_total`, `serve_latency_seconds`). The
Prometheus/JSON renderers live in `repro.obs.export`.
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple


def exp_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """``count`` log-spaced finite upper bounds from ``start``; the
    implicit +inf bucket is appended by `Histogram` itself."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


# 100 us .. ~80 s in x1.3 steps: per-query serving latencies and
# per-stage span durations both land mid-range, so interpolated
# p50/p99 carry ~±15% bucket resolution.
DEFAULT_TIME_BUCKETS = exp_buckets(1e-4, 1.3, 52)


class _Series:
    """One (metric, label set) time series. Mutators bail out on the
    registry flag BEFORE taking the lock — the no-op-mode contract."""

    __slots__ = ("_reg", "_lock", "labels_kv", "_value")

    def __init__(self, reg: "MetricsRegistry", labels_kv: Tuple):
        self._reg = reg
        self._lock = threading.Lock()
        self.labels_kv = labels_kv
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value


class _CounterSeries(_Series):
    __slots__ = ()

    def inc(self, amount: float = 1) -> None:
        if not self._reg._enabled:
            return
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount


class _GaugeSeries(_Series):
    __slots__ = ()

    def set(self, value: float) -> None:
        if not self._reg._enabled:
            return
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1) -> None:
        if not self._reg._enabled:
            return
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1) -> None:
        self.inc(-amount)


class _HistogramSeries(_Series):
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, reg, labels_kv, bounds: Tuple[float, ...]):
        super().__init__(reg, labels_kv)
        self.bounds = bounds                    # finite ubs; +inf implicit
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if not self._reg._enabled:
            return
        i = bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self.sum += value
            self.count += 1

    def collect(self) -> dict:
        """Point-in-time snapshot (counts copied), usable as a window
        start for `quantile(..., since=)`."""
        with self._lock:
            return {"counts": list(self.counts), "sum": self.sum,
                    "count": self.count}

    def quantile(self, q: float, *, since: Optional[dict] = None) -> float:
        """Interpolated q-quantile from the bucket counts (Prometheus
        `histogram_quantile` semantics: linear within the landing
        bucket, the last finite bound for the +inf bucket, 0.0 for an
        empty window). With ``since`` (an earlier `collect()`), the
        quantile of only the observations recorded in between."""
        cur = self.collect()
        counts = cur["counts"]
        if since is not None:
            counts = [c - s for c, s in zip(counts, since["counts"])]
        total = sum(counts)
        if total <= 0:
            return 0.0
        target = q * total
        acc = 0.0
        for i, c in enumerate(counts):
            acc += c
            if acc >= target and c > 0:
                if i >= len(self.bounds):       # +inf bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i else 0.0
                frac = 1.0 - (acc - target) / c
                return lo + (self.bounds[i] - lo) * frac
        return self.bounds[-1]


_SERIES_CLS = {"counter": _CounterSeries, "gauge": _GaugeSeries}


class Metric:
    """A named metric = an unlabeled default series + labeled children.

    Calling a mutator on the metric itself drives the unlabeled series;
    `labels(**kv)` returns (and caches) the child for one label set.
    """

    __slots__ = ("name", "type", "help", "_reg", "_buckets", "_default",
                 "_children", "_lock")

    def __init__(self, reg: "MetricsRegistry", name: str, mtype: str,
                 help: str = "", buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.type = mtype
        self.help = help
        self._reg = reg
        self._buckets = tuple(buckets) if buckets else None
        self._lock = threading.Lock()
        self._children: Dict[Tuple, _Series] = {}
        self._default: Optional[_Series] = None

    def _make(self, labels_kv: Tuple) -> _Series:
        if self.type == "histogram":
            return _HistogramSeries(self._reg, labels_kv, self._buckets)
        return _SERIES_CLS[self.type](self._reg, labels_kv)

    def labels(self, **kv) -> _Series:
        key = tuple(sorted((k, str(v)) for k, v in kv.items()))
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make(key))
        return child

    def _default_series(self) -> _Series:
        if self._default is None:
            with self._lock:
                if self._default is None:
                    self._default = self._make(())
        return self._default

    def series(self) -> List[_Series]:
        """Every live series, unlabeled first (for exporters)."""
        out = [self._default] if self._default is not None else []
        return out + [self._children[k] for k in sorted(self._children)]

    # unlabeled-series conveniences ------------------------------------------
    def inc(self, amount: float = 1) -> None:
        self._default_series().inc(amount)

    def set(self, value: float) -> None:
        self._default_series().set(value)

    def dec(self, amount: float = 1) -> None:
        self._default_series().dec(amount)

    def observe(self, value: float) -> None:
        self._default_series().observe(value)

    def quantile(self, q: float, *, since: Optional[dict] = None) -> float:
        return self._default_series().quantile(q, since=since)

    def collect(self) -> dict:
        return self._default_series().collect()

    @property
    def value(self) -> float:
        return self._default_series().value


class MetricsRegistry:
    """Get-or-create registry of `Metric`s with one enable flag.

    ``enabled=False`` is the true no-op mode: mutators return on the
    flag check, values freeze, exporters render the frozen state.
    """

    def __init__(self, enabled: bool = True):
        self._enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    # -- lifecycle -----------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    # -- declaration (get-or-create, type-checked) ---------------------------

    def _get(self, name: str, mtype: str, help: str = "",
             buckets: Optional[Sequence[float]] = None) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = Metric(self, name, mtype, help, buckets)
                    self._metrics[name] = m
        if m.type != mtype:
            raise TypeError(f"metric {name!r} is a {m.type}, not a {mtype}")
        return m

    def counter(self, name: str, help: str = "") -> Metric:
        if not name.endswith("_total"):
            raise ValueError(f"counter {name!r} must end in '_total' "
                             f"(naming scheme, docs/OBSERVABILITY.md)")
        return self._get(name, "counter", help)

    def gauge(self, name: str, help: str = "") -> Metric:
        return self._get(name, "gauge", help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> Metric:
        return self._get(name, "histogram", help, buckets)

    # -- introspection -------------------------------------------------------

    def metrics(self) -> List[Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def reset(self) -> None:
        """Zero every series (tests; keeps the declared metric objects —
        and any handles modules already hold — valid)."""
        for m in self.metrics():
            for s in m.series():
                with s._lock:
                    if isinstance(s, _HistogramSeries):
                        s.counts = [0] * len(s.counts)
                        s.sum = 0.0
                        s.count = 0
                    else:
                        s._value = 0.0


# The process-global default registry. Modules grab handles through
# `repro.obs.counter/gauge/histogram` (see __init__.py) so one scrape
# endpoint sees the whole process.
REGISTRY = MetricsRegistry(enabled=True)
