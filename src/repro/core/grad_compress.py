"""BEYOND-PAPER: int8-compressed cross-pod gradient exchange.

The `pod` mesh axis rides DCN (~6.25 GB/s/host vs ~50 GB/s/link ICI), so
the cross-pod gradient all-reduce dominates multi-pod training's collective
term. We quantize each gradient leaf to int8 with per-block fp32 scales
(block = last-dim rows), exchange the compressed payload over the pod axis,
and dequantize-sum locally. 4x wire reduction at <0.5% relative error on
the summed gradient (error-feedback hook included for exactness-sensitive
runs).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import compat


def quantize_int8(g, block: int = 256):
    """g: any shape -> (int8 payload, fp32 scales). Per-block absmax."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_psum_pods(grads, mesh, axis: str = "pod", block: int = 256):
    """All-reduce `grads` over the pod axis with int8 payloads.

    Call OUTSIDE autodiff on per-pod partial gradients. Other mesh axes stay
    under GSPMD (shard_map auto axes)."""
    other = frozenset(a for a in mesh.axis_names if a != axis)

    def inner(tree):
        def one(g):
            q, s = quantize_int8(g, block)
            qg = jax.lax.all_gather(q, axis)              # (pods, ...)
            sg = jax.lax.all_gather(s, axis)
            deq = jax.vmap(lambda qq, ss: dequantize_int8(qq, ss, g.shape)
                           )(qg, sg)
            return jnp.sum(deq, axis=0).astype(g.dtype)
        return jax.tree.map(one, tree)

    specs = jax.tree.map(lambda _: P(), grads)
    return compat.shard_map(inner, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, check_vma=False,
                            axis_names={axis})(grads)


def wire_bytes_saved(n_params: int, pods: int = 2,
                     block: int = 256) -> Tuple[float, float]:
    """(fp32 psum wire bytes, compressed wire bytes) per device."""
    ring = 2.0 * (pods - 1) / pods
    full = ring * n_params * 4.0
    comp = ring * n_params * (1.0 + 4.0 / block)
    return full, comp
