"""Large-scale nearest-neighbor search cascade (paper §3.3, Fig. 3):

    IVF probe -> ADC (unitary AQ/RQ LUT) shortlist S_AQ
              -> pairwise-decoder shortlist S_pairs
              -> full QINCo2 neural re-ranking.

All candidate scoring goes through the `kernels/ops` dispatch facade
(`ops.adc_scores` / `ops.pairwise_scores` — the one-hot MXU forms) rather
than per-byte LUT gathers; the IVF-centroid inner-product term is folded in
as an extra ADC codebook so the whole step-2 scan is ONE `adc_scores` call.

The distributed variant shards the database over the `model` mesh axis and
runs the *identical* per-shard kernel path (shared-codes `ops.adc_scores`)
followed by `collectives.distributed_topk` — the billion-scale layout
exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.qinco2 import QincoConfig
from repro.core import aq as aq_mod
from repro.core import ivf as ivf_mod
from repro.core import pairwise as pw_mod
from repro.core import qinco
from repro.kernels import ops

# Out-of-core search telemetry (docs/OBSERVABILITY.md). The fully-jitted
# resident `search()` is one opaque executable — its stage split lives in
# the compiled computation and is profiled via `obs.tracing.enable(
# profile_dir=...)`; the host-driven `search_sharded` loop is where
# per-stage spans (probe/schedule/acquire/fold/rerank) attach.
_C_SEARCH_CALLS = obs.counter(
    "search_sharded_calls_total", "search_sharded invocations")
_C_SEARCH_QUERIES = obs.counter(
    "search_queries_total", "queries answered by search_sharded")
_C_SHARDS_FOLDED = obs.counter(
    "search_shards_folded_total", "per-shard shortlist+merge folds run")
_C_SHARD_ERRORS = obs.counter(
    "search_shard_errors_total",
    "scheduled shards skipped on acquire/integrity errors "
    "(on_shard_error='skip')")
_C_EJECTED = obs.counter(
    "search_deadline_ejected_shards_total",
    "scheduled shards ejected unfolded because the query deadline passed")
_C_DEGRADED = obs.counter(
    "search_degraded_queries_total",
    "queries answered with shard coverage < 1.0")
_C_TOMBSTONED = obs.counter(
    "search_tombstoned_rows_total",
    "tombstoned (deleted) rows masked inside fused per-shard scans")


@dataclasses.dataclass
class SearchIndex:
    """Everything needed at query time (built by `build_index`).

    ``codes`` is packed uint8 whenever the alphabet fits a byte (K <= 256
    — every paper setting): the packed bytes are the HBM-resident form the
    ADC kernels scan directly, 4x smaller than the historical int32. The
    scoring results are bit-identical either way (`kernels/ops` widens
    in-kernel). `repro.index.store.IndexStore` persists this layout to
    disk and round-trips it exactly.
    """
    ivf: ivf_mod.IVFIndex
    codes: jnp.ndarray                 # (N, M) uint8|int32 QINCo2 codes
    aq_books: jnp.ndarray              # (M, K, d) unitary look-up decoder
    aq_norms: jnp.ndarray              # (N,) ||xhat_aq||^2 (w/ centroid)
    pw: pw_mod.PairwiseDecoder         # pairwise decoder over [codes, I~]
    pw_norms: jnp.ndarray              # (N,)
    qinco_params: dict
    cfg: QincoConfig

    @property
    def ext_codes(self):
        """codes ++ centroid RQ codes I~ per vector: (N, M + M~) int32.

        Materializes the FULL database widened to int32 — a fit-time /
        offline-evaluation utility. The serving path (`search` step 3)
        instead gathers the shortlist rows first and widens only those,
        so the packed uint8 codes stay the HBM-resident form.
        M~ = 0 (no centroid RQ codes) degrades to the plain codes.
        """
        codes = self.codes.astype(jnp.int32)
        if self.ivf.centroid_codes is None:
            return codes
        tilde = self.ivf.centroid_codes[self.ivf.assignments]
        return jnp.concatenate([codes, tilde], axis=1)


jax.tree_util.register_dataclass(
    SearchIndex,
    data_fields=("ivf", "codes", "aq_books", "aq_norms", "pw", "pw_norms",
                 "qinco_params"),
    meta_fields=("cfg",))


def build_index(key, xb, qinco_params, cfg: QincoConfig, *, k_ivf: int = 64,
                m_tilde: int = 2, n_pair_books: int = None,
                encode_fn=None, encode_chunk: int = 4096,
                backend: str = "auto", pack: bool = True,
                verbose: bool = False) -> SearchIndex:
    """Encode the database and fit the cascade decoders.

    Database encoding runs through the chunked `encode_dataset` driver, so
    databases larger than a device batch reuse one compiled executable.
    With ``pack`` (default) codes are stored packed uint8 when K <= 256.
    """
    from repro.core import encode as enc
    from repro.index import codes as pc
    n_pair_books = n_pair_books or 2 * cfg.M
    k1, k2 = jax.random.split(key)
    ivf = ivf_mod.build_ivf(k1, xb, k_ivf, m_tilde=m_tilde, K=cfg.K)
    resid = ivf_mod.residual_to_centroid(ivf, xb, ivf.assignments)
    encode_fn = encode_fn or (lambda v: enc.encode_dataset(
        qinco_params, v, cfg, cfg.A_eval, cfg.B_eval, chunk=encode_chunk,
        backend=backend)[0])
    codes = jnp.asarray(encode_fn(resid))
    if pack and pc.packable(cfg.K):
        codes = pc.pack_codes(codes, cfg.K)

    # unitary AQ decoder on the residual codes
    aq_books = aq_mod.fit_aq(codes, resid, cfg.M, cfg.K)
    recon_aq = aq_mod.aq_decode(aq_books, codes) + ivf.centroids[
        ivf.assignments]
    aq_norms = jnp.sum(recon_aq * recon_aq, axis=-1)

    # pairwise decoder over [QINCo2 codes ++ centroid RQ codes (if any)]
    ext = codes.astype(jnp.int32)
    if ivf.centroid_codes is not None:
        ext = jnp.concatenate([ext, ivf.centroid_codes[ivf.assignments]],
                              axis=1)
    pw = pw_mod.fit_pairwise(ext, xb, cfg.K, n_pair_books, verbose=verbose)
    recon_pw = pw.decode(ext)
    pw_norms = jnp.sum(recon_pw * recon_pw, axis=-1)

    return SearchIndex(ivf=ivf, codes=codes, aq_books=aq_books,
                       aq_norms=aq_norms, pw=pw, pw_norms=pw_norms,
                       qinco_params=qinco_params, cfg=cfg)


def adc_lut_ext(aq_books, centroids, q):
    """(Q, M+1, K') LUT: the unitary AQ books plus the IVF-centroid book.

    Scoring a candidate n then reads M code columns plus its bucket id —
    the centroid inner product becomes just another ADC codebook, so step 2
    is a single `ops.adc_scores` call. K' = max(K, k_ivf); both LUT groups
    are zero-padded on the alphabet axis (padded slots are never indexed).
    The single LUT constructor for the resident AND out-of-core paths.
    """
    lut = aq_mod.adc_lut(aq_books, q)                     # (Q, M, K)
    clut = aq_mod.adc_lut(centroids[None], q)             # (Q, 1, k_ivf)
    K, k_ivf = lut.shape[2], clut.shape[2]
    Kp = max(K, k_ivf)
    lut = jnp.pad(lut, ((0, 0), (0, 0), (0, Kp - K)))
    clut = jnp.pad(clut, ((0, 0), (0, 0), (0, Kp - k_ivf)))
    return jnp.concatenate([lut, clut], axis=1)


def _adc_lut_with_centroids(index: SearchIndex, q):
    return adc_lut_ext(index.aq_books, index.ivf.centroids, q)


@partial(jax.jit, static_argnames=("n_probe", "n_short_aq", "n_short_pw",
                                   "topk", "cfg", "backend"))
def search(index: SearchIndex, q, *, n_probe: int = 4, n_short_aq: int = 64,
           n_short_pw: int = 16, topk: int = 1, cfg: QincoConfig = None,
           backend: str = "auto"):
    """Full cascade. q: (Q, d) -> (ids (Q, topk'), dists (Q, topk')).

    Shortlist sizes are clamped to what the probe can actually supply:
    ``n_short_aq`` to the candidate count of the probed buckets,
    ``n_short_pw`` to the (clamped) ``n_short_aq``, and ``topk`` to the
    (clamped) ``n_short_pw`` — a `lax.top_k` wider than its input is a
    trace-time error, and a small index must not force callers to
    hand-size every shortlist. topk' = the clamped ``topk``.
    """
    cfg = cfg or index.cfg
    # 1. IVF probe ----------------------------------------------------------
    top_b, cand, cmask = ivf_mod.probe(index.ivf, q, n_probe)
    n_short_aq = min(n_short_aq, cand.shape[1])
    n_short_pw = min(n_short_pw, n_short_aq)
    topk = min(topk, n_short_pw)
    # 2. ADC over candidates (unitary AQ LUT + centroid term) ----------------
    lut_ext = _adc_lut_with_centroids(index, q)           # (Q, M+1, K')
    codes_ext = jnp.concatenate(
        [index.codes[cand].astype(jnp.int32),
         index.ivf.assignments[cand][..., None]], axis=-1)  # (Q, C, M+1)
    score = ops.adc_scores(codes_ext, lut_ext,
                           norms=index.aq_norms[cand], backend=backend)
    score = jnp.where(cmask, score, -jnp.inf)
    s1, keep1 = jax.lax.top_k(score, n_short_aq)          # (Q, n_short_aq)
    ids1 = jnp.take_along_axis(cand, keep1, axis=1)
    # 3.+4. pairwise re-rank + full QINCo2 decode re-rank --------------------
    # gather the shortlist rows BEFORE widening: only (Q, n_short_aq, ...)
    # leaves the packed code matrix, never an (N, ...) int32 temporary.
    # The tail itself is `_rerank_shortlist` — the SAME implementation the
    # out-of-core `search_sharded` path runs, so resident == out-of-core
    # is structural for steps 3-4, not a hand-kept copy.
    return _rerank_shortlist(
        q, s1, ids1, index.codes[ids1], index.ivf.assignments[ids1],
        index.pw_norms[ids1], index.pw.codebooks,
        index.ivf.centroid_codes, index.ivf.centroids, index.qinco_params,
        n_short_pw=n_short_pw, topk=topk, cfg=cfg, backend=backend,
        pairs=index.pw.pairs, K=cfg.K)


# ---------------------------------------------------------------------------
# Out-of-core search over a ShardedIndexView (shards stay mmap'd on disk)
# ---------------------------------------------------------------------------

# Non-probed buckets get this (finite!) LUT entry instead of -inf: the
# one-hot MXU form multiplies masked entries by 0, and 0 * -inf = NaN
# where 0 * -1e30 = -0.0 leaves probed-row scores bit-identical. Any row
# in a masked bucket scores ~-2e30 — below every real candidate (so the
# per-shard top-k keeps probed rows first) — and is then post-masked to
# the exact -inf the resident path produces.
_NOT_PROBED = np.float32(-1e30)
# Merge rank for entries outside the resident candidate list (non-probed
# rows): sorts after every real position and every padding slot.
_POS_SENTINEL = np.int32(np.iinfo(np.int32).max)


@partial(jax.jit, static_argnames=("n_probe",))
def _probe_and_masked_lut(centroids, aq_books, q, n_probe: int):
    """Probed buckets + the extended ADC LUT with the centroid book
    masked to `_NOT_PROBED` outside them (the probe restriction, folded
    into the same LUT trick that folds the centroid term in)."""
    top_b = ivf_mod.probe_buckets(centroids, q, n_probe)  # (Q, P)
    lut = adc_lut_ext(aq_books, centroids, q)             # (Q, M+1, K')
    Kp = lut.shape[2]
    probed = jnp.any(jnp.arange(Kp)[None, None, :] == top_b[:, :, None],
                     axis=1)                              # (Q, K')
    lut = lut.at[:, -1, :].set(
        jnp.where(probed, lut[:, -1, :], _NOT_PROBED))
    return top_b, lut


def _shard_shortlist(ext, wbr, norms, dead, lut_masked, top_b, base, *,
                     k: int, cap: int, backend: str):
    """One shard's contribution: fused `ops.adc_topk` scan (the per-shard
    kernel the distributed path uses — the (Q, N_loc) score matrix never
    leaves VMEM) + the resident-candidate rank of every survivor.

    ``dead`` (None, or (N_loc,) bool) tombstone-masks deleted rows inside
    the same scan: `ops.adc_topk` folds `TOMBSTONE_PENALTY` into their
    norms (scoring them below every probed AND non-probed row, the same
    finite-penalty trick `_NOT_PROBED` uses), and any dead row that still
    surfaces in a starved top-k is post-masked here to the exact
    (-inf, `_POS_SENTINEL`) a rebuilt survivor store would produce.
    ``dead=None`` is the historical bit-exact path, untouched.

    Returns (vals, pos, gids), each (Q, k'): vals exactly equal the
    resident step-2 scores for probed rows and -inf otherwise; pos is
    the survivor's position in resident `search()`'s candidate array
    (probe_rank * cap + within-bucket rank, `_POS_SENTINEL` for
    non-probed rows); gids are global database ids."""
    vals, loc = ops.adc_topk(ext, lut_masked, k, norms=norms, dead=dead,
                             backend=backend)             # (Q, k')
    b_c = jnp.take(ext[:, -1].astype(jnp.int32), loc)     # survivor buckets
    hit = b_c[..., None] == top_b[:, None, :]             # (Q, k', P)
    found = jnp.any(hit, axis=-1)
    if dead is not None:
        found = jnp.logical_and(found,
                                jnp.logical_not(jnp.take(dead, loc)))
    rank = jnp.argmax(hit, axis=-1).astype(jnp.int32)     # probe rank
    pos = jnp.where(found, rank * cap + jnp.take(wbr, loc), _POS_SENTINEL)
    vals = jnp.where(found, vals, -jnp.inf)
    return vals, pos, base + loc


@partial(jax.jit, static_argnames=("k", "cap", "backend"))
def _fold_shard(vals, pos, gids, ext, wbr, norms, dead, lut_masked, top_b,
                base, *, k: int, cap: int, backend: str):
    """Shortlist one shard AND fold it into the running (Q, k) merge in a
    single jitted launch. The shard loop used to dispatch the shortlist,
    three concatenates, and the ranked merge as separate executables per
    shard; at small per-shard row counts those fixed dispatch costs — not
    the ADC math — dominated the out-of-core gap, so the whole per-shard
    step is one compiled computation (one dispatch per shard). ``dead``
    is None for all-alive shards (empty pytree — the pre-mutation trace)
    or the shard's tombstone mask."""
    from repro.parallel.collectives import merge_topk_ranked
    nv, np_, ng = _shard_shortlist(ext, wbr, norms, dead, lut_masked,
                                   top_b, base,
                                   k=k, cap=cap, backend=backend)
    return merge_topk_ranked(jnp.concatenate([vals, nv], axis=1),
                             jnp.concatenate([pos, np_], axis=1),
                             jnp.concatenate([gids, ng], axis=1), k)


@partial(jax.jit, static_argnames=("cap", "p_pad"))
def _padding_entries(top_b, bucket_fill, *, cap: int, p_pad: int):
    """Synthesized bucket-table padding slots: the resident candidate
    array pads every probed bucket to ``cap`` with (-inf, id 0) entries,
    and `lax.top_k` falls back to them (lowest position first) when the
    probe yields fewer finite candidates than the shortlist. Their
    positions are derivable from the per-bucket fill counts alone, so the
    out-of-core merge reproduces the degenerate small-probe results
    without any resident table. p_pad = min(n_short_aq, cap) slots per
    probed bucket suffice (only n_short_aq entries can ever be picked,
    and every probed bucket offers fill + padding >= p_pad entries)."""
    Q, P = top_b.shape
    fb = bucket_fill[top_b]                               # (Q, P)
    slot = fb[..., None] + jnp.arange(p_pad, dtype=jnp.int32)
    rank = jnp.arange(P, dtype=jnp.int32)[None, :, None]
    pos = jnp.where(slot < cap, rank * cap + slot, _POS_SENTINEL)
    return (jnp.full((Q, P * p_pad), -jnp.inf, jnp.float32),
            pos.reshape(Q, P * p_pad),
            jnp.zeros((Q, P * p_pad), jnp.int32))


@partial(jax.jit, static_argnames=("n_short_pw", "topk", "cfg", "backend",
                                   "pairs", "K"))
def _rerank_shortlist(q, s1, ids1, codes1, assign1, pw_norms1, pw_codebooks,
                      centroid_codes, centroids, qinco_params, *,
                      n_short_pw: int, topk: int, cfg: QincoConfig,
                      backend: str, pairs, K: int):
    """Steps 3-4 of the cascade on gathered shortlist rows: pairwise
    decoder re-rank, then the full QINCo2 decode + exact distance (the
    decode scan runs the indexed `ops.f_theta` kernel: packed uint8 code
    columns go in as kernel indices, the codebook gather + step network
    run fused per step).

    The ONE implementation of the cascade tail: resident `search()`
    feeds it device gathers against its resident arrays, out-of-core
    `search_sharded` feeds it the host rows `ShardedIndexView.
    gather_rows` pulled off the mmaps — so resident == out-of-core for
    steps 3-4 is structural, not a hand-kept copy."""
    Q = q.shape[0]
    plut = pw_mod.pairwise_lut(pw_codebooks, q)           # (Q, M', K^2)
    ext1 = codes1.astype(jnp.int32)
    if centroid_codes is not None:                        # M~ = 0 degrades
        ext1 = jnp.concatenate([ext1, centroid_codes[assign1]], axis=-1)
    score2 = ops.pairwise_scores(ext1, plut, pairs, K,
                                 norms=pw_norms1, backend=backend)
    score2 = jnp.where(s1 > -jnp.inf, score2, -jnp.inf)
    _, keep2 = jax.lax.top_k(score2, n_short_pw)
    ids2 = jnp.take_along_axis(ids1, keep2, axis=1)       # (Q, n_short_pw)
    codes2 = jnp.take_along_axis(codes1, keep2[..., None], axis=1)
    assign2 = jnp.take_along_axis(assign1, keep2, axis=1)
    recon = qinco.decode(qinco_params,
                         codes2.reshape(-1, codes2.shape[-1]), cfg,
                         backend=backend)
    recon = recon + centroids[assign2.reshape(-1)]
    recon = recon.reshape(Q, n_short_pw, -1)
    d2 = jnp.sum(jnp.square(q[:, None, :] - recon), axis=-1)
    dtop, ktop = jax.lax.top_k(-d2, topk)
    return jnp.take_along_axis(ids2, ktop, axis=1), -dtop


def search_sharded(view, q, *, n_probe: int = 4, n_short_aq: int = 64,
                   n_short_pw: int = 16, topk: int = 1,
                   cfg: QincoConfig = None, backend: str = "auto",
                   prefetch: bool = True,
                   deadline_s: Optional[float] = None,
                   t_start_s: Optional[float] = None,
                   on_shard_error: str = "raise",
                   return_coverage: bool = False):
    """Out-of-core cascade over a `ShardedIndexView` — bit-identical
    (indices AND scores) to resident `search()` on the same store.

    Structure: one probe + masked-LUT launch, then a scan over the
    shards `view.schedule_shards` selects — shards with zero probed
    buckets are skipped outright, the rest ordered resident-first — each
    staged through the view's `StagingPool` and folded into the running
    (Q, n_short_aq) merge by `_fold_shard` (fused `ops.adc_topk`
    shortlist + `collectives.merge_topk_ranked`, ONE jitted dispatch per
    shard). With ``prefetch`` (the default, and the path `serve_search
    --out-of-core` uses) shard s+1 is staged by the pool's background
    worker — host `ext` assembly + async `device_put` — while shard s is
    being scanned, so the mmap->device copy leaves the critical path;
    eviction for the prefetched shard is decided at issue time, keeping
    the LRU budget bound intact at allocation. Then ONE host gather of
    only the merged shortlist rows feeds the pairwise and `ops.f_theta`
    re-rank stages. Peak device residency is the pool budget plus
    O(Q * shortlist); the (N, ...) arrays never leave their mmaps.

    Bit-identity argument: per-shard `adc_topk` values equal the resident
    step-2 scores (same `score_tile`/gather scoring, probe restriction
    folded into the LUT leaves probed entries untouched), and the merge
    ranks every candidate by its position in the resident candidate
    array (probe-rank major / bucket slot minor, synthesized padding
    included) so `lax.top_k` tie-breaking matches exactly — which also
    makes scan order (and shard skipping) irrelevant: a skipped shard
    could only contribute (-inf, `_POS_SENTINEL`) entries, and the
    synthesized padding already supplies >= n_short_aq entries with
    better (finite) ranks, so sentinel entries never reach the final
    shortlist. The initial all-sentinel merge state is inert for the
    same reason. One caveat is out of scope: a float-exact score tie
    between rows of DIFFERENT buckets inside one shard is kept/dropped
    at the per-shard k boundary in id order rather than probe-rank
    order.

    Not jitted end-to-end by design (the shard loop is a host loop over
    mmap'd staging); every numerical stage dispatches through jitted
    facades, so one warmed call serves any store with the same shapes.

    Telemetry: each call is one `obs.query_trace` whose
    probe/schedule/acquire/fold/gather/rerank spans land in
    `search_stage_seconds{stage=...}`. With tracing OFF (the default)
    the spans are single-flag-check no-ops and nothing is fenced; with
    tracing ON, span boundaries `block_until_ready` the stage's output
    so stage times are device-honest — at the documented cost of
    serializing the prefetch overlap (docs/KERNELS.md). Results are
    bitwise identical either way (tested): fences synchronize, they
    never change values.

    Graceful degradation (all off by default — the fault-free defaults
    keep this function bit-identical to its pre-degradation behavior):

      - ``on_shard_error="skip"``: a scheduled shard that is quarantined
        or whose acquire fails (`OSError` after the pool's retries, a
        staging timeout, or a `ShardIntegrityError`) is dropped from the
        scan instead of raising. The rank-keyed merge makes this
        well-formed: the dropped shard's rows simply never enter the
        shortlist, exactly as if its buckets held fewer candidates —
        results stay valid approximate answers over the shards that DID
        fold. Device-side failures (the fold itself) always propagate.
      - ``deadline_s``: a wall-clock budget measured from call entry;
        once exceeded, remaining scheduled shards are ejected unfolded
        (`search_deadline_ejected_shards_total`) and the query answers
        from what has folded so far. ``t_start_s`` (a
        `time.perf_counter` timestamp) moves the budget's origin before
        call entry — the serving front door passes each batch's oldest
        ARRIVAL time, so queueing delay is charged against the same
        budget the shard loop checks instead of being subtracted by
        every caller separately. A budget already exhausted at entry
        (e.g. the queue ate all of it) folds nothing and answers from
        the synthesized padding with coverage ~0 — degraded, never
        stalled.
      - ``return_coverage``: returns ``(ids, dists, coverage)`` where
        coverage is (Q,) float32 — for each query, the fraction of its
        *relevant* scheduled shards (shards with at least one probed
        bucket; shards quarantined at open count as relevant to every
        query) that actually folded. 1.0 everywhere on a clean run;
        < 1.0 marks a degraded answer (`search_degraded_queries_total`).
    """
    if on_shard_error not in ("raise", "skip"):
        raise ValueError(f"on_shard_error={on_shard_error!r} "
                         f"(expected 'raise' or 'skip')")
    t_start = time.perf_counter() if t_start_s is None else float(t_start_s)
    cfg = cfg or view.cfg
    q = jnp.asarray(q, jnp.float32)
    cap = view.cap
    n_short_aq = min(n_short_aq, n_probe * cap)           # resident clamps
    n_short_pw = min(n_short_pw, n_short_aq)
    topk = min(topk, n_short_pw)
    Q = q.shape[0]
    _C_SEARCH_CALLS.inc()
    _C_SEARCH_QUERIES.inc(Q)

    # pin one view-state snapshot for the whole call: a concurrent
    # `view.refresh()` (new deltas, new tombstones, a compacted
    # generation) can never change a query already admitted — it only
    # affects calls that pin after the swap
    vst = view.pin()
    try:
        with obs.query_trace("search_sharded", queries=Q):
            with obs.span("search/probe") as sp:
                top_b, lut_m = _probe_and_masked_lut(
                    view.centroids, view.aq_books, q, n_probe)
                sp.fence(top_b, lut_m)
            with obs.span("search/schedule"):
                sched = view.schedule_shards(np.asarray(top_b), vst)
            state = (jnp.full((Q, n_short_aq), -jnp.inf, jnp.float32),
                     jnp.full((Q, n_short_aq), _POS_SENTINEL, jnp.int32),
                     jnp.zeros((Q, n_short_aq), jnp.int32))
            from repro.index.store import ShardIntegrityError
            folded = []
            for i, sid in enumerate(sched):
                if (deadline_s is not None
                        and time.perf_counter() - t_start > deadline_s):
                    _C_EJECTED.inc(len(sched) - i)  # answer with what folded
                    break
                if sid in view.quarantined:
                    if on_shard_error == "raise":
                        raise ShardIntegrityError(
                            sid, "<denylist>",
                            "quarantined by an earlier integrity failure")
                    _C_SHARD_ERRORS.inc()
                    continue
                try:
                    with obs.span("search/acquire"):
                        ent = view.acquire(sid, vst)
                except (OSError, ShardIntegrityError):
                    # OSError: reads still failing after the pool's retries,
                    # or a staging timeout (TimeoutError). Device-side fold
                    # failures below are NOT caught — those mean the process,
                    # not the shard, is unhealthy.
                    if on_shard_error == "raise":
                        raise
                    _C_SHARD_ERRORS.inc()
                    continue
                if prefetch and i + 1 < len(sched):
                    view.prefetch(sched[i + 1], vst)  # stages during scan
                dead_np = vst.dead.get(sid)
                with obs.span("search/fold") as sp:
                    state = _fold_shard(
                        *state, ent["ext"], ent["wbr"], ent["aq_norms"],
                        None if dead_np is None else jnp.asarray(dead_np),
                        lut_m, top_b, np.int32(vst.lo[sid]), k=n_short_aq,
                        cap=cap, backend=backend)
                    sp.fence(state)
                if dead_np is not None:
                    _C_TOMBSTONED.inc(int(np.count_nonzero(dead_np)))
                view.release(sid, vst)
                folded.append(sid)
            _C_SHARDS_FOLDED.inc(len(folded))
            coverage = None
            if return_coverage or len(folded) < len(sched):
                coverage = _shard_coverage(vst, np.asarray(top_b), sched,
                                           folded)
                n_degraded = int(np.count_nonzero(coverage < 1.0))
                if n_degraded:
                    _C_DEGRADED.inc(n_degraded)
            pad = _padding_entries(top_b, vst.bucket_fill, cap=cap,
                                   p_pad=min(n_short_aq, cap))
            s1, _, ids1 = _merge_state(state, pad, n_short_aq)

            with obs.span("search/gather"):
                codes1, assign1, pw_norms1 = view.gather_rows(
                    np.asarray(ids1), vst)
            with obs.span("search/rerank") as sp:
                out = _rerank_shortlist(
                    q, s1, ids1, jnp.asarray(codes1), jnp.asarray(assign1),
                    jnp.asarray(pw_norms1), view.pw.codebooks,
                    view.centroid_codes, view.centroids, view.qinco_params,
                    n_short_pw=n_short_pw, topk=topk, cfg=cfg,
                    backend=backend, pairs=view.pw.pairs, K=view.K)
                sp.fence(out)
    finally:
        view.unpin(vst)
    if return_coverage:
        if coverage is None:
            coverage = np.ones(Q, np.float32)
        return out[0], out[1], coverage
    return out


def _shard_coverage(vst, top_b, sched, folded):
    """(Q,) fraction of each query's relevant scheduled shards that
    folded. Relevance comes from the per-shard bucket-occupancy bitmaps
    (a shard with none of the query's probed buckets could not have
    contributed anyway); a shard quarantined at open has no bitmap and
    conservatively counts as relevant to every query. Queries with no
    relevant shard at all get coverage 1.0 — nothing was lost."""
    Q = top_b.shape[0]
    total = np.zeros(Q, np.float64)
    got = np.zeros(Q, np.float64)
    folded_set = set(folded)
    for sid in sched:
        hit = vst.hit.get(sid)
        rel = np.ones(Q, bool) if hit is None else hit[top_b].any(axis=1)
        total += rel
        if sid in folded_set:
            got += rel
    return np.where(total > 0, got / np.maximum(total, 1.0),
                    1.0).astype(np.float32)


def _merge_state(state, new, k: int):
    """Fold one shard's (vals, pos, gids) into the running merge."""
    from repro.parallel.collectives import merge_topk_ranked
    vals = jnp.concatenate([state[0], new[0]], axis=1)
    pos = jnp.concatenate([state[1], new[1]], axis=1)
    gids = jnp.concatenate([state[2], new[2]], axis=1)
    return merge_topk_ranked(vals, pos, gids, min(k, vals.shape[1]))


# ---------------------------------------------------------------------------
# Distributed search: database sharded across the mesh `model` axis
# ---------------------------------------------------------------------------


def make_distributed_adc(mesh, model_axis: str = "model",
                         backend: str = "auto"):
    """Per-shard fused ADC+top-k + all-gather merge, as a shard_map
    collective.

    db_codes: (N, M) sharded over `model_axis`; lut: (Q, M, K) replicated;
    norms: (N,) sharded. Returns (Q, k) global ids + scores. Each shard
    runs the fused `ops.adc_topk` kernel over its slice — the per-shard
    (Q, N_loc) score matrix never leaves VMEM before shortlisting — then
    the (Q, k) local lists merge via `collectives.merge_topk` (wire cost
    2*Q*k instead of Q*N)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel import compat
    from repro.parallel.collectives import merge_topk

    def fn(lut, db_codes, norms, k: int):
        nshard = mesh.shape[model_axis]
        nloc = db_codes.shape[0] // nshard

        def inner(lut, codes, norms):
            vals, loc = ops.adc_topk(codes, lut, k, norms=norms,
                                     backend=backend)
            base = jax.lax.axis_index(model_axis) * nloc
            return merge_topk(vals, base + loc, k, model_axis)

        return compat.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(model_axis), P(model_axis)),
            out_specs=(P(), P()), check_vma=False,
        )(lut, db_codes, norms)

    return fn
