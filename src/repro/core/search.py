"""Large-scale nearest-neighbor search cascade (paper §3.3, Fig. 3):

    IVF probe -> ADC (unitary AQ/RQ LUT) shortlist S_AQ
              -> pairwise-decoder shortlist S_pairs
              -> full QINCo2 neural re-ranking.

Plus the distributed variant: database sharded over the `model` mesh axis,
per-shard ADC top-k, all-gather + global top-k merge
(`distributed_search`), expressed with shard_map — the billion-scale
layout exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import QincoConfig
from repro.core import aq as aq_mod
from repro.core import ivf as ivf_mod
from repro.core import pairwise as pw_mod
from repro.core import qinco


@dataclasses.dataclass
class SearchIndex:
    """Everything needed at query time (built by `build_index`)."""
    ivf: ivf_mod.IVFIndex
    codes: jnp.ndarray                 # (N, M) QINCo2 codes (of residuals)
    aq_books: jnp.ndarray              # (M, K, d) unitary look-up decoder
    aq_norms: jnp.ndarray              # (N,) ||xhat_aq||^2 (w/ centroid)
    pw: pw_mod.PairwiseDecoder         # pairwise decoder over [codes, I~]
    pw_norms: jnp.ndarray              # (N,)
    qinco_params: dict
    cfg: QincoConfig

    @property
    def ext_codes(self):
        """codes ++ centroid RQ codes I~ per vector: (N, M + M~)."""
        tilde = self.ivf.centroid_codes[self.ivf.assignments]
        return jnp.concatenate([self.codes, tilde], axis=1)


jax.tree_util.register_dataclass(
    SearchIndex,
    data_fields=("ivf", "codes", "aq_books", "aq_norms", "pw", "pw_norms",
                 "qinco_params"),
    meta_fields=("cfg",))


def build_index(key, xb, qinco_params, cfg: QincoConfig, *, k_ivf: int = 64,
                m_tilde: int = 2, n_pair_books: int = None,
                encode_fn=None, verbose: bool = False) -> SearchIndex:
    """Encode the database and fit the cascade decoders."""
    from repro.core import encode as enc
    n_pair_books = n_pair_books or 2 * cfg.M
    k1, k2 = jax.random.split(key)
    ivf = ivf_mod.build_ivf(k1, xb, k_ivf, m_tilde=m_tilde, K=cfg.K)
    resid = ivf_mod.residual_to_centroid(ivf, xb, ivf.assignments)
    encode_fn = encode_fn or (lambda v: enc.encode(
        qinco_params, v, cfg, cfg.A_eval, cfg.B_eval)[0])
    codes = encode_fn(resid)

    # unitary AQ decoder on the residual codes
    aq_books = aq_mod.fit_aq(codes, resid, cfg.M, cfg.K)
    recon_aq = aq_mod.aq_decode(aq_books, codes) + ivf.centroids[
        ivf.assignments]
    aq_norms = jnp.sum(recon_aq * recon_aq, axis=-1)

    # pairwise decoder over [QINCo2 codes ++ centroid RQ codes]
    tilde = ivf.centroid_codes[ivf.assignments]
    ext = jnp.concatenate([codes, tilde], axis=1)
    pw = pw_mod.fit_pairwise(ext, xb, cfg.K, n_pair_books, verbose=verbose)
    recon_pw = pw.decode(ext)
    pw_norms = jnp.sum(recon_pw * recon_pw, axis=-1)

    return SearchIndex(ivf=ivf, codes=codes, aq_books=aq_books,
                       aq_norms=aq_norms, pw=pw, pw_norms=pw_norms,
                       qinco_params=qinco_params, cfg=cfg)


@partial(jax.jit, static_argnames=("n_probe", "n_short_aq", "n_short_pw",
                                   "topk", "cfg"))
def search(index: SearchIndex, q, *, n_probe: int = 4, n_short_aq: int = 64,
           n_short_pw: int = 16, topk: int = 1, cfg: QincoConfig = None):
    """Full cascade. q: (Q, d) -> (ids (Q, topk), dists (Q, topk))."""
    cfg = cfg or index.cfg
    Q = q.shape[0]
    # 1. IVF probe ----------------------------------------------------------
    top_b, cand, cmask = ivf_mod.probe(index.ivf, q, n_probe)
    # 2. ADC over candidates (unitary AQ LUT) --------------------------------
    lut = aq_mod.adc_lut(index.aq_books, q)               # (Q, M, K)
    clut = jnp.einsum("qd,kd->qk", q, index.ivf.centroids)
    cand_codes = index.codes[cand]                        # (Q, C, M)
    ip = jnp.sum(jnp.take_along_axis(
        lut[:, None], cand_codes[..., None], axis=3)[..., 0], axis=2)
    ip = ip + jnp.take_along_axis(
        clut, index.ivf.assignments[cand], axis=1)
    score = 2.0 * ip - index.aq_norms[cand]
    score = jnp.where(cmask, score, -jnp.inf)
    s1, keep1 = jax.lax.top_k(score, n_short_aq)          # (Q, n_short_aq)
    ids1 = jnp.take_along_axis(cand, keep1, axis=1)
    # 3. pairwise decoder re-rank --------------------------------------------
    plut = pw_mod.pairwise_lut(index.pw.codebooks, q)     # (Q, M', K^2)
    ext1 = index.ext_codes[ids1]                          # (Q, S1, M_all)
    buckets = jnp.stack([ext1[..., i] * cfg.K + ext1[..., j]
                         for i, j in index.pw.pairs], axis=-1)
    ipp = jnp.sum(jnp.take_along_axis(
        plut[:, None], buckets[..., None], axis=3)[..., 0], axis=2)
    score2 = 2.0 * ipp - index.pw_norms[ids1]
    score2 = jnp.where(s1 > -jnp.inf, score2, -jnp.inf)
    _, keep2 = jax.lax.top_k(score2, n_short_pw)
    ids2 = jnp.take_along_axis(ids1, keep2, axis=1)       # (Q, n_short_pw)
    # 4. full QINCo2 decode + exact distance ---------------------------------
    flat = ids2.reshape(-1)
    recon = qinco.decode(index.qinco_params, index.codes[flat], cfg)
    recon = recon + index.ivf.centroids[index.ivf.assignments[flat]]
    recon = recon.reshape(Q, n_short_pw, -1)
    d2 = jnp.sum(jnp.square(q[:, None, :] - recon), axis=-1)
    dtop, ktop = jax.lax.top_k(-d2, topk)
    return jnp.take_along_axis(ids2, ktop, axis=1), -dtop


# ---------------------------------------------------------------------------
# Distributed search: database sharded across the mesh `model` axis
# ---------------------------------------------------------------------------


def make_distributed_adc(mesh, model_axis: str = "model"):
    """Per-shard ADC top-k + all-gather merge, as a shard_map collective.

    db_codes: (N, M) sharded over model; lut: (Q, M, K) replicated;
    norms: (N,) sharded. Returns (Q, k) global ids + scores."""
    from jax.sharding import PartitionSpec as P

    def local_topk(lut, codes, norms, base, k):
        ip = jnp.sum(jnp.take_along_axis(
            lut[:, None], codes[None, ..., None], axis=3)[..., 0], axis=2)
        score = 2.0 * ip - norms[None]
        s, i = jax.lax.top_k(score, k)                    # local top-k
        gid = base + i
        # gather all shards' candidates and reduce to a global top-k
        s_all = jax.lax.all_gather(s, model_axis, axis=1, tiled=True)
        g_all = jax.lax.all_gather(gid, model_axis, axis=1, tiled=True)
        s2, i2 = jax.lax.top_k(s_all, k)
        return jnp.take_along_axis(g_all, i2, axis=1), s2

    def fn(lut, db_codes, norms, k: int):
        nshard = mesh.shape[model_axis]
        nloc = db_codes.shape[0] // nshard

        def inner(lut, codes, norms):
            idx = jax.lax.axis_index(model_axis)
            return local_topk(lut, codes, norms, idx * nloc, k)

        return jax.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(model_axis), P(model_axis)),
            out_specs=(P(), P()), check_vma=False,
        )(lut, db_codes, norms)

    return fn
