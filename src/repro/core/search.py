"""Large-scale nearest-neighbor search cascade (paper §3.3, Fig. 3):

    IVF probe -> ADC (unitary AQ/RQ LUT) shortlist S_AQ
              -> pairwise-decoder shortlist S_pairs
              -> full QINCo2 neural re-ranking.

All candidate scoring goes through the `kernels/ops` dispatch facade
(`ops.adc_scores` / `ops.pairwise_scores` — the one-hot MXU forms) rather
than per-byte LUT gathers; the IVF-centroid inner-product term is folded in
as an extra ADC codebook so the whole step-2 scan is ONE `adc_scores` call.

The distributed variant shards the database over the `model` mesh axis and
runs the *identical* per-shard kernel path (shared-codes `ops.adc_scores`)
followed by `collectives.distributed_topk` — the billion-scale layout
exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import QincoConfig
from repro.core import aq as aq_mod
from repro.core import ivf as ivf_mod
from repro.core import pairwise as pw_mod
from repro.core import qinco
from repro.kernels import ops


@dataclasses.dataclass
class SearchIndex:
    """Everything needed at query time (built by `build_index`).

    ``codes`` is packed uint8 whenever the alphabet fits a byte (K <= 256
    — every paper setting): the packed bytes are the HBM-resident form the
    ADC kernels scan directly, 4x smaller than the historical int32. The
    scoring results are bit-identical either way (`kernels/ops` widens
    in-kernel). `repro.index.store.IndexStore` persists this layout to
    disk and round-trips it exactly.
    """
    ivf: ivf_mod.IVFIndex
    codes: jnp.ndarray                 # (N, M) uint8|int32 QINCo2 codes
    aq_books: jnp.ndarray              # (M, K, d) unitary look-up decoder
    aq_norms: jnp.ndarray              # (N,) ||xhat_aq||^2 (w/ centroid)
    pw: pw_mod.PairwiseDecoder         # pairwise decoder over [codes, I~]
    pw_norms: jnp.ndarray              # (N,)
    qinco_params: dict
    cfg: QincoConfig

    @property
    def ext_codes(self):
        """codes ++ centroid RQ codes I~ per vector: (N, M + M~) int32.

        Materializes the FULL database widened to int32 — a fit-time /
        offline-evaluation utility. The serving path (`search` step 3)
        instead gathers the shortlist rows first and widens only those,
        so the packed uint8 codes stay the HBM-resident form.
        M~ = 0 (no centroid RQ codes) degrades to the plain codes.
        """
        codes = self.codes.astype(jnp.int32)
        if self.ivf.centroid_codes is None:
            return codes
        tilde = self.ivf.centroid_codes[self.ivf.assignments]
        return jnp.concatenate([codes, tilde], axis=1)


jax.tree_util.register_dataclass(
    SearchIndex,
    data_fields=("ivf", "codes", "aq_books", "aq_norms", "pw", "pw_norms",
                 "qinco_params"),
    meta_fields=("cfg",))


def build_index(key, xb, qinco_params, cfg: QincoConfig, *, k_ivf: int = 64,
                m_tilde: int = 2, n_pair_books: int = None,
                encode_fn=None, encode_chunk: int = 4096,
                backend: str = "auto", pack: bool = True,
                verbose: bool = False) -> SearchIndex:
    """Encode the database and fit the cascade decoders.

    Database encoding runs through the chunked `encode_dataset` driver, so
    databases larger than a device batch reuse one compiled executable.
    With ``pack`` (default) codes are stored packed uint8 when K <= 256.
    """
    from repro.core import encode as enc
    from repro.index import codes as pc
    n_pair_books = n_pair_books or 2 * cfg.M
    k1, k2 = jax.random.split(key)
    ivf = ivf_mod.build_ivf(k1, xb, k_ivf, m_tilde=m_tilde, K=cfg.K)
    resid = ivf_mod.residual_to_centroid(ivf, xb, ivf.assignments)
    encode_fn = encode_fn or (lambda v: enc.encode_dataset(
        qinco_params, v, cfg, cfg.A_eval, cfg.B_eval, chunk=encode_chunk,
        backend=backend)[0])
    codes = jnp.asarray(encode_fn(resid))
    if pack and pc.packable(cfg.K):
        codes = pc.pack_codes(codes, cfg.K)

    # unitary AQ decoder on the residual codes
    aq_books = aq_mod.fit_aq(codes, resid, cfg.M, cfg.K)
    recon_aq = aq_mod.aq_decode(aq_books, codes) + ivf.centroids[
        ivf.assignments]
    aq_norms = jnp.sum(recon_aq * recon_aq, axis=-1)

    # pairwise decoder over [QINCo2 codes ++ centroid RQ codes (if any)]
    ext = codes.astype(jnp.int32)
    if ivf.centroid_codes is not None:
        ext = jnp.concatenate([ext, ivf.centroid_codes[ivf.assignments]],
                              axis=1)
    pw = pw_mod.fit_pairwise(ext, xb, cfg.K, n_pair_books, verbose=verbose)
    recon_pw = pw.decode(ext)
    pw_norms = jnp.sum(recon_pw * recon_pw, axis=-1)

    return SearchIndex(ivf=ivf, codes=codes, aq_books=aq_books,
                       aq_norms=aq_norms, pw=pw, pw_norms=pw_norms,
                       qinco_params=qinco_params, cfg=cfg)


def _adc_lut_with_centroids(index: SearchIndex, q):
    """(Q, M+1, K') LUT: the unitary AQ books plus the IVF-centroid book.

    Scoring a candidate n then reads M code columns plus its bucket id —
    the centroid inner product becomes just another ADC codebook, so step 2
    is a single `ops.adc_scores` call. K' = max(K, k_ivf); both LUT groups
    are zero-padded on the alphabet axis (padded slots are never indexed).
    """
    lut = aq_mod.adc_lut(index.aq_books, q)               # (Q, M, K)
    clut = aq_mod.adc_lut(index.ivf.centroids[None], q)   # (Q, 1, k_ivf)
    K, k_ivf = lut.shape[2], clut.shape[2]
    Kp = max(K, k_ivf)
    lut = jnp.pad(lut, ((0, 0), (0, 0), (0, Kp - K)))
    clut = jnp.pad(clut, ((0, 0), (0, 0), (0, Kp - k_ivf)))
    return jnp.concatenate([lut, clut], axis=1)


@partial(jax.jit, static_argnames=("n_probe", "n_short_aq", "n_short_pw",
                                   "topk", "cfg", "backend"))
def search(index: SearchIndex, q, *, n_probe: int = 4, n_short_aq: int = 64,
           n_short_pw: int = 16, topk: int = 1, cfg: QincoConfig = None,
           backend: str = "auto"):
    """Full cascade. q: (Q, d) -> (ids (Q, topk'), dists (Q, topk')).

    Shortlist sizes are clamped to what the probe can actually supply:
    ``n_short_aq`` to the candidate count of the probed buckets,
    ``n_short_pw`` to the (clamped) ``n_short_aq``, and ``topk`` to the
    (clamped) ``n_short_pw`` — a `lax.top_k` wider than its input is a
    trace-time error, and a small index must not force callers to
    hand-size every shortlist. topk' = the clamped ``topk``.
    """
    cfg = cfg or index.cfg
    Q = q.shape[0]
    # 1. IVF probe ----------------------------------------------------------
    top_b, cand, cmask = ivf_mod.probe(index.ivf, q, n_probe)
    n_short_aq = min(n_short_aq, cand.shape[1])
    n_short_pw = min(n_short_pw, n_short_aq)
    topk = min(topk, n_short_pw)
    # 2. ADC over candidates (unitary AQ LUT + centroid term) ----------------
    lut_ext = _adc_lut_with_centroids(index, q)           # (Q, M+1, K')
    codes_ext = jnp.concatenate(
        [index.codes[cand].astype(jnp.int32),
         index.ivf.assignments[cand][..., None]], axis=-1)  # (Q, C, M+1)
    score = ops.adc_scores(codes_ext, lut_ext,
                           norms=index.aq_norms[cand], backend=backend)
    score = jnp.where(cmask, score, -jnp.inf)
    s1, keep1 = jax.lax.top_k(score, n_short_aq)          # (Q, n_short_aq)
    ids1 = jnp.take_along_axis(cand, keep1, axis=1)
    # 3. pairwise decoder re-rank --------------------------------------------
    # gather the shortlist rows BEFORE widening: only (Q, n_short_aq, M+M~)
    # leaves the packed code matrix, never an (N, ...) int32 temporary
    plut = pw_mod.pairwise_lut(index.pw.codebooks, q)     # (Q, M', K^2)
    ext1 = index.codes[ids1].astype(jnp.int32)
    if index.ivf.centroid_codes is not None:              # M~ = 0 degrades
        tilde1 = index.ivf.centroid_codes[index.ivf.assignments[ids1]]
        ext1 = jnp.concatenate([ext1, tilde1], axis=-1)
    score2 = ops.pairwise_scores(ext1, plut,
                                 index.pw.pairs, cfg.K,
                                 norms=index.pw_norms[ids1], backend=backend)
    score2 = jnp.where(s1 > -jnp.inf, score2, -jnp.inf)
    _, keep2 = jax.lax.top_k(score2, n_short_pw)
    ids2 = jnp.take_along_axis(ids1, keep2, axis=1)       # (Q, n_short_pw)
    # 4. full QINCo2 decode + exact distance ---------------------------------
    # the decode scan re-ranks through the indexed ops.f_theta kernel: the
    # shortlist's packed code columns go in as uint8 indices, the codebook
    # gather + step network run fused per step
    flat = ids2.reshape(-1)
    recon = qinco.decode(index.qinco_params, index.codes[flat], cfg,
                         backend=backend)
    recon = recon + index.ivf.centroids[index.ivf.assignments[flat]]
    recon = recon.reshape(Q, n_short_pw, -1)
    d2 = jnp.sum(jnp.square(q[:, None, :] - recon), axis=-1)
    dtop, ktop = jax.lax.top_k(-d2, topk)
    return jnp.take_along_axis(ids2, ktop, axis=1), -dtop


# ---------------------------------------------------------------------------
# Distributed search: database sharded across the mesh `model` axis
# ---------------------------------------------------------------------------


def make_distributed_adc(mesh, model_axis: str = "model",
                         backend: str = "auto"):
    """Per-shard fused ADC+top-k + all-gather merge, as a shard_map
    collective.

    db_codes: (N, M) sharded over `model_axis`; lut: (Q, M, K) replicated;
    norms: (N,) sharded. Returns (Q, k) global ids + scores. Each shard
    runs the fused `ops.adc_topk` kernel over its slice — the per-shard
    (Q, N_loc) score matrix never leaves VMEM before shortlisting — then
    the (Q, k) local lists merge via `collectives.merge_topk` (wire cost
    2*Q*k instead of Q*N)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel import compat
    from repro.parallel.collectives import merge_topk

    def fn(lut, db_codes, norms, k: int):
        nshard = mesh.shape[model_axis]
        nloc = db_codes.shape[0] // nshard

        def inner(lut, codes, norms):
            vals, loc = ops.adc_topk(codes, lut, k, norms=norms,
                                     backend=backend)
            base = jax.lax.axis_index(model_axis) * nloc
            return merge_topk(vals, base + loc, k, model_axis)

        return compat.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(model_axis), P(model_axis)),
            out_specs=(P(), P()), check_vma=False,
        )(lut, db_codes, norms)

    return fn
