"""QINCo2 training (paper App. A.2), with optional data-parallel sharding.

Per batch: (1) encode with Q_QI-B under the *current* params — no autodiff;
(2) one forward-backward pass through f on the selected codes only;
(3) AdamW (wd 0.1), cosine schedule, grad clip; (4) per-epoch dead-codeword
reset from the step-residual statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import QincoConfig
from repro.core import encode as enc
from repro.core import qinco, rq
from repro.models.common import init_params
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


def normalize_dataset(x):
    """Paper: per-feature mean 0, global std 1."""
    mu = np.mean(x, axis=0, keepdims=True)
    x = x - mu
    sd = np.std(x)
    return (x / sd).astype(np.float32), (mu, sd)


def init_qinco2(key, x_train, cfg: QincoConfig):
    """Init params: Kaiming nets (+zero down-proj) and noisy-RQ codebooks."""
    k1, k2, k3 = jax.random.split(key, 3)
    params = init_params(qinco.param_specs(cfg), k1)
    rq_cbs = rq.rq_train(k2, x_train[:min(len(x_train), 20_000)],
                         cfg.M, cfg.K, iters=cfg.kmeans_init_iters)
    return qinco.init_from_rq(params, rq_cbs, k3, cfg.codebook_init_noise)


def make_train_step(cfg: QincoConfig, opt_cfg: adamw.AdamWConfig):
    @jax.jit
    def train_step(params, opt_state, x):
        codes, _, _ = enc.encode(params, x, cfg, cfg.A_train, cfg.B_train)
        codes = jax.lax.stop_gradient(codes)

        def loss_fn(p):
            loss, auxes = enc.train_forward(p, x, codes, cfg)
            return loss, auxes

        (loss, (main, aux, last_mse)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_s, metrics = adamw.update(grads, opt_state, params,
                                             opt_cfg)
        metrics.update(loss=loss, main=main, aux=aux, mse=last_mse)
        # codeword usage for dead-code reset
        usage = jnp.zeros((cfg.M, cfg.K), jnp.int32).at[
            jnp.arange(cfg.M)[None], codes].add(1)
        return new_p, new_s, metrics, usage
    return train_step


def reset_dead_codes(key, params, usage, resid_mu, resid_sd):
    """Paper: reset unused codewords ~ U with the residual mean/std."""
    M, K = usage.shape
    d = params["codebooks"].shape[-1]
    dead = usage == 0                                  # (M, K)
    k1, k2 = jax.random.split(key)
    lim = jnp.sqrt(3.0) * resid_sd[:, None, None]      # match std
    new = resid_mu[:, None, :] + jax.random.uniform(
        k1, (M, K, d), minval=-1.0, maxval=1.0) * lim
    new_pre = resid_mu[:, None, :] + jax.random.uniform(
        k2, (M, K, d), minval=-1.0, maxval=1.0) * lim
    cb = jnp.where(dead[..., None], new, params["codebooks"])
    pre = jnp.where(dead[..., None], new_pre, params["pre_codebooks"])
    return dict(params, codebooks=cb, pre_codebooks=pre), int(dead.sum())


def train(key, x_train, cfg: QincoConfig, *, steps_per_epoch=None,
          epochs=None, x_val=None, log_every: int = 50, verbose=True):
    """Full training loop (CPU-scale). Returns (params, history)."""
    epochs = epochs or cfg.epochs
    x_train = jnp.asarray(x_train)
    n = x_train.shape[0]
    bs = min(cfg.batch_size, n)
    steps_per_epoch = steps_per_epoch or max(n // bs, 1)
    total = steps_per_epoch * epochs
    opt_cfg = adamw.AdamWConfig(
        lr=cosine_with_warmup(cfg.lr, total, min(100, total // 10),
                              cfg.min_lr_ratio),
        weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip,
    )
    key, sub = jax.random.split(key)
    params = init_qinco2(sub, np.asarray(x_train), cfg)
    opt_state = adamw.init(params, opt_cfg)
    step_fn = make_train_step(cfg, opt_cfg)

    history = []
    t0 = time.perf_counter()
    for ep in range(epochs):
        key, kperm, kreset = jax.random.split(key, 3)
        order = jax.random.permutation(kperm, n)
        usage_tot = jnp.zeros((cfg.M, cfg.K), jnp.int32)
        for s in range(steps_per_epoch):
            idx = jax.lax.dynamic_slice_in_dim(order, (s * bs) % max(n - bs, 1),
                                               bs)
            xb = x_train[idx]
            params, opt_state, metrics, usage = step_fn(params, opt_state, xb)
            usage_tot = usage_tot + usage
        # dead-code reset from last batch's residual stats
        codes, xhat, _ = enc.encode(params, xb, cfg, cfg.A_train, cfg.B_train)
        traj = qinco.decode_partial(params, codes, cfg)
        prev = jnp.concatenate([jnp.zeros_like(traj[:, :1]), traj[:, :-1]], 1)
        resid = xb[:, None, :] - prev                      # (N, M, d)
        mu = jnp.mean(resid, axis=0)                       # (M, d)
        sd = jnp.std(resid, axis=(0, 2))                   # (M,)
        params, n_dead = reset_dead_codes(kreset, params, np.asarray(usage_tot),
                                          mu, sd)
        rec = {"epoch": ep, "loss": float(metrics["loss"]),
               "mse": float(metrics["mse"]), "dead": n_dead,
               "time": time.perf_counter() - t0}
        if x_val is not None:
            rec["val_mse"] = float(enc.reconstruction_mse(
                params, jnp.asarray(x_val), cfg, cfg.A_eval, cfg.B_eval))
        history.append(rec)
        if verbose:
            print(f"[qinco2] epoch {ep}: " + " ".join(
                f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in rec.items() if k != "epoch"), flush=True)
    return params, history
