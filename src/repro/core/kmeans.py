"""Batched Lloyd k-means in JAX (used for RQ/IVF/KV-cache codebooks)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def pairwise_sqdist(x, c):
    """x: (N, d), c: (K, d) -> (N, K) squared L2."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    return x2 - 2.0 * x @ c.T + c2


def assign(x, c):
    return jnp.argmin(pairwise_sqdist(x, c), axis=-1)


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key, x, k: int, iters: int = 10):
    """Returns (centroids (k, d), assignments (N,)).

    Init: random data points. Empty clusters keep their previous centroid
    (the training loop separately resets dead codewords, paper App. A.2).
    """
    n = x.shape[0]
    idx = jax.random.choice(key, n, (k,), replace=n < k)
    c0 = x[idx]
    if n < k:   # de-duplicate by noise so clusters can separate
        c0 = c0 + 1e-3 * jax.random.normal(key, c0.shape, c0.dtype)

    def step(c, _):
        a = assign(x, c)
        onehot = jax.nn.one_hot(a, k, dtype=x.dtype)         # (N, K)
        counts = jnp.sum(onehot, axis=0)                     # (K,)
        sums = onehot.T @ x                                  # (K, d)
        new_c = jnp.where(counts[:, None] > 0,
                          sums / jnp.maximum(counts[:, None], 1), c)
        return new_c, None

    c, _ = lax.scan(step, c0, None, length=iters)
    return c, assign(x, c)


def kmeans_cost(x, c):
    return jnp.mean(jnp.min(pairwise_sqdist(x, c), axis=-1))
