"""Additive-quantizer decoder fit on fixed codes (paper §3.3 / Table 4).

Given codes (N, M) produced by QINCo2 and their source vectors x, find
codebooks {C^m} minimizing ||x - sum_m C^m[i_m]||^2 — one large ridge
least-squares solved via the normal equations, assembled on device with
scatter-adds (the one-hot design matrix is never materialized).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("M", "K"))
def fit_aq(codes, x, M: int, K: int, ridge: float = 1e-4):
    """codes: (N, M) int32; x: (N, d) -> codebooks (M, K, d)."""
    N, d = x.shape
    MK = M * K
    flat = codes + (jnp.arange(M) * K)[None, :]           # (N, M)
    # G[a, b] = #(vectors using code-slot a and b)
    G = jnp.zeros((MK, MK), jnp.float32)
    G = G.at[flat[:, :, None], flat[:, None, :]].add(1.0)
    b = jnp.zeros((MK, d), jnp.float32).at[flat].add(x[:, None, :])
    G = G + ridge * N / MK * jnp.eye(MK)
    C = jnp.linalg.solve(G, b)
    return C.reshape(M, K, d)


def aq_decode(codebooks, codes):
    M = codebooks.shape[0]
    return jnp.sum(codebooks[jnp.arange(M)[None], codes], axis=1)


@partial(jax.jit, static_argnames=("M", "K"))
def fit_rq_decoder(codes, x, M: int, K: int, ridge: float = 1.0):
    """Sequential (RQ-style) decoder fit: each codebook is the per-bucket
    mean of the residual left by previous steps — the paper's cheaper
    alternative to the joint AQ solve (Table 4, 'RQ' row)."""
    N, d = x.shape
    r = x
    cbs = []
    for m in range(M):
        idx = codes[:, m]
        sums = jnp.zeros((K, d), jnp.float32).at[idx].add(r)
        cnts = jnp.zeros((K,), jnp.float32).at[idx].add(1.0)
        cb = sums / (cnts[:, None] + ridge)
        cbs.append(cb)
        r = r - cb[idx]
    return jnp.stack(cbs)


def adc_lut(codebooks, q):
    """Asymmetric-distance LUT: (M, K) inner products <q, C^m_k>.

    codebooks: (M, K, d); q: (Q, d) -> (Q, M, K)."""
    return jnp.einsum("qd,mkd->qmk", q, codebooks)


def adc_scores(lut, codes, norms, backend: str = "auto"):
    """Approx -||q - xhat||^2 up to a ||q||^2 constant.

    lut: (Q, M, K); codes: (N, M); norms: (N,) = ||xhat||^2.
    Returns (Q, N) scores (higher = closer). Thin wrapper over the
    `kernels/ops.adc_scores` dispatch (kept for its LUT-first signature)."""
    from repro.kernels import ops
    return ops.adc_scores(codes, lut, norms=norms, backend=backend)
