"""Pairwise additive decoding (paper §3.3, Eq. 8-9).

Combined codes I^{i,j} = I^i * K + I^j index codebooks of size K^2, chosen
greedily RQ-style over all pairs of available code columns (QINCo2 codes
plus the RQ-quantized IVF-centroid codes I~). Each codebook is the ridge
per-bucket mean of the current residual — the least-squares solution for a
one-hot design.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PairwiseDecoder:
    pairs: Tuple[Tuple[int, int], ...]  # column indices into the code matrix
    codebooks: jnp.ndarray              # (M', K^2, d)
    K: int

    def __post_init__(self):
        self.pairs = tuple(tuple(p) for p in self.pairs)

    def decode(self, codes):
        return pairwise_decode(self.codebooks, codes, self.pairs, self.K)


jax.tree_util.register_dataclass(
    PairwiseDecoder, data_fields=("codebooks",), meta_fields=("pairs", "K"))


def _bucket_ids(codes_i, codes_j, K: int):
    """I^i * K + I^j, widened first: packed uint8 columns would wrap at
    256 while the combined alphabet needs up to 16 bits."""
    return codes_i.astype(jnp.int32) * K + codes_j.astype(jnp.int32)


@partial(jax.jit, static_argnames=("K",))
def _bucket_fit(codes_i, codes_j, r, K: int, ridge: float = 1.0):
    """Per-bucket ridge means + achieved SSE reduction for one pair."""
    bucket = _bucket_ids(codes_i, codes_j, K)            # (N,)
    d = r.shape[1]
    sums = jnp.zeros((K * K, d), jnp.float32).at[bucket].add(r)
    cnts = jnp.zeros((K * K,), jnp.float32).at[bucket].add(1.0)
    cb = sums / (cnts[:, None] + ridge)
    # SSE reduction = sum_b <cb_b, sums_b> (exact for ridge=0)
    gain = jnp.sum(cb * sums)
    return cb, gain


def fit_pairwise(codes, x, K: int, n_books: int, *,
                 candidate_pairs: Sequence[Tuple[int, int]] = None,
                 ridge: float = 1.0, verbose: bool = False):
    """Greedy pair selection (Eq. 8). codes: (N, M_all) int32; x: (N, d).

    Returns a PairwiseDecoder with n_books codebooks. Columns may repeat
    across selected pairs (paper: 'some input codes can be used several
    times, or not at all')."""
    N, M_all = codes.shape
    if candidate_pairs is None:
        candidate_pairs = [(i, j) for i in range(M_all)
                           for j in range(i + 1, M_all)]
    r = jnp.asarray(x, jnp.float32)
    sel_pairs: List[Tuple[int, int]] = []
    books = []
    for t in range(n_books):
        best = None
        for (i, j) in candidate_pairs:
            cb, gain = _bucket_fit(codes[:, i], codes[:, j], r, K, ridge)
            if best is None or float(gain) > best[0]:
                best = (float(gain), (i, j), cb)
        gain, (i, j), cb = best
        sel_pairs.append((i, j))
        books.append(cb)
        r = r - cb[_bucket_ids(codes[:, i], codes[:, j], K)]
        if verbose:
            mse = float(jnp.mean(jnp.sum(r * r, -1)))
            print(f"[pairwise] step {t}: pair=({i},{j}) mse={mse:.6g}")
    return PairwiseDecoder(sel_pairs, jnp.stack(books), K)


def consecutive_pairs_decoder(codes, x, K: int, *, ridge: float = 1.0):
    """Baseline: fixed consecutive pairs (1,2),(3,4),... (Table 4)."""
    M_all = codes.shape[1]
    pairs = [(i, i + 1) for i in range(0, M_all - 1, 2)]
    return _fixed_fit(codes, x, K, pairs, ridge)


def _fixed_fit(codes, x, K, pairs, ridge):
    r = jnp.asarray(x, jnp.float32)
    books = []
    for (i, j) in pairs:
        cb, _ = _bucket_fit(codes[:, i], codes[:, j], r, K, ridge)
        books.append(cb)
        r = r - cb[_bucket_ids(codes[:, i], codes[:, j], K)]
    return PairwiseDecoder(list(pairs), jnp.stack(books), K)


def pairwise_decode(codebooks, codes, pairs, K: int):
    """codebooks: (M', K^2, d); codes: (N, M_all) -> (N, d)."""
    out = jnp.zeros((codes.shape[0], codebooks.shape[-1]), jnp.float32)
    for t, (i, j) in enumerate(pairs):
        out = out + codebooks[t, _bucket_ids(codes[:, i], codes[:, j], K)]
    return out


def pairwise_lut(codebooks, q):
    """(Q, M', K^2) inner-product LUTs for the search cascade."""
    return jnp.einsum("qd,tkd->qtk", q, codebooks)


def pairwise_scores(lut, codes, pairs, K: int, norms, backend: str = "auto"):
    """lut: (Q, M', K^2); codes: (N, M_all); norms ||xhat_pair||^2 -> (Q,N).

    Thin wrapper over `kernels/ops.pairwise_scores` (kept for its LUT-first
    signature); bucket formation and the one-hot ADC matmul live there."""
    from repro.kernels import ops
    return ops.pairwise_scores(codes, lut, tuple(tuple(p) for p in pairs), K,
                               norms=norms, backend=backend)
