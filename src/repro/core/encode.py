"""QINCo2 encoding: candidate pre-selection + beam search (paper §3.2).

One code path covers the whole family:
    Q_RQ    (QINCo greedy): A = K, B = 1
    Q_QI-A  (pre-selection): A < K, B = 1
    Q_QI-B  (beam search):   A < K, B > 1

Shapes are static: (N, B, ...) tensors, lax.top_k selection, no raggedness.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.qinco2 import QincoConfig
from repro.core import qinco


def _sqdist_to_codebook(r, cb):
    """r: (N, B, d); cb: (K, d) -> (N, B, K)."""
    r2 = jnp.sum(r * r, axis=-1, keepdims=True)
    c2 = jnp.sum(cb * cb, axis=-1)
    return r2 - 2.0 * jnp.einsum("nbd,kd->nbk", r, cb) + c2


def preselect(params, m_g, r, xhat, pre_cb, A: int, cfg: QincoConfig):
    """Top-A candidate indices (N, B, A) by distance to C~ (Eq. 6)."""
    if cfg.Ls >= 1 and "g" in params:
        cand = qinco.f_apply(m_g, pre_cb, xhat[..., None, :], cfg)  # (N,B,K,d)
        d2 = jnp.sum(jnp.square(r[..., None, :] - cand), axis=-1)
    else:
        d2 = _sqdist_to_codebook(r, pre_cb)
    if A >= cfg.K:
        idx = jnp.broadcast_to(jnp.arange(cfg.K), d2.shape[:-1] + (cfg.K,))
        return idx, d2
    _, idx = lax.top_k(-d2, A)
    return idx, d2


@partial(jax.jit, static_argnames=("cfg", "A", "B"))
def encode(params, x, cfg: QincoConfig, A: Optional[int] = None,
           B: Optional[int] = None):
    """Beam-search encode. x: (N, d) -> (codes (N, M), xhat (N, d), mse).

    Maintains B hypotheses; step m expands each with its top-A pre-selected
    candidates, evaluates f_theta on the A*B expansions and keeps the best B
    (Fig. 2). Also returns the per-beam per-step selected pre-codebook index
    trace needed for the C~ auxiliary loss.
    """
    A = A or cfg.A_eval
    B = B or cfg.B_eval
    A = min(A, cfg.K)
    N, d = x.shape

    xhat = jnp.zeros((N, 1, d), x.dtype)          # beams start identical
    err = jnp.zeros((N, 1), x.dtype)
    codes = jnp.zeros((N, 1, cfg.M), jnp.int32)

    for m in range(cfg.M):
        fm = jax.tree.map(lambda a: a[m], params["f"])
        gm = (jax.tree.map(lambda a: a[m], params["g"])
              if "g" in params else None)
        cb = params["codebooks"][m]               # (K, d)
        pre_cb = params["pre_codebooks"][m]
        Bcur = xhat.shape[1]
        r = x[:, None, :] - xhat                  # (N, Bcur, d)
        idx, _ = preselect(params, gm, r, xhat, pre_cb, A, cfg)  # (N,Bcur,A)
        cand = cb[idx]                            # (N, Bcur, A, d)
        f_out = qinco.f_apply(fm, cand, xhat[..., None, :], cfg)
        new_xhat = xhat[..., None, :] + f_out     # (N, Bcur, A, d)
        new_err = jnp.sum(jnp.square(x[:, None, None, :] - new_xhat), -1)

        k = min(B, Bcur * A)
        flat_err = new_err.reshape(N, Bcur * A)
        top_err, flat_idx = lax.top_k(-flat_err, k)
        b_idx = flat_idx // A                     # (N, k)
        a_idx = flat_idx % A
        take = lambda t, bi: jnp.take_along_axis(t, bi, axis=1)
        xhat = jnp.take_along_axis(
            new_xhat.reshape(N, Bcur * A, d), flat_idx[..., None], axis=1)
        sel_code = jnp.take_along_axis(
            idx.reshape(N, Bcur * A), flat_idx, axis=1)    # (N, k)
        codes = take(codes, b_idx[..., None])
        codes = codes.at[:, :, m].set(sel_code)
        err = -top_err

    best = jnp.argmin(err, axis=1)
    codes_best = jnp.take_along_axis(codes, best[:, None, None], 1)[:, 0]
    xhat_best = jnp.take_along_axis(xhat, best[:, None, None], 1)[:, 0]
    mse = jnp.mean(jnp.min(err, axis=1))
    return codes_best, xhat_best, mse


@partial(jax.jit, static_argnames=("cfg",))
def train_forward(params, x, codes, cfg: QincoConfig):
    """Differentiable teacher-forced pass on the selected codes.

    loss = sum_m ||x - xhat^m||^2 (per-step reconstruction, as in QINCo)
         + aux: pre-codebook C~ regression toward the step residuals.
    """
    traj = qinco.decode_partial(params, codes, cfg)       # (N, M, d)
    errs = jnp.sum(jnp.square(x[:, None, :] - traj), axis=-1)   # (N, M)
    main = jnp.mean(jnp.sum(errs, axis=1))

    # residual targets r^m = x - xhat^{m-1} (stop-grad), pre-codebook entries
    prev = jnp.concatenate([jnp.zeros_like(traj[:, :1]), traj[:, :-1]], 1)
    resid = lax.stop_gradient(x[:, None, :] - prev)       # (N, M, d)
    pre = params["pre_codebooks"]                         # (M, K, d)
    # gather C~[m, codes[n, m]] -> (N, M, d)
    sel = pre[jnp.arange(cfg.M)[None, :], codes]          # (N, M, d)
    aux = jnp.mean(jnp.sum(jnp.square(resid - sel), axis=-1))
    return main + aux, (main, aux, jnp.mean(errs[:, -1]))


def reconstruction_mse(params, x, cfg: QincoConfig, A=None, B=None):
    _, xhat, _ = encode(params, x, cfg, A, B)
    return jnp.mean(jnp.sum(jnp.square(x - xhat), axis=-1))
