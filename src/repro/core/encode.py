"""QINCo2 encoding: candidate pre-selection + beam search (paper §3.2).

One code path covers the whole family:
    Q_RQ    (QINCo greedy): A = K, B = 1
    Q_QI-A  (pre-selection): A < K, B = 1
    Q_QI-B  (beam search):   A < K, B > 1

The beam is an explicit `BeamState` pytree (xhat / err / codes) advanced by
a single `lax.scan` over the stacked step params — one trace per
(cfg, A, B, backend) regardless of M, instead of M unrolled Python-loop
steps. Shapes are static throughout: the beam is B-wide from step 0, with
not-yet-populated hypotheses carrying err = +inf so that flat top-k over
the B*A expansions reproduces the growing-beam (min(B, A^m)) semantics of
the reference implementation exactly.

The beam step is FUSED end to end on the kernel backend (``fused=True``,
the default): pre-selection runs through `ops.l2_topk` (Eq. 6, L_s = 0)
or the fused `ops.preselect_topk` (L_s >= 1: g_phi + distance + top-A in
one launch), and the expansion/scoring/selection runs through
`ops.f_theta_err` — the (N, B, A, d) candidate expansion and the
per-expansion error tensor never round-trip HBM before top-k.
``fused=False`` keeps the historical unfused composite (`ops.f_theta` +
`lax.top_k`), bit-identical per backend — the comparison baseline for
the parity suite and `benchmarks/encode_throughput.py`.

`encode_dataset` is the chunked driver for database-scale encoding
(static chunk shapes, donated chunk buffers, optional shard_map over a
data axis).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.qinco2 import QincoConfig
from repro.core import qinco
from repro.kernels import ops


@dataclasses.dataclass
class BeamState:
    """The B-hypothesis beam carried through the encode scan.

    xhat: (N, B, d) running reconstructions; err: (N, B) squared errors
    (+inf marks a beam slot not yet populated); codes: (N, B, M) selected
    indices so far (columns >= current step are zero).
    """
    xhat: jnp.ndarray
    err: jnp.ndarray
    codes: jnp.ndarray


jax.tree_util.register_dataclass(
    BeamState, data_fields=("xhat", "err", "codes"), meta_fields=())


def _identity_idx(K: int, shape):
    """The exhaustive candidate list 0..K-1, broadcast to ``shape + (K,)``.
    Packed uint8 when the alphabet fits a byte (K <= 256 — every paper
    setting): the indexed `ops.f_theta`/`ops.f_theta_err` forms consume
    the bytes directly, so the pre-selector wire cost drops 4x vs the
    historical int32 identity tensor."""
    dt = jnp.uint8 if K <= 256 else jnp.int32
    return jnp.broadcast_to(jnp.arange(K, dtype=dt), shape + (K,))


def preselect(gm, r, xhat, pre_cb, A: int, cfg: QincoConfig,
              backend: str = "auto", *, fused: bool = True):
    """Top-A candidate indices (N, B, A) by distance to C~ (Eq. 6).

    gm: the step's g_phi params (None when L_s = 0). A >= K short-circuits
    to the identity candidate list (exhaustive search, QINCo greedy mode).
    With ``fused`` the L_s >= 1 path runs the single-launch
    `ops.preselect_topk` (g_phi + distance + top-A, nothing K-wide leaves
    VMEM); unfused keeps the historical f_theta + `lax.top_k` composite.
    """
    N, Bb, d = r.shape
    if A >= cfg.K:      # exhaustive: the candidate list is the identity
        return _identity_idx(cfg.K, (N, Bb))
    if cfg.Ls >= 1 and gm is not None:
        if fused:
            idx, _ = ops.preselect_topk(gm, pre_cb, xhat, r, A,
                                        backend=backend)
            return idx
        if ops.resolve_backend(backend) == "pallas":
            # indexed-form ops.f_theta: ship (N, B, K) packed indices and
            # gather in-kernel, instead of broadcast-materializing the
            # (N, B, K, d) candidate tensor into HBM for the kernel launch
            cand = ops.f_theta(gm, pre_cb, xhat,
                               idx=_identity_idx(cfg.K, (N, Bb)),
                               backend=backend)             # (N, B, K, d)
        else:
            # gathered form: the shared (K, d) pre-codebook is in-projected
            # once, then broadcast against the (N, B, 1, d) beam
            cand = ops.f_theta(gm, pre_cb, xhat[..., None, :],
                               backend=backend)             # (N, B, K, d)
        d2 = jnp.sum(jnp.square(r[..., None, :] - cand), axis=-1)
        _, idx = lax.top_k(-d2, A)
        return idx
    idx, _ = ops.l2_topk(r.reshape(N * Bb, d), pre_cb, A, backend=backend)
    return idx.reshape(N, Bb, A)


def _stacked_step_inputs(params):
    """The per-step scan inputs: step nets + codebooks, stacked over M."""
    xs = {"f": params["f"], "cb": params["codebooks"],
          "pre": params["pre_codebooks"], "m": None}
    if "g" in params:
        xs["g"] = params["g"]
    M = params["codebooks"].shape[0]
    xs["m"] = jnp.arange(M)
    return xs


def _beam_step(state: BeamState, xs, *, x, cfg: QincoConfig, A: int, B: int,
               backend: str, fused: bool = True) -> Tuple[BeamState, None]:
    """Expand each beam with its top-A candidates, keep the best B (Fig. 2)."""
    N, Bb, d = state.xhat.shape
    r = x[:, None, :] - state.xhat                        # (N, B, d)
    idx = preselect(xs.get("g"), r, state.xhat, xs["pre"], A, cfg, backend,
                    fused=fused)
    Acur = idx.shape[-1]
    if fused:
        # single-launch ops.f_theta_err: expansion, scoring, and the flat
        # top-B all happen on the VMEM-resident tile — only the (N, B, A)
        # indices go in and only the (N, B)-and-smaller selections plus
        # the winning (N, B, d) reconstructions come out
        err, flat_idx, xhat = ops.f_theta_err(
            xs["f"], xs["cb"], state.xhat, idx, x, state.err,
            backend=backend)
    else:
        # unfused composite: indexed-form ops.f_theta (the codebook gather
        # still happens inside the kernel) + full-width error + lax.top_k
        f_out = ops.f_theta(xs["f"], xs["cb"], state.xhat, idx=idx,
                            backend=backend)              # (N, B, A, d)
        new_xhat = state.xhat[..., None, :] + f_out       # (N, B, A, d)
        new_err = jnp.sum(jnp.square(x[:, None, None, :] - new_xhat), -1)
        # expansions of not-yet-populated beams must not be selectable
        new_err = jnp.where(jnp.isinf(state.err)[..., None], jnp.inf,
                            new_err)
        flat_err = new_err.reshape(N, Bb * Acur)
        top_err, flat_idx = lax.top_k(-flat_err, Bb)      # (N, B)
        err = -top_err
        xhat = jnp.take_along_axis(
            new_xhat.reshape(N, Bb * Acur, d), flat_idx[..., None], axis=1)
    b_idx = flat_idx // Acur
    sel_code = jnp.take_along_axis(
        idx.reshape(N, Bb * Acur), flat_idx, axis=1)      # (N, B)
    codes = jnp.take_along_axis(state.codes, b_idx[..., None], axis=1)
    codes = lax.dynamic_update_slice(
        codes, sel_code[..., None].astype(codes.dtype), (0, 0, xs["m"]))
    return BeamState(xhat=xhat, err=err, codes=codes), None


def _encode_impl(params, x, cfg: QincoConfig, A: Optional[int] = None,
                 B: Optional[int] = None, backend: str = "auto",
                 fused: bool = True):
    """Beam-search encode. x: (N, d) -> (codes (N, M), xhat (N, d), mse)."""
    A = A or cfg.A_eval
    B = B or cfg.B_eval
    A = min(A, cfg.K)
    N, d = x.shape

    init = BeamState(
        xhat=jnp.zeros((N, B, d), x.dtype),
        err=jnp.where(jnp.arange(B)[None, :] == 0, 0.0,
                      jnp.inf).astype(x.dtype) * jnp.ones((N, 1), x.dtype),
        codes=jnp.zeros((N, B, cfg.M), jnp.int32),
    )
    step = partial(_beam_step, x=x, cfg=cfg, A=A, B=B, backend=backend,
                   fused=fused)
    state, _ = lax.scan(step, init, _stacked_step_inputs(params))

    best = jnp.argmin(state.err, axis=1)
    codes_best = jnp.take_along_axis(state.codes, best[:, None, None], 1)[:, 0]
    xhat_best = jnp.take_along_axis(state.xhat, best[:, None, None], 1)[:, 0]
    mse = jnp.mean(jnp.min(state.err, axis=1))
    return codes_best, xhat_best, mse


encode = jax.jit(_encode_impl, static_argnames=("cfg", "A", "B", "backend",
                                                "fused"))
encode.__doc__ = _encode_impl.__doc__

# chunk variant: the incoming chunk buffer is donated (same shape/dtype as
# the returned xhat, so XLA can reuse it) — used only by encode_dataset,
# whose chunks are freshly device_put host slices.
_encode_chunk = jax.jit(_encode_impl, static_argnames=("cfg", "A", "B",
                                                       "backend", "fused"),
                        donate_argnums=(1,))


def encode_dataset(params, x, cfg: QincoConfig, A: Optional[int] = None,
                   B: Optional[int] = None, *, chunk: int = 4096,
                   backend: str = "auto", fused: bool = True, mesh=None,
                   data_axis: str = "data", out_codes=None):
    """Encode a database larger than a device batch, chunk by chunk.

    Every chunk has the same static shape (the tail is zero-padded and
    sliced off), so the whole dataset reuses ONE compiled executable; chunk
    buffers are donated. With ``mesh``, each chunk is shard_mapped over
    ``data_axis`` (params replicated — the paper's DDP database-encode
    layout). Results land in host memory (``out_codes`` may preallocate).

    Host<->device staging is double-buffered: chunk i+1 is device_put and
    its encode dispatched (JAX dispatch is async) BEFORE chunk i's results
    are fetched back to host, so the host readback of one chunk overlaps
    the device compute of the next — the billion-vector pipeline shape.

    Returns (codes (N, M) int32 np.ndarray, xhat (N, d) np.ndarray, mse).
    """
    A = A or cfg.A_eval
    B = B or cfg.B_eval
    x = np.asarray(x)
    N, d = x.shape
    chunk = max(1, min(chunk, N))
    if mesh is not None:
        nsh = mesh.shape[data_axis]
        chunk = max(nsh, chunk - chunk % nsh)
        fn = _make_sharded_chunk_encoder(cfg, A, B, backend, fused, mesh,
                                         data_axis)
    else:
        fn = partial(_encode_chunk, cfg=cfg, A=A, B=B, backend=backend,
                     fused=fused)

    codes = out_codes if out_codes is not None else np.empty((N, cfg.M),
                                                             np.int32)
    xhat = np.empty((N, d), np.float32)

    def drain(pending):
        plo, phi, c, xh = pending
        codes[plo:phi] = np.asarray(c)[:phi - plo]        # blocks here
        xhat[plo:phi] = np.asarray(xh)[:phi - plo]

    pending = None                                        # one-deep pipeline
    for lo in range(0, N, chunk):
        hi = min(lo + chunk, N)
        xc = x[lo:hi]
        if hi - lo < chunk:                               # static tail shape
            xc = np.concatenate(
                [xc, np.zeros((chunk - (hi - lo), d), x.dtype)])
        c, xh, _ = fn(params, jax.device_put(xc))         # async dispatch
        if pending is not None:
            drain(pending)
        pending = (lo, hi, c, xh)
    if pending is not None:
        drain(pending)
    mse = float(np.mean(np.sum((x - xhat) ** 2, axis=-1)))
    return codes, xhat, mse


def _make_sharded_chunk_encoder(cfg, A, B, backend, fused, mesh, data_axis):
    from jax.sharding import PartitionSpec as P

    from repro.parallel import compat

    def run(params, xc):
        def local(params, x_loc):
            codes, xhat, mse = _encode_impl(params, x_loc, cfg, A, B,
                                            backend, fused)
            # per-shard means are equal-weighted (chunks divide evenly
            # over the axis), so pmean == the chunk-global mean — and the
            # out_spec below promises a replicated scalar
            return codes, xhat, jax.lax.pmean(mse, data_axis)

        pspec = jax.tree.map(lambda _: P(), params)
        return compat.shard_map(
            local, mesh=mesh,
            in_specs=(pspec, P(data_axis)),
            out_specs=(P(data_axis), P(data_axis), P()),
            check_vma=False)(params, xc)

    return jax.jit(run, donate_argnums=(1,))


@partial(jax.jit, static_argnames=("cfg",))
def train_forward(params, x, codes, cfg: QincoConfig):
    """Differentiable teacher-forced pass on the selected codes.

    loss = sum_m ||x - xhat^m||^2 (per-step reconstruction, as in QINCo)
         + aux: pre-codebook C~ regression toward the step residuals.
    """
    traj = qinco.decode_partial(params, codes, cfg)       # (N, M, d)
    errs = jnp.sum(jnp.square(x[:, None, :] - traj), axis=-1)   # (N, M)
    main = jnp.mean(jnp.sum(errs, axis=1))

    # residual targets r^m = x - xhat^{m-1} (stop-grad), pre-codebook entries
    prev = jnp.concatenate([jnp.zeros_like(traj[:, :1]), traj[:, :-1]], 1)
    resid = lax.stop_gradient(x[:, None, :] - prev)       # (N, M, d)
    pre = params["pre_codebooks"]                         # (M, K, d)
    # gather C~[m, codes[n, m]] -> (N, M, d)
    sel = pre[jnp.arange(cfg.M)[None, :], codes]          # (N, M, d)
    aux = jnp.mean(jnp.sum(jnp.square(resid - sel), axis=-1))
    return main + aux, (main, aux, jnp.mean(errs[:, -1]))


def reconstruction_mse(params, x, cfg: QincoConfig, A=None, B=None):
    _, xhat, _ = encode(params, x, cfg, A, B)
    return jnp.mean(jnp.sum(jnp.square(x - xhat), axis=-1))
