"""Classic MCQ baselines: RQ (greedy + beam), PQ, OPQ.

These are both Table-3 baselines and the initialization path for QINCo2
(noisy RQ codebooks, paper App. A.2).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.kmeans import kmeans, pairwise_sqdist


# ---------------------------------------------------------------------------
# Residual Quantization
# ---------------------------------------------------------------------------


def rq_train(key, x, M: int, K: int, iters: int = 10):
    """Sequential k-means on residuals -> codebooks (M, K, d)."""
    cbs = []
    r = x
    for m in range(M):
        key, sub = jax.random.split(key)
        c, a = kmeans(sub, r, K, iters)
        cbs.append(c)
        r = r - c[a]
    return jnp.stack(cbs)


@partial(jax.jit, static_argnames=("B",))
def rq_encode(codebooks, x, B: int = 1):
    """Beam-search RQ encode. codebooks: (M, K, d); x: (N, d).

    Returns (codes (N, M), xhat (N, d))."""
    M, K, d = codebooks.shape
    N = x.shape[0]
    xhat = jnp.zeros((N, 1, d), x.dtype)
    codes = jnp.zeros((N, 1, M), jnp.int32)
    err = jnp.zeros((N, 1), x.dtype)

    for m in range(M):
        cb = codebooks[m]
        Bcur = xhat.shape[1]
        r = x[:, None, :] - xhat
        d2 = (jnp.sum(r * r, -1, keepdims=True)
              - 2.0 * jnp.einsum("nbd,kd->nbk", r, cb)
              + jnp.sum(cb * cb, -1))                    # (N, Bcur, K)
        total = err[..., None] + d2
        k = min(B, Bcur * K)
        top, flat = lax.top_k(-total.reshape(N, Bcur * K), k)
        b_idx, k_idx = flat // K, flat % K
        xhat = (jnp.take_along_axis(xhat, b_idx[..., None], 1)
                + cb[k_idx])
        codes = jnp.take_along_axis(codes, b_idx[..., None], 1)
        codes = codes.at[:, :, m].set(k_idx)
        err = -top

    best = jnp.argmin(err, 1)
    return (jnp.take_along_axis(codes, best[:, None, None], 1)[:, 0],
            jnp.take_along_axis(xhat, best[:, None, None], 1)[:, 0])


def rq_decode(codebooks, codes):
    M = codebooks.shape[0]
    return jnp.sum(codebooks[jnp.arange(M)[None], codes], axis=1)


# ---------------------------------------------------------------------------
# Product Quantization / OPQ
# ---------------------------------------------------------------------------


def pq_train(key, x, M: int, K: int, iters: int = 10):
    """x: (N, d), d % M == 0 -> codebooks (M, K, d//M)."""
    N, d = x.shape
    ds = d // M
    xs = x.reshape(N, M, ds)
    cbs = []
    for m in range(M):
        key, sub = jax.random.split(key)
        c, _ = kmeans(sub, xs[:, m], K, iters)
        cbs.append(c)
    return jnp.stack(cbs)


def pq_encode(codebooks, x):
    M, K, ds = codebooks.shape
    xs = x.reshape(x.shape[0], M, ds)
    d2 = jnp.stack([pairwise_sqdist(xs[:, m], codebooks[m])
                    for m in range(M)], axis=1)          # (N, M, K)
    return jnp.argmin(d2, axis=-1)


def pq_decode(codebooks, codes):
    M = codebooks.shape[0]
    parts = codebooks[jnp.arange(M)[None], codes]        # (N, M, ds)
    return parts.reshape(codes.shape[0], -1)


def opq_train(key, x, M: int, K: int, iters: int = 10, outer: int = 5):
    """OPQ (Ge et al. 2013): alternate PQ fit and Procrustes rotation."""
    d = x.shape[1]
    R = jnp.eye(d)
    cbs = pq_train(key, x, M, K, iters)
    for _ in range(outer):
        xr = x @ R
        codes = pq_encode(cbs, xr)
        xhat = pq_decode(cbs, codes)
        # R = argmin ||xR - xhat||: Procrustes on x^T xhat
        u, _, vt = jnp.linalg.svd(x.T @ xhat, full_matrices=False)
        R = u @ vt
        key, sub = jax.random.split(key)
        cbs = pq_train(sub, x @ R, M, K, iters)
    return cbs, R


def opq_encode(cbs_R, x):
    cbs, R = cbs_R
    return pq_encode(cbs, x @ R)


def opq_decode(cbs_R, codes):
    cbs, R = cbs_R
    return pq_decode(cbs, codes) @ R.T
