"""BEYOND-PAPER: residual-quantized KV caches for LM decode.

Applies the paper's RQ machinery to per-head key/value vectors: each
(head_dim,) vector is encoded to `m_bytes` codes against per-(layer, head)
codebooks fitted offline with k-means on sampled K/V activations. Decode
attention dequantizes cache tiles with the one-hot MXU trick
(`kernels/kv_dequant_attn.py` fuses this with the attention math).

Compression: head_dim * 2 bytes (bf16) -> m_bytes, e.g. 128-dim head at
4 bytes = 64x. The decode-roofline memory term scales down accordingly
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans
from repro.models.dense import _dequant_chunk, _rq_encode_vec


def fit_kv_codebooks(key, kv_samples, m_bytes: int, codebook_size: int,
                     iters: int = 8):
    """kv_samples: (S, KVH, D) -> codebooks (KVH, m_bytes, K, D).

    Residual k-means per head: codebook m fits the residual left by
    codebooks < m (exactly RQ training on the K/V vector stream)."""
    S, KVH, D = kv_samples.shape
    books = []
    r = jnp.moveaxis(kv_samples, 1, 0).astype(jnp.float32)   # (KVH, S, D)
    for m in range(m_bytes):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, KVH)
        cb, asn = jax.vmap(lambda k, x: kmeans(k, x, codebook_size, iters)
                           )(keys, r)
        books.append(cb)
        r = r - jax.vmap(lambda c, a: c[a])(cb, asn)
    return jnp.stack(books, axis=1)                          # (KVH, M, K, D)


def encode_kv(x, codebooks):
    """x: (..., KVH, D) -> codes (..., KVH, m_bytes) uint8."""
    return _rq_encode_vec(x, codebooks)


def decode_kv(codes, codebooks):
    """codes: (B, T, KVH, m) -> (B, T, KVH, D)."""
    return _dequant_chunk(codes, codebooks)


def quantization_mse(x, codebooks):
    codes = encode_kv(x, codebooks)
    xhat = decode_kv(codes[None] if codes.ndim == 3 else codes,
                     codebooks)
    if x.ndim == 3:
        xhat = xhat[0]
    return jnp.mean(jnp.sum(jnp.square(x - xhat), axis=-1))


def compression_ratio(head_dim: int, m_bytes: int,
                      act_bytes: float = 2.0) -> float:
    return head_dim * act_bytes / m_bytes
