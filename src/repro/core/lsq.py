"""LSQ-style additive quantization baseline (Martinez et al. 2018).

Encoding: iterated conditional modes (ICM) — cycle through the M code
positions, re-picking each code to minimize the residual given the others
fixed. Codebook update: the joint least-squares solve from core/aq.py.
A light version of LSQ++ (no annealed perturbations), enough for the
Table 3 baseline ordering.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import aq as aq_mod
from repro.core import rq as rq_mod


@partial(jax.jit, static_argnames=("sweeps",))
def icm_encode(codebooks, x, codes, sweeps: int = 2):
    """codes: (N, M) warm start; returns improved codes."""
    M, K, d = codebooks.shape

    def one_sweep(codes, _):
        def update_m(codes, m):
            recon = aq_mod.aq_decode(codebooks, codes)
            partial_ = recon - codebooks[m, codes[:, m]]
            r = x - partial_
            d2 = (jnp.sum(r * r, -1, keepdims=True)
                  - 2.0 * r @ codebooks[m].T
                  + jnp.sum(codebooks[m] ** 2, -1))
            return codes.at[:, m].set(jnp.argmin(d2, -1).astype(codes.dtype)), None

        codes, _ = jax.lax.scan(update_m, codes, jnp.arange(M))
        return codes, None

    codes, _ = jax.lax.scan(one_sweep, codes, None, length=sweeps)
    return codes


def lsq_train(key, x, M: int, K: int, *, outer: int = 4, icm_sweeps: int = 2):
    """Alternate ICM encoding and least-squares codebook updates."""
    cbs = rq_mod.rq_train(key, x, M, K)
    codes, _ = rq_mod.rq_encode(cbs, x, B=1)
    for _ in range(outer):
        codes = icm_encode(cbs, x, codes, icm_sweeps)
        cbs = aq_mod.fit_aq(codes, x, M, K)
    return cbs


def lsq_encode(codebooks, x, *, icm_sweeps: int = 4):
    M, K, _ = codebooks.shape
    # warm start greedily (RQ-style) then ICM
    codes, _ = rq_mod.rq_encode(codebooks, x, B=1)
    return icm_encode(codebooks, x, codes, icm_sweeps)


lsq_decode = aq_mod.aq_decode
