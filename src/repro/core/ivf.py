"""IVF coarse quantizer (paper §3.3, Fig. 3).

TPU adaptation: the HNSW graph walk over IVF centroids is replaced by a
brute-force centroid matmul + top_k (MXU-friendly; DESIGN.md §3). Buckets
are laid out as a padded dense (K_ivf, bucket_cap) table so that gathering
N_probe buckets is a static-shape operation.

Also provides the RQ quantization of IVF centroids (codes I~) consumed by
the pairwise decoder (integration of pairwise decoding with IVF).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rq as rq_mod
from repro.core.kmeans import assign as kmeans_assign
from repro.core.kmeans import kmeans, pairwise_sqdist


@dataclasses.dataclass
class IVFIndex:
    centroids: jnp.ndarray        # (K_ivf, d)
    buckets: jnp.ndarray          # (K_ivf, cap) int32 ids into the database
    bucket_mask: jnp.ndarray      # (K_ivf, cap) bool (False = padding)
    assignments: jnp.ndarray      # (N,) bucket of each db vector
    centroid_codes: Optional[jnp.ndarray] = None   # (K_ivf, M~) I~ codes
    centroid_rq_books: Optional[jnp.ndarray] = None  # (M~, K, d)


jax.tree_util.register_dataclass(
    IVFIndex,
    data_fields=("centroids", "buckets", "bucket_mask", "assignments",
                 "centroid_codes", "centroid_rq_books"),
    meta_fields=())


def bucket_cap(n: int, k_ivf: int, cap_factor: float = 2.0) -> int:
    """Rows per padded bucket. cap_factor >= 1 guarantees total capacity
    k_ivf * cap >= n, so spilling always finds a non-full bucket."""
    return int(np.ceil(n / k_ivf * cap_factor))


def assign_with_spill(xb, centroids, assign, cap: int, fill=None):
    """Enforce the bucket capacity WITHOUT dropping vectors: a vector whose
    nearest centroid's bucket is full spills to the nearest non-full one
    (and its assignment is updated so residuals/probing stay consistent).

    Rows are processed in index order, which makes the result deterministic
    and streaming-friendly: pass the running ``fill`` counts to continue
    across shards (`index/builder.py`). Returns (assignments, fill), both
    np arrays.

    Fast path: when no bucket can overflow within this batch (the common
    case at cap_factor >= 2 — one vectorized bincount check), all rows are
    accepted in bulk. Otherwise only rows targeting at-risk buckets are
    walked one by one (runs of safe rows advance in bulk), so billion-
    scale builds never pay a Python loop per vector — even when one hot
    bucket stays full for the rest of the stream. Both paths are exactly
    equivalent to the naive sequential loop.
    """
    xb = np.asarray(xb)
    centroids = np.asarray(centroids)
    assign = np.asarray(assign).astype(np.int32).copy()
    k_ivf = centroids.shape[0]
    fill = np.zeros(k_ivf, np.int64) if fill is None else np.asarray(
        fill, np.int64).copy()
    incoming = np.bincount(assign, minlength=k_ivf)
    if np.all(fill + incoming <= cap):             # nothing can overflow
        return assign, fill + incoming
    # Slow path — but only rows targeting "at-risk" buckets are walked one
    # by one. S upper-bounds the spilled-row count by fixpoint (each spill
    # could land in any bucket); a bucket with fill + incoming + S <= cap
    # then can NEVER be full when one of its own rows arrives, so those
    # rows are accepted under any interleaving and are advanced in bulk
    # (segment bincounts keep the sequential walk's per-bucket fills
    # exact, including safe buckets as potential spill targets).
    S, prev = 0, -1
    while S != prev and S < len(assign):
        prev = S
        S = int(np.sum(np.maximum(fill + incoming + S - cap, 0)))
    risky = fill + incoming + S > cap              # (k_ivf,) bool
    seg_start = 0
    for i in np.flatnonzero(risky[assign]):
        if i > seg_start:
            fill += np.bincount(assign[seg_start:i], minlength=k_ivf)
        b = assign[i]
        if fill[b] >= cap:
            d2 = np.sum((xb[i] - centroids) ** 2, axis=-1)
            for nb in np.argsort(d2, kind="stable"):
                if fill[nb] < cap:
                    b = int(nb)
                    break
            else:
                raise ValueError(
                    f"all {k_ivf} buckets full at cap={cap} (n > k_ivf*cap)")
            assign[i] = b
        fill[b] += 1
        seg_start = i + 1
    if seg_start < len(assign):
        fill += np.bincount(assign[seg_start:], minlength=k_ivf)
    return assign, fill


def buckets_from_assignments(assign, k_ivf: int, cap: int):
    """Rebuild the padded dense bucket table from final assignments.

    Vector ids appear within each bucket in increasing order — the same
    order the build-time fill loop produces — so a store that persists
    only assignments reconstructs `buckets`/`bucket_mask` bit-identically
    (`index/store.py` relies on this). Assignments must already respect
    ``cap`` (i.e. post-spill). Vectorized: no per-row Python loop.
    """
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=k_ivf)
    if counts.max(initial=0) > cap:
        raise ValueError(f"bucket count {counts.max()} exceeds cap {cap}; "
                         f"assignments were not capacity-enforced")
    order = np.argsort(assign, kind="stable")      # bucket-major, id-ascending
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(len(assign)) - np.repeat(starts, counts)
    buckets = np.zeros((k_ivf, cap), np.int32)
    mask = np.zeros((k_ivf, cap), bool)
    buckets[assign[order], pos] = order
    mask[assign[order], pos] = True
    return buckets, mask


def within_bucket_ranks(assign, k_ivf: int, fill=None):
    """Bucket-table slot of each row: its rank among same-bucket rows,
    continued from running ``fill`` counts.

    For rows streamed in id order this reproduces, per row, the column
    that `buckets_from_assignments` would place it at in the dense
    (K_ivf, cap) table — the per-shard metadata the out-of-core
    `ShardedIndexView` derives from each shard's assignments (pass the
    cumulative fill of earlier shards as ``fill``). Vectorized, same
    argsort trick as `buckets_from_assignments`.

    Returns (ranks (n,) int32, updated fill (k_ivf,) int64).
    """
    assign = np.asarray(assign)
    fill = (np.zeros(k_ivf, np.int64) if fill is None
            else np.asarray(fill, np.int64).copy())
    counts = np.bincount(assign, minlength=k_ivf)
    order = np.argsort(assign, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    local = np.empty(len(assign), np.int64)
    local[order] = np.arange(len(assign)) - np.repeat(starts, counts)
    ranks = (local + fill[assign]).astype(np.int32)
    return ranks, fill + counts


def build_ivf(key, xb, k_ivf: int, *, kmeans_iters: int = 10,
              cap_factor: float = 2.0, m_tilde: int = 0, K: int = 256):
    """Train coarse centroids on xb and bucket the database.

    Bucket overflow spills to the nearest non-full centroid instead of
    silently dropping the vector (which made it unsearchable).
    """
    n = xb.shape[0]
    cent, assign = kmeans(key, xb, k_ivf, kmeans_iters)
    cap = bucket_cap(n, k_ivf, cap_factor)
    assign_np, _ = assign_with_spill(xb, cent, assign, cap)
    buckets, mask = buckets_from_assignments(assign_np, k_ivf, cap)
    idx = IVFIndex(centroids=cent, buckets=jnp.asarray(buckets),
                   bucket_mask=jnp.asarray(mask),
                   assignments=jnp.asarray(assign_np))
    if m_tilde > 0:
        key, sub = jax.random.split(key)
        books = rq_mod.rq_train(sub, cent, m_tilde, K)
        codes, _ = rq_mod.rq_encode(books, cent, B=4)
        idx.centroid_codes = codes
        idx.centroid_rq_books = books
    return idx


def probe_buckets(centroids, q, n_probe: int):
    """q: (Q, d) -> probed bucket ids (Q, n_probe), best-first.

    The bucket-table-free half of `probe`: all a sharded/out-of-core
    reader needs (it derives candidates from per-shard assignment
    metadata instead of one resident bucket table). Kept as the single
    implementation so resident and sharded search probe identically."""
    d2 = pairwise_sqdist(q, centroids)
    _, top = jax.lax.top_k(-d2, n_probe)                  # (Q, n_probe)
    return top


def probe(index: IVFIndex, q, n_probe: int):
    """q: (Q, d) -> (bucket ids (Q, n_probe), candidate ids (Q, n_probe*cap),
    candidate mask)."""
    top = probe_buckets(index.centroids, q, n_probe)
    cand = index.buckets[top].reshape(q.shape[0], -1)
    mask = index.bucket_mask[top].reshape(q.shape[0], -1)
    return top, cand, mask


def residual_to_centroid(index: IVFIndex, x, assignment):
    return x - index.centroids[assignment]


def assign_to_centroids(centroids, x):
    """Nearest-centroid assignment (N,) int32 — the streaming builder's
    per-shard coarse quantization. Thin host-side wrapper over
    `kmeans.assign` so assignment semantics live in one place."""
    return np.asarray(kmeans_assign(jnp.asarray(x),
                                    jnp.asarray(centroids))).astype(np.int32)
