"""IVF coarse quantizer (paper §3.3, Fig. 3).

TPU adaptation: the HNSW graph walk over IVF centroids is replaced by a
brute-force centroid matmul + top_k (MXU-friendly; DESIGN.md §3). Buckets
are laid out as a padded dense (K_ivf, bucket_cap) table so that gathering
N_probe buckets is a static-shape operation.

Also provides the RQ quantization of IVF centroids (codes I~) consumed by
the pairwise decoder (integration of pairwise decoding with IVF).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rq as rq_mod
from repro.core.kmeans import kmeans, pairwise_sqdist


@dataclasses.dataclass
class IVFIndex:
    centroids: jnp.ndarray        # (K_ivf, d)
    buckets: jnp.ndarray          # (K_ivf, cap) int32 ids into the database
    bucket_mask: jnp.ndarray      # (K_ivf, cap) bool (False = padding)
    assignments: jnp.ndarray      # (N,) bucket of each db vector
    centroid_codes: Optional[jnp.ndarray] = None   # (K_ivf, M~) I~ codes
    centroid_rq_books: Optional[jnp.ndarray] = None  # (M~, K, d)


jax.tree_util.register_dataclass(
    IVFIndex,
    data_fields=("centroids", "buckets", "bucket_mask", "assignments",
                 "centroid_codes", "centroid_rq_books"),
    meta_fields=())


def build_ivf(key, xb, k_ivf: int, *, kmeans_iters: int = 10,
              cap_factor: float = 2.0, m_tilde: int = 0, K: int = 256):
    """Train coarse centroids on xb and bucket the database."""
    n = xb.shape[0]
    cent, assign = kmeans(key, xb, k_ivf, kmeans_iters)
    cap = int(np.ceil(n / k_ivf * cap_factor))
    assign_np = np.asarray(assign)
    buckets = np.full((k_ivf, cap), 0, np.int32)
    mask = np.zeros((k_ivf, cap), bool)
    fill = np.zeros(k_ivf, np.int32)
    for i, b in enumerate(assign_np):
        if fill[b] < cap:
            buckets[b, fill[b]] = i
            mask[b, fill[b]] = True
            fill[b] += 1
    idx = IVFIndex(centroids=cent, buckets=jnp.asarray(buckets),
                   bucket_mask=jnp.asarray(mask),
                   assignments=jnp.asarray(assign_np))
    if m_tilde > 0:
        key, sub = jax.random.split(key)
        books = rq_mod.rq_train(sub, cent, m_tilde, K)
        codes, _ = rq_mod.rq_encode(books, cent, B=4)
        idx.centroid_codes = codes
        idx.centroid_rq_books = books
    return idx


def probe(index: IVFIndex, q, n_probe: int):
    """q: (Q, d) -> (bucket ids (Q, n_probe), candidate ids (Q, n_probe*cap),
    candidate mask)."""
    d2 = pairwise_sqdist(q, index.centroids)
    _, top = jax.lax.top_k(-d2, n_probe)                  # (Q, n_probe)
    cand = index.buckets[top].reshape(q.shape[0], -1)
    mask = index.bucket_mask[top].reshape(q.shape[0], -1)
    return top, cand, mask


def residual_to_centroid(index: IVFIndex, x, assignment):
    return x - index.centroids[assignment]
