"""QINCo2 model: implicit neural codebooks (paper §3, App. A.1).

f_theta (Eq. 10-13), per step m:
    c_emb = P_d^de(c)
    v_0   = c_emb + L_{d+de}^{de}(concat[c_emb ; xhat])     (bias)
    v_i   = v_{i-1} + L_dh^de(relu(L_de^dh(v_{i-1})))       (no bias)
    f     = c + P_de^d(v_L)

Pre-selection g_phi (Eq. 6): with L_s = 0 (paper's Pareto-optimal choice)
g(c|x) = c, i.e. a plain learned codebook C~. L_s >= 1 uses the same
residual architecture with hidden dim 128.

`qinco1_mode` reproduces the QINCo baseline: d_e = d (identity outer
projections) and greedy encoding (A=K, B=1) — used for the Table 3 ladder.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.qinco2 import QincoConfig
from repro.kernels import ops
from repro.models.common import ParamSpec, init_params, is_spec


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _step_specs(cfg: QincoConfig, L: int, de: int, dh: int) -> Dict[str, Any]:
    d = cfg.d
    p: Dict[str, Any] = {
        "concat_w": ParamSpec((d + de, de), (None, None)),
        "concat_b": ParamSpec((de,), (None,), init="zeros"),
        "blocks_w1": ParamSpec((L, de, dh), (None, None, None)),
        "blocks_w2": ParamSpec((L, dh, de), (None, None, None),
                               init="zeros"),     # paper: zero-init down-proj
    }
    if de != d:
        p["in_proj"] = ParamSpec((d, de), (None, None))
        p["out_proj"] = ParamSpec((de, d), (None, None))
    return p


def param_specs(cfg: QincoConfig) -> Dict[str, Any]:
    """All step networks stacked over M (scanned at apply time)."""
    f = _step_specs(cfg, cfg.L, cfg.de, cfg.dh)
    stacked = {k: ParamSpec((cfg.M,) + v.shape, ("steps",) + v.axes, v.dtype,
                            v.init, v.scale) for k, v in f.items()}
    out = {
        "codebooks": ParamSpec((cfg.M, cfg.K, cfg.d), ("steps", None, None),
                               init="normal", scale=0.1),
        "pre_codebooks": ParamSpec((cfg.M, cfg.K, cfg.d),
                                   ("steps", None, None),
                                   init="normal", scale=0.1),
        "f": stacked,
    }
    if cfg.Ls >= 1:
        g = _step_specs(cfg, cfg.Ls, 128, 128)
        out["g"] = {k: ParamSpec((cfg.M,) + v.shape, ("steps",) + v.axes,
                                 v.dtype, v.init, v.scale)
                    for k, v in g.items()}
    return out


def init_from_rq(params, rq_codebooks, key, noise: float = 0.025):
    """Paper init: noisy RQ codebooks (sigma = noise * per-feature std of the
    RQ codebooks), shared by C and C~."""
    s = jnp.std(rq_codebooks)
    eps = noise * s * jax.random.normal(key, rq_codebooks.shape)
    cb = rq_codebooks + eps
    return dict(params, codebooks=cb, pre_codebooks=jnp.array(rq_codebooks))


# ---------------------------------------------------------------------------
# Step network
# ---------------------------------------------------------------------------


def f_apply(step_params, c, xhat, cfg: QincoConfig, *,
            backend: str = "auto"):
    """f_theta^m. c: (..., d); xhat: (..., d) -> (..., d).

    Batch dims of c and xhat broadcast jointly (the encoder passes
    c=(N,B,A,d) against xhat=(N,B,1,d); the L_s>=1 pre-selector passes
    c=(1,1,K,d)). Dispatches through `kernels/ops.f_theta` — the fused
    Pallas step-network kernel on the kernel backend, the historical
    (bit-identical) jnp path on ``backend="xla"``.
    """
    return ops.f_theta(step_params, c, xhat, backend=backend)


def g_apply(params, m_params_g, c, xhat, cfg: QincoConfig, *,
            backend: str = "auto"):
    """g_phi^m (only for L_s >= 1)."""
    return f_apply(m_params_g, c, xhat, cfg, backend=backend)


def step_params_at(params, m):
    """Slice the stacked step params at step m (trace-safe)."""
    return jax.tree.map(lambda a: a[m], params["f"])


# ---------------------------------------------------------------------------
# Decoding (Eq. 4): xhat = sum_m f_theta^m(C^m[i_m] | xhat^{m-1})
# ---------------------------------------------------------------------------


def decode(params, codes, cfg: QincoConfig, *, backend: str = "auto"):
    """codes: (N, M) int (uint8 packed or int32) -> (N, d) reconstruction.

    Each step runs the indexed form of `ops.f_theta`: the per-step code
    column goes into the kernel as indices (packed uint8 stays uint8 on
    the wire) and the codebook gather happens in-kernel.
    """
    N = codes.shape[0]
    xhat0 = jnp.zeros((N, cfg.d), jnp.float32)

    def step(xhat, xs):
        fm, cb, idx = xs
        f = ops.f_theta(fm, cb, xhat, idx=idx[:, None],
                        backend=backend)[:, 0]    # (N, d)
        return xhat + f, None

    xhat, _ = lax.scan(step, xhat0,
                       (params["f"], params["codebooks"], codes.T))
    return xhat


def decode_partial(params, codes, cfg: QincoConfig, *,
                   backend: str = "xla"):
    """Per-step reconstructions (N, M, d) — used for training loss and the
    dynamic-rate evaluation (paper Fig. S3).

    Defaults to the xla backend: this is the differentiated path
    (`encode.train_forward` takes its gradient) and the fused Pallas
    forward kernel defines no VJP.
    """
    N = codes.shape[0]
    xhat0 = jnp.zeros((N, cfg.d), jnp.float32)

    def step(xhat, xs):
        fm, cb, idx = xs
        new = xhat + f_apply(fm, cb[idx], xhat, cfg, backend=backend)
        return new, new

    _, traj = lax.scan(step, xhat0,
                       (params["f"], params["codebooks"], codes.T))
    return jnp.moveaxis(traj, 0, 1)               # (N, M, d)
