"""QINCo2 model: implicit neural codebooks (paper §3, App. A.1).

f_theta (Eq. 10-13), per step m:
    c_emb = P_d^de(c)
    v_0   = c_emb + L_{d+de}^{de}(concat[c_emb ; xhat])     (bias)
    v_i   = v_{i-1} + L_dh^de(relu(L_de^dh(v_{i-1})))       (no bias)
    f     = c + P_de^d(v_L)

Pre-selection g_phi (Eq. 6): with L_s = 0 (paper's Pareto-optimal choice)
g(c|x) = c, i.e. a plain learned codebook C~. L_s >= 1 uses the same
residual architecture with hidden dim 128.

`qinco1_mode` reproduces the QINCo baseline: d_e = d (identity outer
projections) and greedy encoding (A=K, B=1) — used for the Table 3 ladder.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.qinco2 import QincoConfig
from repro.models.common import ParamSpec, init_params, is_spec


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _step_specs(cfg: QincoConfig, L: int, de: int, dh: int) -> Dict[str, Any]:
    d = cfg.d
    p: Dict[str, Any] = {
        "concat_w": ParamSpec((d + de, de), (None, None)),
        "concat_b": ParamSpec((de,), (None,), init="zeros"),
        "blocks_w1": ParamSpec((L, de, dh), (None, None, None)),
        "blocks_w2": ParamSpec((L, dh, de), (None, None, None),
                               init="zeros"),     # paper: zero-init down-proj
    }
    if de != d:
        p["in_proj"] = ParamSpec((d, de), (None, None))
        p["out_proj"] = ParamSpec((de, d), (None, None))
    return p


def param_specs(cfg: QincoConfig) -> Dict[str, Any]:
    """All step networks stacked over M (scanned at apply time)."""
    f = _step_specs(cfg, cfg.L, cfg.de, cfg.dh)
    stacked = {k: ParamSpec((cfg.M,) + v.shape, ("steps",) + v.axes, v.dtype,
                            v.init, v.scale) for k, v in f.items()}
    out = {
        "codebooks": ParamSpec((cfg.M, cfg.K, cfg.d), ("steps", None, None),
                               init="normal", scale=0.1),
        "pre_codebooks": ParamSpec((cfg.M, cfg.K, cfg.d),
                                   ("steps", None, None),
                                   init="normal", scale=0.1),
        "f": stacked,
    }
    if cfg.Ls >= 1:
        g = _step_specs(cfg, cfg.Ls, 128, 128)
        out["g"] = {k: ParamSpec((cfg.M,) + v.shape, ("steps",) + v.axes,
                                 v.dtype, v.init, v.scale)
                    for k, v in g.items()}
    return out


def init_from_rq(params, rq_codebooks, key, noise: float = 0.025):
    """Paper init: noisy RQ codebooks (sigma = noise * per-feature std of the
    RQ codebooks), shared by C and C~."""
    s = jnp.std(rq_codebooks)
    eps = noise * s * jax.random.normal(key, rq_codebooks.shape)
    cb = rq_codebooks + eps
    return dict(params, codebooks=cb, pre_codebooks=jnp.array(rq_codebooks))


# ---------------------------------------------------------------------------
# Step network
# ---------------------------------------------------------------------------


def f_apply(step_params, c, xhat, cfg: QincoConfig):
    """f_theta^m. c: (..., d); xhat: (..., d) -> (..., d).

    Batch dims of c and xhat broadcast jointly (the encoder passes
    c=(N,B,A,d) against xhat=(N,B,1,d); the L_s>=1 pre-selector passes
    c=(1,1,K,d))."""
    p = step_params
    if "in_proj" in p:
        c_emb = c @ p["in_proj"]
    else:
        c_emb = c
    bshape = jnp.broadcast_shapes(c_emb.shape[:-1], xhat.shape[:-1])
    c_emb = jnp.broadcast_to(c_emb, bshape + c_emb.shape[-1:])
    xb = jnp.broadcast_to(xhat, bshape + (cfg.d,))
    v = c_emb + jnp.concatenate([c_emb, xb], axis=-1) @ p["concat_w"] \
        + p["concat_b"]

    def block(v, wb):
        w1, w2 = wb
        return v + jax.nn.relu(v @ w1) @ w2, None

    v, _ = lax.scan(block, v, (p["blocks_w1"], p["blocks_w2"]))
    if "out_proj" in p:
        return c + v @ p["out_proj"]
    return c + v


def g_apply(params, m_params_g, c, xhat, cfg: QincoConfig):
    """g_phi^m (only for L_s >= 1)."""
    return f_apply(m_params_g, c, xhat, cfg)


def step_params_at(params, m):
    """Slice the stacked step params at step m (trace-safe)."""
    return jax.tree.map(lambda a: a[m], params["f"])


# ---------------------------------------------------------------------------
# Decoding (Eq. 4): xhat = sum_m f_theta^m(C^m[i_m] | xhat^{m-1})
# ---------------------------------------------------------------------------


def decode(params, codes, cfg: QincoConfig):
    """codes: (N, M) int32 -> (N, d) reconstruction."""
    N = codes.shape[0]
    xhat0 = jnp.zeros((N, cfg.d), jnp.float32)

    def step(xhat, xs):
        fm, cb, idx = xs
        c = cb[idx]                               # (N, d)
        return xhat + f_apply(fm, c, xhat, cfg), None

    xhat, _ = lax.scan(step, xhat0,
                       (params["f"], params["codebooks"], codes.T))
    return xhat


def decode_partial(params, codes, cfg: QincoConfig):
    """Per-step reconstructions (N, M, d) — used for training loss and the
    dynamic-rate evaluation (paper Fig. S3)."""
    N = codes.shape[0]
    xhat0 = jnp.zeros((N, cfg.d), jnp.float32)

    def step(xhat, xs):
        fm, cb, idx = xs
        new = xhat + f_apply(fm, cb[idx], xhat, cfg)
        return new, new

    _, traj = lax.scan(step, xhat0,
                       (params["f"], params["codebooks"], codes.T))
    return jnp.moveaxis(traj, 0, 1)               # (N, M, d)
