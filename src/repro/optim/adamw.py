"""AdamW with decoupled weight decay, global-norm clipping and optional
low-precision optimizer state (bf16 m/v for the ≥100B configs).

State mirrors the param pytree, so it inherits the param shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    zeros_v = jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)


def abstract_state(abstract_p, cfg: AdamWConfig) -> AdamWState:
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype), abstract_p)
    z2 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype), abstract_p)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z2)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    metrics["lr"] = lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g32
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g32 * g32
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (delta + cfg.weight_decay * p32)
        return (p32.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
