"""LR schedules (paper App. A.2: cosine to min_ratio with linear warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(max_lr: float, total_steps: int,
                       warmup_steps: int = 0, min_ratio: float = 1e-3):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = max_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, max_lr * cos)
    return schedule


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
