"""Host data pipeline: deterministic sharded batching with prefetch.

Each host feeds its local devices; global determinism comes from seeding by
(step, host). `ShardedLoader.checkpoint_state()` makes the input pipeline
restartable — resuming a run replays from the exact step.
"""
from __future__ import annotations

import collections
import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    """Wraps a `make_batch(step) -> dict[str, np.ndarray]` source with a
    background prefetch thread and device placement."""

    def __init__(self, make_batch: Callable[[int], dict], *,
                 start_step: int = 0, prefetch: int = 2,
                 sharding=None):
        self._make = make_batch
        self._step = start_step
        self._sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        self._step = step + 1
        if self._sharding is not None:
            batch = jax.tree.map(
                lambda a, s: jax.device_put(a, s), batch, self._sharding)
        return step, batch

    def checkpoint_state(self) -> dict:
        return {"step": self._step}

    def close(self):
        self._stop.set()


def host_shard(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Slice the per-host portion of a global batch (multi-host layout)."""
    def cut(a):
        per = a.shape[0] // n_hosts
        return a[host_id * per:(host_id + 1) * per]
    return jax.tree.map(cut, batch)
