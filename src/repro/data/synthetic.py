"""Synthetic datasets standing in for the paper's four benchmarks.

The real sets (Deep1B, BigANN, FB-ssnpp, Contriever) are unavailable
offline; we match dimensionality and generate anisotropic Gaussian-mixture
data (clustered like CNN/SIFT embeddings) so *relative* claims are testable
(DESIGN.md §7). Also provides LM token streams for the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

DATASET_DIMS = {
    "bigann": 128,     # SIFT descriptors
    "deep1b": 96,      # CNN image embeddings
    "fb-ssnpp": 256,   # SSCD image embeddings
    "contriever": 768, # text embeddings
}


def make_vectors(name: str, n: int, *, seed: int = 0,
                 n_clusters: Optional[int] = None,
                 dim: Optional[int] = None) -> np.ndarray:
    """Clustered anisotropic GMM matching the named dataset's dim."""
    d = dim or DATASET_DIMS[name]
    n_clusters = n_clusters or max(32, d // 2)
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * 2.0
    # anisotropic per-cluster covariances (low-rank + diag, like real emb.)
    ranks = 8
    lows = rng.normal(size=(n_clusters, d, ranks)).astype(np.float32) * 0.5
    assign = rng.integers(0, n_clusters, size=n)
    z = rng.normal(size=(n, ranks)).astype(np.float32)
    eps = rng.normal(size=(n, d)).astype(np.float32) * 0.3
    x = centers[assign] + np.einsum("ndr,nr->nd", lows[assign], z) + eps
    return x.astype(np.float32)


def make_splits(name: str, *, n_train: int, n_db: int, n_query: int,
                seed: int = 0):
    """(train, database, queries, ground-truth nn ids)."""
    x = make_vectors(name, n_train + n_db + n_query, seed=seed)
    xt, xb, xq = (x[:n_train], x[n_train:n_train + n_db],
                  x[n_train + n_db:])
    # queries perturbed toward db points for non-trivial recall
    rng = np.random.default_rng(seed + 1)
    pick = rng.integers(0, n_db, size=n_query)
    xq = 0.7 * xq + 0.3 * xb[pick]
    gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)
    return xt, xb, xq, gt


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


def batch_at(vocab: int, seq_len: int, batch: int, step: int, *,
             seed: int = 0) -> dict:
    """Random-access deterministic LM batch (noisy Markov chain, learnable):
    restart-safe by construction — batch(step) depends only on (args)."""
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=(vocab, 4))    # transition structure
    srng = np.random.default_rng((seed + 1) * 1_000_003 + step)
    toks = np.empty((batch, seq_len + 1), np.int32)
    toks[:, 0] = srng.integers(0, vocab, size=batch)
    choice = srng.integers(0, 4, size=(batch, seq_len))
    noise = srng.random((batch, seq_len)) < 0.1
    rand = srng.integers(0, vocab, size=(batch, seq_len))
    for t in range(seq_len):
        nexts = nxt[toks[:, t], choice[:, t]]
        toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nexts)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def token_stream(vocab: int, seq_len: int, batch: int, *, seed: int = 0
                 ) -> Iterator[dict]:
    """Iterator view over batch_at."""
    step = 0
    while True:
        yield batch_at(vocab, seq_len, batch, step, seed=seed)
        step += 1
