import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""§Perf hillclimbing: hypothesis -> change -> re-lower -> re-analyse.

Three cells (worst roofline fraction / most collective-bound / most
paper-representative), each with a ladder of variants. Every variant is a
REAL config change (re-lowered and re-compiled at the production mesh);
the record keeps both the analytic roofline terms and the HLO-parsed
collective schedule as evidence.

    PYTHONPATH=src python -m repro.launch.perf --cell all
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_arch
from repro.launch.dryrun import run_cell
from repro.launch.mesh import HW


def _variants():
    """cell -> [(variant_name, hypothesis, arch_override_fn, kv_quant)]"""
    mamba = get_arch("mamba2-1.3b")
    kimi = get_arch("kimi-k2-1t-a32b")
    dsc = get_arch("deepseek-coder-33b")
    cham = get_arch("chameleon-34b")

    def rp(a, **kw):
        return dataclasses.replace(
            a, parallel=dataclasses.replace(a.parallel, **kw))

    return {
        # -- worst roofline fraction: tiny model strangled by TP-16 ----------
        "mamba2_train": [
            ("baseline", "paper-faithful default mapping (TP over model)",
             lambda: mamba, False),
            ("dp_only_fsdp",
             "1.3B params need no TP at 4k seq: map the model axis to data "
             "parallelism + FSDP; collective term should drop ~6x "
             "(62L x 2 AR x 1.9 x 0.5GB activations -> one 5.2GB param "
             "AG/RS pipeline)",
             lambda: rp(mamba, dp_only=True, fsdp=True), False),
            ("dp_only_fsdp_bf16",
             "params/opt in bf16 halve the FSDP all-gather bytes again",
             lambda: rp(mamba, dp_only=True, fsdp=True,
                        param_dtype="bfloat16",
                        opt_state_dtype="bfloat16"), False),
        ],
        # -- most collective-bound: 1T MoE -----------------------------------
        "kimi_train": [
            ("baseline", "paper-faithful default (TP+FSDP+EP)",
             lambda: kimi, False),
            ("parallel_block",
             "PaLM-style fused attn+MoE block: one TP all-reduce per layer "
             "instead of two -> TP AR volume halves (~8.6s -> ~4.3s)",
             lambda: rp(kimi, parallel_block=True), False),
            ("parallel_block_moe2d",
             "2D expert sharding (experts x model, expert-FFN x data): "
             "expert weights (97% of 1T) are never all-gathered; dispatch "
             "buffers cross `data` instead (~9GB vs ~240GB per step)",
             lambda: rp(kimi, parallel_block=True, moe_2d=True), False),
            ("pb_moe2d_remat_dots",
             "with collectives down, recompute less: remat full->dots cuts "
             "the backward recompute (compute term x0.825)",
             lambda: rp(kimi, parallel_block=True, moe_2d=True,
                        remat_policy="dots"), False),
        ],
        # -- multi-pod: the DCN gradient exchange ----------------------------
        "kimi_train_pod2": [
            ("no_compress",
             "cross-pod fp-precision gradient all-reduce rides DCN "
             "(6.25 GB/s): ~8GB/device of gradient per step -> +1.3s",
             lambda: rp(kimi, parallel_block=True, moe_2d=True,
                        grad_compress_pods=False), False),
            ("int8_compress",
             "int8+per-block-scale gradient exchange (core/grad_compress): "
             "4x fewer DCN bytes. NOTE: the in-graph shard_map integration "
             "trips an XLA SPMD partitioner CHECK (partial-manual around a "
             "GSPMD interior, b/433785288-adjacent); the collective itself "
             "is validated full-manual in tests, the 512-chip row uses the "
             "analytic wire model.",
             lambda: rp(kimi, parallel_block=True, moe_2d=True,
                        grad_compress_pods=True), False),
        ],
        # -- bonus: prefill is collective-bound too --------------------------
        "chameleon_prefill": [
            ("baseline", "prefill inherits training TP ARs (2/layer) AND "
             "the FSDP param all-gathers",
             lambda: cham, False),
            ("parallel_block",
             "fused attn+MLP: one TP AR per layer in prefill as well",
             lambda: rp(cham, parallel_block=True), False),
            ("pb_serving_layout",
             "no optimizer at prefill: drop FSDP (params TP-sharded, "
             "data-replicated in bf16) -> param all-gathers vanish",
             lambda: rp(cham, parallel_block=True, fsdp=False,
                        param_dtype="bfloat16"), False),
        ],
        # -- paper-representative: RQ-quantized KV cache for decode ----------
        "deepseek_decode": [
            ("baseline", "bf16 KV cache: 66GB/device, does NOT fit v5e HBM",
             lambda: dsc, False),
            ("kv_quant_rq4",
             "the paper's RQ machinery on K/V vectors (m=4 bytes/head, "
             "64x): cache 66GB -> ~1GB, memory term ~7x down, fits HBM",
             lambda: dsc, True),
            ("kv_quant_bf16_params",
             "with the cache compressed, weights dominate decode reads: "
             "serve with bf16 params (fp32 master stays in the trainer)",
             lambda: rp(dsc, param_dtype="bfloat16"), True),
            ("serving_layout",
             "decode inherits the trainer's FSDP layout -> per-step param "
             "all-gathers over `data` in the HLO; a serving layout (params "
             "TP-sharded, data-replicated) removes them",
             lambda: rp(dsc, param_dtype="bfloat16", fsdp=False), True),
        ],
    }


CELL_SHAPES = {"mamba2_train": "train_4k", "kimi_train": "train_4k",
               "kimi_train_pod2": "train_4k",
               "deepseek_decode": "decode_32k",
               "chameleon_prefill": "prefill_32k"}
CELL_PODS = {"kimi_train_pod2": True}


def run(cell: str, out_dir: Path, multi_pod=False, force=False):
    rows = []
    multi_pod = multi_pod or CELL_PODS.get(cell, False)
    for name, hypothesis, arch_fn, kvq in _variants()[cell]:
        arch = arch_fn()
        tagged = dataclasses.replace(arch, name=f"{arch.name}+{name}")
        rec = run_cell(arch.name, CELL_SHAPES[cell], multi_pod=multi_pod,
                       kv_quant=kvq, out_dir=out_dir, force=force,
                       arch_override=tagged)
        rec["variant"] = name
        rec["hypothesis"] = hypothesis
        (out_dir / f"{tagged.name}__{CELL_SHAPES[cell]}.meta.json"
         ).write_text(json.dumps({"variant": name,
                                  "hypothesis": hypothesis}))
        rows.append(rec)
        if rec.get("error"):
            print(f"  {name}: ERROR {rec['error'][:200]}")
            continue
        fit = rec["analytic"].get("note_hbm_fit_bytes", 0) <= HW["hbm_bytes"]
        print(f"  {name}: t_comp={rec['t_compute_s']:.4f} "
              f"t_mem={rec['t_memory_s']:.4f} "
              f"t_coll={rec['t_collective_s']:.4f} "
              f"dom={rec['bottleneck']} frac={rec['roofline_fraction']:.2f} "
              f"fit={'Y' if fit else 'N'}", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all"] + list(CELL_SHAPES))
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = list(CELL_SHAPES) if args.cell == "all" else [args.cell]
    for c in cells:
        print(f"== {c} ==", flush=True)
        run(c, out, multi_pod=args.multi_pod, force=args.force)


if __name__ == "__main__":
    main()
