"""Batched decode server: prefill -> (optionally RQ-quantized) KV cache ->
autoregressive decode_step loop. CPU-scale demo of the same step the
dry-run lowers at the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --kv-quant
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import kv_quant
from repro.models import lm
from repro.models.common import ShardCtx, abstract_params, init_params


def build_cache_from_prefill(arch, params, batch_tokens, ctx, *, max_len,
                             kv_quant_on=False, frames=None, key=None):
    """Run prefill, fill a decode cache of capacity max_len."""
    B, P = batch_tokens.shape
    batch = {"tokens": batch_tokens}
    if arch.family == "encdec":
        batch["frames"] = frames
    logits, extras = lm.prefill(params, batch, arch, ctx)
    specs = lm.cache_specs(arch, B, max_len, kv_quant_on)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         abstract_params(specs))
    kv = extras.get("kv")
    if arch.family in ("dense", "moe") and kv is not None:
        k, v = kv                                   # (L, B, P, KVH, hd)
        if not kv_quant_on:
            cache = {
                "k": cache["k"].at[:, :, :P].set(k.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, :, :P].set(v.astype(cache["v"].dtype)),
            }
        else:
            # fit per-(layer,head) RQ codebooks on the prefill K/V stream
            kq = arch.kv_quant
            L = k.shape[0]
            samp_k = k.reshape(L, -1, k.shape[-2], k.shape[-1])
            cb_k = jnp.stack([kv_quant.fit_kv_codebooks(
                jax.random.fold_in(key, i), samp_k[i], kq.m_bytes,
                kq.codebook_size) for i in range(L)])
            samp_v = v.reshape(L, -1, v.shape[-2], v.shape[-1])
            cb_v = jnp.stack([kv_quant.fit_kv_codebooks(
                jax.random.fold_in(key, 1000 + i), samp_v[i], kq.m_bytes,
                kq.codebook_size) for i in range(L)])
            codes_k = jax.vmap(kv_quant.encode_kv)(k, cb_k)
            codes_v = jax.vmap(kv_quant.encode_kv)(v, cb_v)
            cache = dict(
                cache,
                k_cb=cb_k.astype(cache["k_cb"].dtype),
                v_cb=cb_v.astype(cache["v_cb"].dtype),
                k_codes=cache["k_codes"].at[:, :, :P].set(
                    codes_k.astype(jnp.uint8)),
                v_codes=cache["v_codes"].at[:, :, :P].set(
                    codes_v.astype(jnp.uint8)),
            )
    elif arch.family == "encdec" and kv is not None:
        (k, v), (xk, xv) = kv
        cache = dict(cache, cross_k=xk.astype(cache["cross_k"].dtype),
                     cross_v=xv.astype(cache["cross_v"].dtype))
        cache["self"] = {
            "k": cache["self"]["k"].at[:, :, :P].set(
                k.astype(cache["self"]["k"].dtype)),
            "v": cache["self"]["v"].at[:, :, :P].set(
                v.astype(cache["self"]["v"].dtype)),
        }
    # ssm/hybrid: decode re-walks the prompt below (constant-size state)
    return logits, cache


def generate(arch, params, prompts, *, gen_len: int, ctx=None,
             kv_quant_on=False, temperature: float = 0.0, seed: int = 0,
             frames=None):
    ctx = ctx or ShardCtx(active=False)
    B, P = prompts.shape
    max_len = P + gen_len
    key = jax.random.key(seed)
    needs_replay = arch.family in ("ssm", "hybrid")
    if needs_replay:
        specs = lm.cache_specs(arch, B, max_len, kv_quant_on)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             abstract_params(specs))
        logits = None
    else:
        logits, cache = build_cache_from_prefill(
            arch, params, prompts, ctx, max_len=max_len,
            kv_quant_on=kv_quant_on, frames=frames, key=key)

    step = jax.jit(lambda p, c, t, pos: lm.decode_step(
        p, c, t, pos, arch, ctx, kv_quant=kv_quant_on))

    out = [prompts]
    if needs_replay:                      # feed the prompt token by token
        for i in range(P):
            logits, cache = step(params, cache, prompts[:, i:i + 1], i)
    tok = _sample(logits[:, -1] if logits.ndim == 3 else logits,
                  temperature, key)
    for g in range(gen_len):
        out.append(tok)
        logits, cache = step(params, cache, tok, P + g)
        key = jax.random.fold_in(key, g)
        tok = _sample(logits[:, -1], temperature, key)
    return jnp.concatenate(out, axis=1)


def _sample(logits, temperature, key):
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jax.random.categorical(key, logits / temperature
                                  ).astype(jnp.int32)[:, None]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    params = init_params(lm.param_specs(arch), jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 arch.vocab_size)
    frames = None
    if arch.family == "encdec":
        frames = jnp.zeros((args.batch, arch.encoder_context, arch.d_model),
                           jnp.float32)
    t0 = time.perf_counter()
    toks = generate(arch, params, prompts, gen_len=args.gen,
                    kv_quant_on=args.kv_quant, frames=frames)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) kv_quant={args.kv_quant}")
    print(np.asarray(toks[:2, args.prompt_len - 4:args.prompt_len + 8]))


if __name__ == "__main__":
    main()
