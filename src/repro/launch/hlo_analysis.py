"""Post-compile HLO analysis: collective bytes, flops, memory.

The compiled module is the SPMD-partitioned per-device program, so parsed
shapes are per-device. Wire-byte models (ring algorithms):

    all-reduce          2 (n-1)/n * B      (B = operand bytes)
    reduce-scatter      (n-1)   * B_out    (operand = n * result)
    all-gather          (n-1)   * B_in     (result = n * operand)
    all-to-all          (n-1)/n * B
    collective-permute  B
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type (scalar or tuple) + collective op name. In post-optimization
# HLO, operands are printed without shapes, so all byte accounting derives
# from the result type and the op semantics.
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shapes_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$")
_CALL_RE = re.compile(
    r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)"
    r"|branch_computations=\{([%\w.\-,\s]+)\}")
_WHILE_RE = re.compile(r"\bwhile\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def _parse_computations(hlo_text: str):
    """Split HLO text into named computations -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMP_RE.match(raw.rstrip())
        if m and ("->" in raw) and raw.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if raw.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None and line:
            comps[cur].append(line)
    return comps, entry


def _multiplicities(comps, entry) -> Dict[str, float]:
    """Execution count per computation, scaling while bodies by trip count."""
    # edges: computation -> [(child, factor)]
    edges: Dict[str, List] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for line in lines:
            trip = 1.0
            if _WHILE_RE.search(line):
                t = _TRIP_RE.search(line)
                if t:
                    trip = float(t.group(1))
            for m in _CALL_RE.finditer(line):
                if m.group(1):
                    children = [m.group(1)]
                else:
                    children = [c.strip() for c in m.group(2).split(",")]
                body = _BODY_RE.search(line)
                for ch in children:
                    ch = ch.lstrip("%")
                    if ch not in comps:
                        continue
                    factor = trip if (body and ch == body.group(1)) else 1.0
                    edges[cname].append((ch, factor))

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    import functools
    import sys
    sys.setrecursionlimit(10000)

    # propagate via DFS from entry (HLO call graphs are DAGs)
    memo_children = edges
    visiting = []

    def visit(c, m):
        for ch, f in memo_children.get(c, []):
            mult[ch] += m * f
            visit(ch, m * f)

    visit(entry, 1.0)
    return mult


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind stats, weighted by enclosing while-loop trip counts.

    Byte figures are per device; `wire_bytes` applies the ring models in the
    module docstring. Collectives inside a scanned layer body are counted
    trip_count times (XLA's own cost analysis counts loop bodies once).
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        comps = {"__all__": hlo_text.splitlines()}
        mult = {"__all__": 1.0}
    else:
        mult = _multiplicities(comps, entry)
    stats = defaultdict(lambda: {"count": 0.0, "operand_bytes": 0.0,
                                 "wire_bytes": 0.0, "max_group": 1,
                                 "static_count": 0})
    for cname, lines in comps.items():
        w = mult.get(cname, 0.0)
        if w == 0.0:
            continue
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            op = m.group(2)
            is_start = m.group(3) is not None
            n = max(_group_size(line), 1)
            res_b = _shapes_bytes(m.group(1))
            if is_start and op in ("all-reduce", "collective-permute"):
                res_b /= 2.0    # async start result is an (in, out) tuple
            if op == "all-reduce":
                wire = 2.0 * (n - 1) / n * res_b
            elif op == "reduce-scatter":
                wire = float(n - 1) * res_b      # operand = n * result
            elif op == "all-gather":
                wire = (n - 1) / n * res_b       # result is gathered (full)
            elif op == "all-to-all":
                wire = (n - 1) / n * res_b
            else:  # collective-permute
                wire = res_b
            s = stats[op]
            s["count"] += w
            s["static_count"] += 1
            s["operand_bytes"] += w * res_b
            s["wire_bytes"] += w * wire
            s["max_group"] = max(s["max_group"], n)
    return dict(stats)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["wire_bytes"] for s in stats.values())


def total_operand_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["operand_bytes"] for s in stats.values())


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def cost_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
