"""Step builders shared by the trainer, server, and the AOT dry-run."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.common import ParamSpec, ShardCtx
from repro.optim import adamw
from repro.parallel import compat


def make_train_step(arch: ArchConfig, ctx: ShardCtx, opt_cfg, mesh=None):
    compress = (arch.parallel.grad_compress_in_graph and mesh is not None
                and "pod" in getattr(mesh, "axis_names", ()))

    def train_step(params, opt_state, batch):
        if compress:
            # One shard_map over the pod axis (data/model stay under GSPMD
            # via auto axes): per-pod partial gradients, then the int8
            # exchange replaces the fp psum GSPMD would insert over DCN.
            from repro.core.grad_compress import dequantize_int8, quantize_int8
            from jax.sharding import PartitionSpec as P

            def inner(p, b):
                loss, g = jax.value_and_grad(
                    lambda q: lm.loss_fn(q, b, arch, ctx))(p)
                loss = jax.lax.pmean(loss, "pod")
                npods = mesh.shape["pod"]

                def reduce_one(x):
                    q8, s = quantize_int8(x)
                    qg = jax.lax.all_gather(q8, "pod")
                    sg = jax.lax.all_gather(s, "pod")
                    deq = jax.vmap(
                        lambda qq, ss: dequantize_int8(qq, ss, x.shape))(
                        qg, sg)
                    return (jnp.sum(deq, 0) / npods).astype(x.dtype)

                return loss, jax.tree.map(reduce_one, g)

            pspec = jax.tree.map(lambda _: P(), params)
            bspec = jax.tree.map(lambda _: P("pod"), batch)
            loss, grads = compat.shard_map(
                inner, mesh=mesh, in_specs=(pspec, bspec),
                out_specs=(P(), pspec), check_vma=False,
                axis_names={"pod"})(params, batch)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, batch, arch, ctx))(params)
        new_p, new_s, metrics = adamw.update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_p, new_s, metrics
    return train_step


def make_prefill_step(arch: ArchConfig, ctx: ShardCtx):
    collect = arch.family in ("dense", "moe", "encdec")

    def prefill_step(params, batch):
        logits, extras = lm.prefill(params, batch, arch, ctx)
        if collect:
            return logits, extras["kv"]
        return logits
    return prefill_step


def make_decode_step(arch: ArchConfig, ctx: ShardCtx, kv_quant: bool = False):
    def decode_step(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, arch, ctx,
                              kv_quant=kv_quant)
    return decode_step


def prefill_kv_specs(arch: ArchConfig, batch: int, seq: int):
    """Axis-annotated specs for the prefill kv output (for out_shardings)."""
    if arch.family not in ("dense", "moe", "encdec"):
        return None
    a = arch.attn
    kv = ParamSpec((arch.n_layers, batch, seq, a.num_kv_heads, a.head_dim),
                   ("layers", "batch", "seq", "kv_heads", None), jnp.float32)
    if arch.family in ("dense", "moe"):
        return (kv, kv)
    if arch.family == "encdec":
        xkv = ParamSpec(
            (arch.n_layers, batch, arch.encoder_context, a.num_kv_heads,
             a.head_dim),
            ("layers", "batch", None, "kv_heads", None), jnp.float32)
        return ((kv, kv), (xkv, xkv))
    return None
