"""Analytic per-device FLOPs / HBM-bytes / collective-bytes model.

Why this exists: XLA's `cost_analysis()` on the CPU backend counts while-loop
bodies ONCE (a scanned 62-layer model under-reports ~62x) and promotes bf16
all-reduces to f32, so compiled numbers are kept as structural cross-checks
while the roofline terms come from this model, which mirrors the exact
einsums in `repro.models.*` (TPU semantics: bf16 compute, flash-fused
attention keeps score matrices in VMEM).

All outputs are per device. Wire-byte ring models match hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm


@dataclasses.dataclass
class CellModel:
    flops: float = 0.0            # per device, whole step
    hbm_bytes: float = 0.0        # per device
    ici_bytes: float = 0.0        # per device, intra-pod wire bytes
    dcn_bytes: float = 0.0        # per device, cross-pod wire bytes
    notes: Dict[str, float] = dataclasses.field(default_factory=dict)


def _bwd_multiplier(policy: str) -> float:
    # fwd+bwd = 3x fwd flops; remat recomputes fwd (approximately) once more
    return {"nothing": 3.0, "dots": 3.3, "full": 4.0}.get(policy, 3.0)


def _layer_token_flops(arch: ArchConfig, ctx_len: float,
                       tp_heads_pad: bool = True) -> Dict[str, float]:
    """Forward flops per token for one layer, split by component."""
    d = arch.d_model
    out: Dict[str, float] = {}
    if arch.attn is not None:
        a = arch.attn
        qkv = 2 * d * (a.num_heads + 2 * a.num_kv_heads) * a.head_dim
        proj = 2 * d * a.num_heads * a.head_dim
        # chunked-masked attention computes full ctx per query (no causal
        # flop saving), local layers cap ctx at the window
        attn = 4 * a.num_heads * a.head_dim * ctx_len
        out["attn_proj"] = qkv + proj
        out["attn_sdpa"] = attn
    if arch.moe is not None:
        m = arch.moe
        out["moe"] = (2 * d * m.num_experts                     # router
                      + m.top_k * 6 * d * m.d_ff_expert
                      + m.num_shared_experts * 6 * d * m.d_ff_shared)
    elif arch.d_ff:
        out["mlp"] = 6 * d * arch.d_ff
    if arch.ssm is not None:
        s = arch.ssm
        di = s.expand * d
        H = di // s.head_dim
        G, N, P, Q = s.ngroups, s.state_dim, s.head_dim, s.chunk_size
        in_dim = 2 * di + 2 * G * N + H
        out["ssm_proj"] = 2 * d * in_dim + 2 * di * d
        out["ssm_conv"] = 2 * s.conv_width * (di + 2 * G * N)
        out["ssm_ssd"] = (2 * Q * G * N + 2 * Q * H * P + 4 * H * P * N)
    return out


def _avg_ctx(arch: ArchConfig, S: int) -> float:
    """Mean attended context per query across layers (train/prefill)."""
    if arch.attn is None:
        return 0.0
    a = arch.attn
    full = S / 2.0                      # causal average
    if a.window is None:
        return full
    local = min(a.window, S / 2.0)
    if a.global_every <= 1:
        return local
    n_glob = arch.n_layers // a.global_every
    n_loc = arch.n_layers - n_glob
    return (n_loc * local + n_glob * full) / arch.n_layers


def _attn_layer_counts(arch: ArchConfig):
    """(# layers with attention, # mamba layers)."""
    if arch.family == "dense":
        return arch.n_layers, 0
    if arch.family == "moe":
        return arch.n_layers, 0
    if arch.family == "ssm":
        return 0, arch.n_layers
    if arch.family == "hybrid":
        n_attn = arch.n_layers // arch.shared_attn_every
        return n_attn, arch.n_layers
    if arch.family == "encdec":
        return arch.n_layers + arch.n_encoder_layers, 0
    raise ValueError(arch.family)


def model_cell(arch: ArchConfig, shape: ShapeConfig,
               mesh_axes: Dict[str, int], *, kv_quant: bool = False
               ) -> CellModel:
    cm = CellModel()
    TP = mesh_axes.get("model", 1)
    DP_pod = mesh_axes.get("data", 1)
    PODS = mesh_axes.get("pod", 1)
    if arch.parallel.dp_only:
        DP_pod *= TP                     # model axis joins data parallelism
        TP = 1
    DP = DP_pod * PODS
    ndev = TP * DP
    B, S = shape.global_batch, shape.seq_len
    batch_sharded = B % DP == 0 and B >= DP
    tokens_g = B * (S if shape.kind != "decode" else 1)
    tokens_dev = tokens_g / (DP if batch_sharded else 1)

    d = arch.d_model
    act_b = 2.0                                         # bf16 activations
    pb = 4.0 if arch.parallel.param_dtype == "float32" else 2.0
    ob = 4.0 if arch.parallel.opt_state_dtype == "float32" else 2.0
    P_total = lm.count_params(arch)
    P_embed = arch.vocab_size * d * (1 if arch.tie_embeddings else 2)
    P_body = P_total - P_embed
    # TP shards body params ~evenly; embeddings shard on vocab
    P_dev = (P_body + P_embed) / TP
    if arch.parallel.fsdp:
        P_dev_resident = P_dev / DP_pod
    else:
        P_dev_resident = P_dev

    # ---------------- FLOPs -------------------------------------------------
    ctx = _avg_ctx(arch, S) if shape.kind != "decode" else S
    n_attn, n_mamba = _attn_layer_counts(arch)
    per_tok = 0.0
    comp = _layer_token_flops(arch, ctx)
    if arch.family == "encdec":
        # decoder layers: self-attn(S) + cross-attn(enc) + mlp; encoder: full
        enc_ctx = arch.encoder_context
        dec = (comp.get("attn_proj", 0) * 2    # self + cross projections
               + 4 * arch.attn.num_heads * arch.attn.head_dim
               * ((S / 2 if shape.kind != "decode" else S) + enc_ctx)
               + comp.get("mlp", 0))
        per_tok = arch.n_layers * dec
        enc_tok = arch.n_encoder_layers * (
            comp.get("attn_proj", 0) + comp.get("mlp", 0)
            + 4 * arch.attn.num_heads * arch.attn.head_dim * enc_ctx)
        enc_tokens_dev = (B * enc_ctx) / (DP if batch_sharded else 1)
    else:
        attn_part = comp.get("attn_proj", 0.0) + comp.get("attn_sdpa", 0.0)
        mlp_part = comp.get("moe", comp.get("mlp", 0.0))
        ssm_part = (comp.get("ssm_proj", 0.0) + comp.get("ssm_conv", 0.0)
                    + comp.get("ssm_ssd", 0.0))
        if arch.family in ("dense", "moe"):
            per_tok = arch.n_layers * (attn_part + mlp_part)
            if arch.family == "moe" and arch.moe_first_dense:
                per_tok += arch.moe_first_dense * (
                    6 * d * arch.d_ff - comp.get("moe", 0.0))
        elif arch.family == "ssm":
            per_tok = arch.n_layers * ssm_part
        elif arch.family == "hybrid":
            per_tok = (n_mamba * ssm_part
                       + n_attn * (attn_part + 6 * d * arch.d_ff))
        enc_tok, enc_tokens_dev = 0.0, 0.0
    head = 2 * d * arch.vocab_size                       # logits
    fwd_dev = (tokens_dev * (per_tok + head) + enc_tokens_dev * enc_tok) / TP
    if shape.kind == "train":
        cm.flops = fwd_dev * _bwd_multiplier(arch.parallel.remat_policy)
    else:
        cm.flops = fwd_dev
        if shape.kind == "decode":
            # decode attends the whole cache per layer (not ctx/2)
            pass
    cm.notes["fwd_flops_dev"] = fwd_dev
    cm.notes["tokens_dev"] = tokens_dev

    # ---------------- HBM bytes --------------------------------------------
    act_stream = tokens_dev * d * act_b
    if shape.kind == "train":
        n_layers_eff = arch.n_layers + arch.n_encoder_layers
        param_traffic = 3.0 * P_dev * pb + 2.0 * P_dev * 4.0  # reads + grads
        opt_traffic = 2.0 * 2.0 * P_dev * ob                  # m,v rw
        act_traffic = n_layers_eff * act_stream * 4.0         # save+read f/b
        if arch.family == "moe":
            act_traffic += arch.n_layers * act_stream * (
                2.0 * (arch.moe.top_k + 1))                   # dispatch bufs
        cm.hbm_bytes = param_traffic + opt_traffic + act_traffic
        cm.notes["hbm_fit_bytes"] = (P_dev_resident * pb + 2 * P_dev * ob
                                     + P_dev * 4.0
                                     + n_layers_eff * act_stream)
    elif shape.kind == "prefill":
        cm.hbm_bytes = (P_dev * pb
                        + (arch.n_layers + arch.n_encoder_layers)
                        * act_stream * 2.0)
        if arch.attn is not None:
            a = arch.attn
            kv_write = (tokens_dev * 2 * a.num_kv_heads * a.head_dim * 2.0
                        * _attn_layer_counts(arch)[0]) / min(TP, 1e9)
            cm.hbm_bytes += kv_write
        cm.notes["hbm_fit_bytes"] = P_dev_resident * pb
    else:  # decode: read all resident params + the whole KV cache / states
        cache_dev = _cache_bytes_dev(arch, shape, TP, DP, batch_sharded,
                                     kv_quant=kv_quant)
        cm.hbm_bytes = P_dev * pb + cache_dev
        cm.notes["cache_bytes_dev"] = cache_dev
        cm.notes["hbm_fit_bytes"] = P_dev_resident * pb + cache_dev

    # ---------------- Collectives ------------------------------------------
    ici = dcn = 0.0
    ring = lambda n: 2.0 * (n - 1) / n if n > 1 else 0.0
    half = lambda n: (n - 1) / n if n > 1 else 0.0
    n_layers_eff = arch.n_layers + arch.n_encoder_layers
    if TP > 1 and shape.kind != "decode":
        # 2 activation all-reduces per layer fwd (+2 bwd for train);
        # the fused parallel block (PaLM-style) halves both
        n_ar = 4.0 if shape.kind == "train" else 2.0
        if arch.parallel.parallel_block:
            n_ar /= 2.0
        ici += n_layers_eff * n_ar * ring(TP) * act_stream
    if shape.kind == "prefill" and arch.parallel.fsdp:
        ici += half(DP_pod) * P_dev * pb          # param AG (fwd only)
        # vocab-sharded CE logsumexp (train) / final logits gather
        ici += 2 * tokens_dev * 4.0 * ring(TP)
    if TP > 1 and shape.kind == "decode":
        ici += n_layers_eff * 2.0 * ring(TP) * tokens_dev * d * act_b
    if shape.kind == "decode" and arch.parallel.fsdp:
        # a training-style FSDP layout all-gathers every weight per decode
        # step (HLO-verified, §Perf C-cell); serving layouts avoid this
        ici += half(DP_pod) * P_dev * pb
    if shape.kind == "train":
        # gradient dtype matches the param dtype (JAX cotangents)
        P_fsdp = P_dev                                # params under FSDP
        if arch.family == "moe" and arch.parallel.moe_2d:
            # 2D-sharded expert weights are never gathered/reduced over data
            m = arch.moe
            n_moe = arch.n_layers - arch.moe_first_dense
            P_experts = n_moe * m.num_experts * 3 * d * m.d_ff_expert / TP
            P_fsdp = max(P_dev - P_experts, 0.0)
        grads_col = P_fsdp * pb
        if arch.parallel.fsdp:
            ici += 2.0 * half(DP_pod) * P_fsdp * pb   # AG params fwd+bwd
            ici += half(DP_pod) * grads_col           # RS grads
        elif DP_pod > 1:
            ici += ring(DP_pod) * grads_col           # AR grads intra-pod
        if PODS > 1:
            gb = P_dev * pb / (DP_pod if arch.parallel.fsdp else 1.0)
            if arch.parallel.grad_compress_pods:
                gb /= 4.0                             # int8 + scales
            dcn += ring(PODS) * gb
        if arch.family == "moe" and arch.parallel.expert_parallel:
            disp = tokens_dev * d * act_b * arch.moe.top_k
            ici += 4.0 * half(TP) * disp              # a2a x,y fwd+bwd
            if arch.parallel.moe_2d:
                # dispatch buffers cross the data axis instead of the
                # expert weights: AG(xe) + AR(ye) fwd, mirrored in bwd
                disp_dev = (shape.global_batch * shape.seq_len
                            * arch.moe.top_k * arch.moe.capacity_factor
                            * d * act_b / TP)
                ici += 2.0 * (half(DP_pod) + ring(DP_pod)) * disp_dev
    if shape.kind == "decode" and not batch_sharded:
        # SP softmax merges: negligible (heads * f32), count embed/logits AR
        ici += 2 * tokens_dev * d * 4.0 * ring(DP)
    cm.ici_bytes = ici
    cm.dcn_bytes = dcn
    return cm


def _cache_bytes_dev(arch: ArchConfig, shape: ShapeConfig, TP: int, DP: int,
                     batch_sharded: bool, kv_quant: bool = False) -> float:
    B, S = shape.global_batch, shape.seq_len
    shard = DP if batch_sharded else DP  # batch-sharded or seq-sharded
    total = 0.0
    a = arch.attn
    # per-token-per-layer KV bytes: bf16 full cache vs m_bytes RQ codes
    if a is not None:
        if kv_quant:
            per_tok = 2.0 * a.num_kv_heads * arch.kv_quant.m_bytes
        else:
            per_tok = 2.0 * a.num_kv_heads * a.head_dim * 2.0
    if arch.family in ("dense", "moe", "encdec"):
        if arch.family == "encdec":
            total += (arch.n_layers * B * arch.encoder_context * per_tok)
        total += arch.n_layers * B * S * per_tok
    elif arch.family == "hybrid":
        n_attn = arch.n_layers // arch.shared_attn_every
        total += n_attn * B * S * per_tok
        total += _ssm_state_bytes(arch, B)
    elif arch.family == "ssm":
        total += _ssm_state_bytes(arch, B)
    return total / shard


def _ssm_state_bytes(arch: ArchConfig, B: int) -> float:
    s = arch.ssm
    di = s.expand * arch.d_model
    H = di // s.head_dim
    conv_ch = di + 2 * s.ngroups * s.state_dim
    return arch.n_layers * B * (H * s.head_dim * s.state_dim * 4.0
                                + (s.conv_width - 1) * conv_ch * 4.0)
