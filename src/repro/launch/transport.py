"""Socket transport for the search front door: length-prefixed frames.

The network edge the serving stack sits behind (docs/SERVING.md). One
TCP listener, one accept thread, one reader thread per connection —
each decoded request frame is handed to the front door's admission
callback on the reader thread, and responses are written back later
(from the dispatch thread) through a per-connection write lock, so a
slow client never blocks another connection's reads or the batcher.

Wire format (all integers big-endian):

    frame   := u32 payload_len | payload        (payload_len <= MAX_FRAME)
    payload := u32 header_len | header_json | body

``header_json`` is a UTF-8 JSON object; ``body`` is raw little-endian
binary (query vectors f32, result ids i32 + dists f32 + coverage f32)
whose layout the header describes. Request/response header shapes and
the status-code taxonomy live in docs/SERVING.md; the `STATUS_*`
constants below are the single source of truth for the codes, and
`RETRYABLE_STATUSES` is the client-side retry contract: transient
overload (`RESOURCE_EXHAUSTED`) and drain (`UNAVAILABLE`) may be
retried, everything else — malformed requests, unknown tenants,
integrity failures — must not be (mirroring the storage-layer rule
that retries never clear persistent corruption).

Robustness contract of the reader loop, exercised by the network fault
kinds in `repro.index.faults` (connection drops, slow/partial writes,
malformed frames, clients vanishing mid-response):

  - partial reads are normal: `_recv_exact` loops until the frame is
    complete or the peer is gone;
  - a malformed frame (oversized length, truncated payload, bad JSON)
    gets one best-effort `INVALID_ARGUMENT` reply and the connection is
    CLOSED — framing state after garbage is unrecoverable by design;
  - a connection dying at any point (mid-frame, mid-response) is
    counted and cleaned up, never raised into the accept loop;
  - writes go through `Connection.send`, which serializes frames per
    connection and converts peer-vanished errors into a `False` return
    (+ `transport_send_failures_total`) so the dispatcher treats an
    unreachable client as delivered-and-gone, not as a server fault;
  - sends are bounded by a per-socket send timeout (`SO_SNDTIMEO`, so
    the reader's blocking `recv` is untouched): a client that keeps the
    connection open but stops READING fills its TCP buffer until
    `sendall` times out, which is treated exactly like a vanished
    client (counted, connection closed) — a slow reader stalls one
    `send` for at most `send_timeout_s`, never the dispatcher forever.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Callable, Optional

from repro import obs

MAX_FRAME = 1 << 26            # 64 MB: > any sane micro-batch, < a DoS
_U32 = struct.Struct(">I")

#: bound on one blocked response write: past this, the peer is treated
#: as vanished. Generous — a healthy client drains its receive buffer
#: in milliseconds; only a stopped reader ever gets here.
SEND_TIMEOUT_S = 5.0

# status taxonomy (docs/SERVING.md) ------------------------------------------
STATUS_OK = "OK"
STATUS_INVALID = "INVALID_ARGUMENT"      # malformed frame / bad shapes
STATUS_NOT_FOUND = "NOT_FOUND"           # unknown tenant
STATUS_SHED = "RESOURCE_EXHAUSTED"       # load-shed: queue past watermark
STATUS_UNAVAILABLE = "UNAVAILABLE"       # draining / not accepting
STATUS_INTEGRITY = "INTEGRITY_ERROR"     # shard integrity: never retry
STATUS_INTERNAL = "INTERNAL"             # unexpected server-side failure

#: the client retry policy: ONLY transient conditions. Integrity and
#: argument errors are persistent — retrying them re-runs a failure.
RETRYABLE_STATUSES = frozenset({STATUS_SHED, STATUS_UNAVAILABLE})

_C_CONNS = obs.counter("transport_connections_total",
                       "TCP connections accepted")
_C_FRAMES = obs.counter("transport_frames_total",
                        "request frames decoded (label dir=in|out)")
_C_FRAME_ERRORS = obs.counter(
    "transport_frame_errors_total",
    "malformed frames (bad length/JSON) answered INVALID_ARGUMENT")
_C_CONN_ABORTS = obs.counter(
    "transport_conn_aborts_total",
    "connections dropped mid-frame or mid-stream by the peer")
_C_SEND_FAILS = obs.counter(
    "transport_send_failures_total",
    "response frames that could not be written (client vanished)")
_G_OPEN = obs.gauge("transport_open_connections", "currently open conns")


class FrameError(ValueError):
    """Malformed wire data: bad lengths, truncated payload, bad JSON."""


class ConnectionAbort(FrameError):
    """The peer vanished mid-frame (connection drop): there is nobody
    left to answer, so this is cleanup, not a protocol error."""


def encode_frame(header: dict, body: bytes = b"") -> bytes:
    hdr = json.dumps(header, separators=(",", ":")).encode()
    payload_len = 4 + len(hdr) + len(body)
    if payload_len > MAX_FRAME:
        raise FrameError(f"frame of {payload_len} bytes exceeds MAX_FRAME")
    return b"".join((_U32.pack(payload_len), _U32.pack(len(hdr)), hdr, body))


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes. None = clean EOF before the first byte;
    `FrameError` = EOF mid-read (a peer that vanished inside a frame)."""
    chunks, got = [], 0
    while got < n:
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except (ConnectionError, OSError):
            chunk = b""
        if not chunk:
            if got == 0:
                return None
            raise ConnectionAbort(f"EOF {got}/{n} bytes into a frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Optional[tuple]:
    """-> (header dict, body bytes), or None on clean EOF between
    frames. Raises `FrameError` on malformed data."""
    raw = _recv_exact(sock, 4)
    if raw is None:
        return None
    (payload_len,) = _U32.unpack(raw)
    if not 4 <= payload_len <= MAX_FRAME:
        raise FrameError(f"payload length {payload_len} outside "
                         f"[4, {MAX_FRAME}]")
    payload = _recv_exact(sock, payload_len)
    if payload is None:
        raise ConnectionAbort("EOF before payload")
    (hdr_len,) = _U32.unpack(payload[:4])
    if hdr_len > payload_len - 4:
        raise FrameError(f"header length {hdr_len} exceeds payload")
    try:
        header = json.loads(payload[4:4 + hdr_len].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameError(f"bad header JSON: {e}") from e
    if not isinstance(header, dict):
        raise FrameError(f"header is {type(header).__name__}, not object")
    return header, payload[4 + hdr_len:]


def send_frame(sock: socket.socket, header: dict, body: bytes = b"") -> None:
    sock.sendall(encode_frame(header, body))


class Connection:
    """One accepted client connection: framed reads on the owner reader
    thread, thread-safe framed writes from anywhere (the dispatcher).

    ``send_timeout_s`` arms `SO_SNDTIMEO` on the socket (send-side
    only — the reader's blocking `recv` keeps waiting indefinitely
    between frames): a peer that stops reading makes `sendall` fail
    after at most that long instead of blocking the caller — critical
    because OK responses are written from the single dispatcher thread,
    which must never be held hostage by one stalled client.
    """

    def __init__(self, sock: socket.socket, peer: str, *,
                 send_timeout_s: float = SEND_TIMEOUT_S):
        self._sock = sock
        self.peer = peer
        self._wlock = threading.Lock()
        self._closed = False
        if send_timeout_s is not None and send_timeout_s > 0:
            sec = int(send_timeout_s)
            usec = int((send_timeout_s - sec) * 1e6)
            try:
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDTIMEO,
                                struct.pack("ll", sec, usec))
            except (OSError, ValueError):
                # platform without SO_SNDTIMEO: degrade to unbounded
                # sends rather than refuse the connection
                pass

    @property
    def closed(self) -> bool:
        return self._closed

    def send(self, header: dict, body: bytes = b"") -> bool:
        """Write one response frame. False = the client is gone (counted
        in `transport_send_failures_total`); the caller's work is done
        either way — a vanished client is not a server failure. A send
        that times out (`SO_SNDTIMEO`: the peer stopped reading and its
        TCP buffer is full) raises `socket.timeout`, an `OSError` — the
        same vanished-client path: framing state mid-frame is
        unrecoverable anyway, so the connection closes."""
        frame = encode_frame(header, body)
        with self._wlock:
            if self._closed:
                _C_SEND_FAILS.inc()
                return False
            try:
                self._sock.sendall(frame)
            except (ConnectionError, OSError):
                _C_SEND_FAILS.inc()
                self._close_locked()
                return False
        _C_FRAMES.labels(dir="out").inc()
        return True

    def _close_locked(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._sock.close()
            except OSError:
                pass
            _G_OPEN.dec()

    def close(self) -> None:
        with self._wlock:
            self._close_locked()


class TransportServer:
    """Accept loop + per-connection reader threads over framed TCP.

    ``handler(conn, header, body)`` runs on the connection's reader
    thread for every decoded frame; it must not block for long (the
    front door's handler only validates + enqueues — the admission
    contract). `stop_accepting()` closes the listener while leaving
    live connections readable/writable (the drain half-state);
    `close()` tears everything down.
    """

    def __init__(self, handler: Callable[[Connection, dict, bytes], None],
                 *, host: str = "127.0.0.1", port: int = 0,
                 backlog: int = 128,
                 send_timeout_s: float = SEND_TIMEOUT_S):
        self._handler = handler
        self._send_timeout_s = send_timeout_s
        self._listener = socket.create_server((host, port), backlog=backlog)
        self.host = host
        self.port = self._listener.getsockname()[1]
        self._lock = threading.Lock()
        self._conns: set = set()
        self._accepting = True
        self._closed = False
        self._threads = []
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="transport-accept", daemon=True)
        self._accept_thread.start()

    # -- lifecycle -----------------------------------------------------------

    @property
    def accepting(self) -> bool:
        return self._accepting

    def stop_accepting(self) -> None:
        """Close the listener (new connects are refused by the OS); live
        connections keep flowing — the first half of a graceful drain."""
        with self._lock:
            if not self._accepting:
                return
            self._accepting = False
        # shutdown() BEFORE close(): on Linux, close() does NOT wake a
        # thread blocked in accept() — the syscall holds the socket
        # alive, so the "closed" listener keeps accepting and the join
        # below eats its full timeout. shutdown() interrupts the
        # blocked accept (EINVAL) so the accept loop exits promptly.
        try:
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass                      # not listening / already gone
        try:
            self._listener.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=5.0)

    def close(self) -> None:
        """Full teardown: stop accepting, close every connection, join
        the reader threads."""
        self.stop_accepting()
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns)
            threads = list(self._threads)
        for c in conns:
            c.close()
        for t in threads:
            t.join(timeout=5.0)

    # -- loops ---------------------------------------------------------------

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except OSError:           # listener closed: drain or shutdown
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = Connection(sock, f"{addr[0]}:{addr[1]}",
                              send_timeout_s=self._send_timeout_s)
            _C_CONNS.inc()
            _G_OPEN.inc()
            t = threading.Thread(target=self._reader_loop,
                                 args=(conn, sock),
                                 name=f"transport-read-{addr[1]}",
                                 daemon=True)
            with self._lock:
                self._conns.add(conn)
                self._threads.append(t)
            t.start()

    def _reader_loop(self, conn: Connection, sock: socket.socket) -> None:
        try:
            while not conn.closed:
                try:
                    frame = recv_frame(sock)
                except ConnectionAbort:
                    # the peer dropped mid-frame: nobody to answer
                    _C_CONN_ABORTS.inc()
                    break
                except FrameError:
                    # garbage on the wire: framing state is gone, so one
                    # best-effort typed error, then hang up
                    _C_FRAME_ERRORS.inc()
                    conn.send({"status": STATUS_INVALID,
                               "error": "malformed frame; closing"})
                    break
                if frame is None:                    # clean EOF
                    return
                _C_FRAMES.labels(dir="in").inc()
                header, body = frame
                try:
                    self._handler(conn, header, body)
                except Exception as e:               # handler bug: reply,
                    conn.send({"id": header.get("id"),  # don't kill reads
                               "status": STATUS_INTERNAL,
                               "error": f"{type(e).__name__}: {e}"})
        except (ConnectionError, OSError):
            _C_CONN_ABORTS.inc()
        finally:
            conn.close()
            with self._lock:
                self._conns.discard(conn)
                # prune ourselves so a long-running server doesn't keep
                # one dead Thread (and its conn closure) per connection
                # ever accepted
                try:
                    self._threads.remove(threading.current_thread())
                except ValueError:
                    pass                  # close() already snapshotted us
