"""Production mesh builders. Functions, not module constants, so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (pod=2, 16, 16) = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per-direction)
    "dcn_bw": 6.25e9,              # bytes/s per host across pods (50 Gb/s)
    "hbm_bytes": 16e9,             # v5e HBM capacity
}
