import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first backend init. (Tests may shrink the placeholder count.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod AOT dry-run: .lower().compile() every (arch x shape x mesh)
cell on placeholder devices, then record memory / cost / collective stats
for the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh prod --pods both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, SHAPE_BY_NAME, get_arch, list_archs, \
    shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import analytic
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import HW, make_production_mesh, make_test_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, prefill_kv_specs)
from repro.models import lm
from repro.models.common import ShardCtx, abstract_params, is_spec
from repro.parallel import compat
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup
from repro.parallel import sharding as shd


def _abstract(tree):
    return abstract_params(tree)


def _opt_cfg(arch: ArchConfig):
    return adamw.AdamWConfig(
        lr=cosine_with_warmup(3e-4, 10_000, 500), weight_decay=0.1,
        grad_clip=1.0,
        state_dtype=jnp.dtype(arch.parallel.opt_state_dtype))


def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh, *,
               kv_quant: bool = False):
    """Returns (jitted_fn, abstract_args, static_info)."""
    rules, ctx = shd.make_rules(arch, mesh, shape)
    pspecs = shd.sharding_tree(lm.param_specs(arch), rules, mesh)
    aparams = _abstract(lm.param_specs(arch))
    rep = shd.replicated(mesh)
    info = {
        "param_bytes_per_device":
            shd.bytes_per_device(lm.param_specs(arch), rules, mesh),
    }

    if shape.kind == "train":
        cfg = _opt_cfg(arch)
        astate = adamw.abstract_state(aparams, cfg)
        ostate_sh = adamw.AdamWState(
            step=rep,
            m=jax.tree.map(lambda _: None, astate.m),  # placeholder
            v=jax.tree.map(lambda _: None, astate.v))
        # m/v mirror params -> same shardings
        mv_specs = jax.tree.map(
            lambda s: dataclasses.replace(
                s, dtype=jnp.dtype(arch.parallel.opt_state_dtype)),
            lm.param_specs(arch), is_leaf=is_spec)
        mv_sh = shd.sharding_tree(mv_specs, rules, mesh)
        ostate_sh = adamw.AdamWState(step=rep, m=mv_sh, v=mv_sh)
        info["opt_bytes_per_device"] = 2 * shd.bytes_per_device(
            mv_specs, rules, mesh)
        bspecs = lm.batch_specs(arch, shape.seq_len, shape.global_batch,
                                "train")
        bsh = shd.sharding_tree(bspecs, rules, mesh)
        fn = make_train_step(arch, ctx, cfg, mesh=mesh)
        metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        jitted = jax.jit(fn, in_shardings=(pspecs, ostate_sh, bsh),
                         out_shardings=(pspecs, ostate_sh, metrics_sh))
        args = (aparams, astate, _abstract(bspecs))
        return jitted, args, info

    if shape.kind == "prefill":
        bspecs = lm.batch_specs(arch, shape.seq_len, shape.global_batch,
                                "prefill")
        bsh = shd.sharding_tree(bspecs, rules, mesh)
        fn = make_prefill_step(arch, ctx)
        logits_sh = shd.sharding_tree(_logits_spec(arch, shape.global_batch),
                                      rules, mesh)
        kvs = prefill_kv_specs(arch, shape.global_batch, shape.seq_len)
        if kvs is not None:
            kv_sh = shd.sharding_tree(kvs, rules, mesh)
            out_sh = (logits_sh, kv_sh)
        else:
            out_sh = logits_sh
        jitted = jax.jit(fn, in_shardings=(pspecs, bsh), out_shardings=out_sh)
        args = (aparams, _abstract(bspecs))
        return jitted, args, info

    # decode
    bspecs = lm.batch_specs(arch, shape.seq_len, shape.global_batch,
                            "decode", kv_quant=kv_quant)
    cache_specs = bspecs.pop("cache")
    tok_sh = shd.sharding_tree(bspecs, rules, mesh)["tokens"]
    cache_sh = shd.sharding_tree(cache_specs, rules, mesh)
    info["cache_bytes_per_device"] = shd.bytes_per_device(
        cache_specs, rules, mesh)
    fn = make_decode_step(arch, ctx, kv_quant=kv_quant)
    logits_sh = shd.sharding_tree(_logits_spec(arch, shape.global_batch),
                                  rules, mesh)
    jitted = jax.jit(fn, in_shardings=(pspecs, cache_sh, tok_sh, rep),
                     out_shardings=(logits_sh, cache_sh))
    args = (aparams, _abstract(cache_specs),
            jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args, info


def _logits_spec(arch: ArchConfig, batch: int):
    from repro.models.common import ParamSpec
    return ParamSpec((batch, 1, arch.vocab_size), ("batch", None, "vocab"),
                     jnp.float32)


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = new tokens only."""
    n = lm.active_params(arch)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch       # decode: one token per seq


def roofline_terms(rec: dict, mesh_devices: int) -> dict:
    """Roofline terms from the analytic model (TPU semantics); the raw
    HLO-parsed numbers stay in the record as cross-checks."""
    am = rec["analytic"]
    t_compute = am["flops"] / HW["peak_flops_bf16"]
    t_memory = am["hbm_bytes"] / HW["hbm_bw"]
    t_coll = am["ici_bytes"] / HW["ici_bw"] + am["dcn_bytes"] / HW["dcn_bw"]
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    t_bound = max(t_compute, t_memory, t_coll)
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "bottleneck": dom,
            "roofline_fraction": t_compute / t_bound if t_bound else 0.0}


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             mesh_kind: str = "prod", kv_quant: bool = False,
             out_dir: Path = None, force: bool = False,
             arch_override=None) -> dict:
    arch = arch_override or get_arch(arch_name)
    shape = SHAPE_BY_NAME[shape_name]
    tag = f"{arch.name}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if kv_quant:
        tag += "__kvq"
    if mesh_kind == "test":
        tag += "__testmesh"
    out_path = (out_dir / f"{tag}.json") if out_dir else None
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
    if out_path and out_path.exists() and not force:
        return json.loads(out_path.read_text())

    ok, reason = shape_applicable(arch, shape)
    rec = {"arch": arch.name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kv_quant": kv_quant, "runnable": ok}
    if not ok:
        rec["skip_reason"] = reason
        if out_path:
            out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = (make_test_mesh(multi_pod=multi_pod) if mesh_kind == "test"
            else make_production_mesh(multi_pod=multi_pod))
    ndev = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    try:
        with compat.use_mesh(mesh):
            jitted, args, info = build_cell(arch, shape, mesh,
                                            kv_quant=kv_quant)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if out_path:
            out_path.write_text(json.dumps(rec, indent=1))
        return rec

    hlo = compiled.as_text()
    coll = ha.collective_stats(hlo)
    rec.update(info)
    rec["devices"] = ndev
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["cost"] = ha.cost_analysis_dict(compiled)
    rec["memory"] = ha.memory_analysis_dict(compiled)
    rec["collectives"] = {k: {kk: (vv if isinstance(vv, int) else float(vv))
                              for kk, vv in v.items()}
                          for k, v in coll.items()}
    rec["collective_wire_bytes"] = ha.total_collective_bytes(coll)
    rec["collective_operand_bytes"] = ha.total_operand_bytes(coll)
    rec["model_flops_global"] = model_flops(arch, shape)
    rec["params_total"] = lm.count_params(arch)
    rec["params_active"] = lm.active_params(arch)
    am = analytic.model_cell(arch, shape, dict(mesh.shape),
                             kv_quant=kv_quant)
    rec["analytic"] = {"flops": am.flops, "hbm_bytes": am.hbm_bytes,
                       "ici_bytes": am.ici_bytes, "dcn_bytes": am.dcn_bytes,
                       **{f"note_{k}": v for k, v in am.notes.items()}}
    rec["model_hlo_ratio"] = (
        rec["model_flops_global"] / ndev / am.flops if am.flops else 0.0)
    rec.update(roofline_terms(rec, ndev))
    if out_path:
        out_dir.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
    return rec


QINCO_CELLS = [("qinco2-l", "train"), ("qinco2-l", "encode"),
               ("qinco2-s", "search")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--pods", default="both", choices=["1", "2", "both"])
    ap.add_argument("--mesh", default="prod", choices=["prod", "test"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--qinco", action="store_true",
                    help="also lower the paper's own workloads (train/"
                         "encode/search) at the mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.qinco:
        from repro.launch.qinco_cells import run_qinco_cell
        pods_l = {"1": [False], "2": [True], "both": [False, True]}[args.pods]
        for preset, kind in QINCO_CELLS:
            for mp in pods_l:
                mesh = (make_test_mesh(multi_pod=mp) if args.mesh == "test"
                        else make_production_mesh(multi_pod=mp))
                t0 = time.perf_counter()
                rec = run_qinco_cell(preset, kind, multi_pod=mp, mesh=mesh,
                                     out_dir=Path(args.out),
                                     force=args.force)
                status = (f"ok dom={rec.get('bottleneck')}"
                          if not rec.get("error")
                          else "ERROR " + rec["error"][:100])
                print(f"[{time.perf_counter()-t0:7.1f}s] {preset:22s} {kind:12s} "
                      f"pods={2 if mp else 1} {status}", flush=True)
        return

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    pods = {"1": [False], "2": [True], "both": [False, True]}[args.pods]
    out_dir = Path(args.out)

    n_ok = n_skip = n_err = 0
    for arch_name in archs:
        for shape_name in shapes:
            for multi_pod in pods:
                t0 = time.perf_counter()
                rec = run_cell(arch_name, shape_name, multi_pod=multi_pod,
                               mesh_kind=args.mesh, kv_quant=args.kv_quant,
                               out_dir=out_dir, force=args.force)
                if rec.get("error"):
                    n_err += 1
                    status = "ERROR " + rec["error"][:120]
                elif not rec.get("runnable", True):
                    n_skip += 1
                    status = "skip"
                else:
                    n_ok += 1
                    status = (f"ok t_comp={rec['t_compute_s']:.4f}s "
                              f"t_mem={rec['t_memory_s']:.4f}s "
                              f"t_coll={rec['t_collective_s']:.4f}s "
                              f"dom={rec['bottleneck']}")
                print(f"[{time.perf_counter()-t0:7.1f}s] {arch_name:22s} "
                      f"{shape_name:12s} pods={2 if multi_pod else 1} "
                      f"{status}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
