"""QINCo2's own workloads lowered at the production mesh (the paper's
centerpiece at scale): DP training, database beam-encode, and distributed
ADC search with the database sharded over `model`.

Called from dryrun.py (same placeholder-device env)."""
from __future__ import annotations

import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.qinco2 import PRESETS, QincoConfig
from repro.core import encode as enc
from repro.core import qinco
from repro.kernels import ops
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import HW
from repro.models.common import abstract_params
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup
from repro.parallel import compat
from repro.parallel.collectives import distributed_topk


def _qinco_flops(cfg: QincoConfig, n_vec: int, kind: str) -> float:
    """Per Table S2: enc ~ A*B*M*de*(d+L*dh) + B*K*d; dec ~ M*de*(d+L*dh)."""
    A, B = cfg.A_train, cfg.B_train
    f_net = 2.0 * cfg.de * (cfg.d + cfg.L * cfg.dh)
    enc_f = cfg.M * (A * B * f_net + B * cfg.K * cfg.d * 2.0)
    dec_f = cfg.M * f_net
    if kind == "encode":
        return n_vec * enc_f
    if kind == "train":            # encode + fwd/bwd on selected codes
        return n_vec * (enc_f + 3.0 * dec_f)
    return n_vec * dec_f


def run_qinco_cell(preset: str, kind: str, *, multi_pod: bool, mesh,
                   out_dir: Path = None, force: bool = False) -> dict:
    tag = f"{preset}__{kind}__{'pod2' if multi_pod else 'pod1'}"
    out_path = (out_dir / f"{tag}.json") if out_dir else None
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
    if out_path and out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = PRESETS[preset]()
    ndev = int(np.prod(list(mesh.shape.values())))
    rep = NamedSharding(mesh, P())
    all_axes = tuple(mesh.axis_names)
    vec_sh = NamedSharding(mesh, P(all_axes))
    rec = {"arch": preset, "shape": kind,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
           "runnable": True}
    t0 = time.perf_counter()
    # Everything below is FULL-MANUAL shard_map: beam-search encoding is
    # per-vector (embarrassingly parallel over the batch), so GSPMD's
    # propagation through the beam-reindex gathers would otherwise insert
    # giant all-gathers. Manual mode = the paper's actual DDP layout.
    try:
        if kind == "train":
            n_vec = 512 * ndev                 # paper batch scaled to mesh
            opt_cfg = adamw.AdamWConfig(
                lr=cosine_with_warmup(cfg.lr, 10_000, 100, cfg.min_lr_ratio),
                weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip)
            aparams = abstract_params(qinco.param_specs(cfg))
            astate = adamw.abstract_state(aparams, opt_cfg)
            psh = jax.tree.map(lambda _: rep, aparams)
            osh = adamw.AdamWState(step=rep,
                                   m=jax.tree.map(lambda _: rep, astate.m),
                                   v=jax.tree.map(lambda _: rep, astate.v))

            def step(params, opt_state, x):
                def local(params, opt_state, x_loc):
                    codes, _, _ = enc.encode(params, x_loc, cfg,
                                             cfg.A_train, cfg.B_train)
                    codes = jax.lax.stop_gradient(codes)
                    (loss, _), grads = jax.value_and_grad(
                        lambda p: enc.train_forward(p, x_loc, codes, cfg),
                        has_aux=True)(params)
                    # DDP: mean-reduce grads/loss over every mesh axis
                    grads = jax.tree.map(
                        lambda g: jax.lax.pmean(g, all_axes), grads)
                    loss = jax.lax.pmean(loss, all_axes)
                    np_, ns_, _ = adamw.update(grads, opt_state, params,
                                               opt_cfg)
                    return np_, ns_, loss

                pspec = jax.tree.map(lambda _: P(), params)
                ospec = jax.tree.map(lambda _: P(), opt_state)
                return compat.shard_map(
                    local, mesh=mesh,
                    in_specs=(pspec, ospec, P(all_axes)),
                    out_specs=(pspec, ospec, P()),
                    check_vma=False)(params, opt_state, x)

            jitted = jax.jit(step, in_shardings=(psh, osh, vec_sh),
                             out_shardings=(psh, osh, rep))
            args = (aparams, astate,
                    jax.ShapeDtypeStruct((n_vec, cfg.d), jnp.float32))
        elif kind == "encode":
            n_vec = 4096 * ndev                # database encode throughput
            aparams = abstract_params(qinco.param_specs(cfg))
            psh = jax.tree.map(lambda _: rep, aparams)

            def encode_db(params, x):
                def local(params, x_loc):
                    codes, _, mse = enc.encode(params, x_loc, cfg,
                                               cfg.A_eval, cfg.B_eval)
                    return codes, jax.lax.pmean(mse, all_axes)

                pspec = jax.tree.map(lambda _: P(), params)
                return compat.shard_map(
                    local, mesh=mesh, in_specs=(pspec, P(all_axes)),
                    out_specs=(P(all_axes), P()),
                    check_vma=False)(params, x)

            jitted = jax.jit(encode_db, in_shardings=(psh, vec_sh),
                             out_shardings=(vec_sh, rep))
            args = (aparams,
                    jax.ShapeDtypeStruct((n_vec, cfg.d), jnp.float32))
        elif kind == "search":
            # database codes sharded over `model`: per-shard ADC + local
            # top-k, all-gather of the tiny shortlists, global merge,
            # neural re-rank of the merged candidates
            n_db = 1_000_000 * mesh.shape["model"]
            n_q, k = 4096, 64
            n_loc = n_db // mesh.shape["model"]
            db_sh = NamedSharding(mesh, P("model"))
            aparams = abstract_params(qinco.param_specs(cfg))
            psh = jax.tree.map(lambda _: rep, aparams)

            def search_step(params, lut, db_codes, norms):
                def local(params, lut, codes, norms):
                    # identical per-shard kernel path as core/search:
                    # shared-codes ops.adc_scores + shortlist merge
                    # (xla_onehot: TPU-shaped one-hot-matmul HLO for the
                    # roofline stats, even when lowered on placeholders)
                    scores = ops.adc_scores(codes, lut, norms=norms,
                                            backend="xla_onehot")
                    base = jax.lax.axis_index("model") * n_loc
                    merged, s2 = distributed_topk(scores, base, k, "model")
                    # neural re-rank: decode this shard's share of hits
                    local_hits = jnp.where(
                        (merged >= base) & (merged < base + n_loc),
                        merged - base, 0)
                    recon = qinco.decode(params,
                                         codes[local_hits.reshape(-1)], cfg)
                    return merged, s2, jax.lax.psum(
                        jnp.sum(recon), "model")

                pspec = jax.tree.map(lambda _: P(), params)
                return compat.shard_map(
                    local, mesh=mesh,
                    in_specs=(pspec, P(), P("model"), P("model")),
                    out_specs=(P(), P(), P()),
                    check_vma=False)(params, lut, db_codes, norms)

            jitted = jax.jit(
                search_step,
                in_shardings=(psh, rep, db_sh, db_sh),
                out_shardings=(rep, rep, rep))
            args = (aparams,
                    jax.ShapeDtypeStruct((n_q, cfg.M, cfg.K), jnp.float32),
                    jax.ShapeDtypeStruct((n_db, cfg.M), jnp.int32),
                    jax.ShapeDtypeStruct((n_db,), jnp.float32))
            n_vec = n_q
        else:
            raise ValueError(kind)

        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
        if out_path:
            out_path.write_text(json.dumps(rec, indent=1))
        return rec

    hlo = compiled.as_text()
    coll = ha.collective_stats(hlo)
    rec["compile_s"] = round(time.perf_counter() - t0, 2)
    rec["cost"] = ha.cost_analysis_dict(compiled)
    rec["memory"] = ha.memory_analysis_dict(compiled)
    rec["collectives"] = {kk: dict(v) for kk, v in coll.items()}
    rec["collective_wire_bytes"] = ha.total_collective_bytes(coll)
    flops_dev = _qinco_flops(PRESETS[preset](), n_vec, kind) / ndev
    if kind == "search":
        # ADC dominates: Q x N_local x M one-hot matmul on the MXU
        flops_dev = 2.0 * 4096 * 1_000_000 * PRESETS[preset]().M \
            * PRESETS[preset]().K
    hbm = rec["memory"].get("argument_size_in_bytes", 0) / ndev
    if kind == "search":
        hbm = 1_000_000 * PRESETS[preset]().M  # codes stream, int8-packable
    rec["analytic"] = {"flops": flops_dev, "hbm_bytes": float(hbm),
                       "ici_bytes": rec["collective_wire_bytes"],
                       "dcn_bytes": 0.0}
    t_c = flops_dev / HW["peak_flops_bf16"]
    t_m = hbm / HW["hbm_bw"]
    t_x = rec["collective_wire_bytes"] / HW["ici_bw"]
    rec.update(t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
               bottleneck=max((("compute", t_c), ("memory", t_m),
                               ("collective", t_x)),
                              key=lambda kv: kv[1])[0],
               roofline_fraction=t_c / max(t_c, t_m, t_x, 1e-30))
    if out_path:
        out_path.write_text(json.dumps(rec, indent=1))
    return rec
