"""End-to-end LM trainer: mesh + sharded step + checkpoint/restart +
preemption handling + straggler monitoring.

CPU-scale runs use reduced configs (`--reduced`); the identical step is the
one AOT-compiled by the dry-run at the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (CheckpointManager, PreemptionGuard,
                                      StragglerMonitor)
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import ShardedLoader
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.models.common import ShardCtx, abstract_params, init_params
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup
from repro.parallel import sharding as shd


def train_loop(arch, *, steps: int, batch: int, seq: int, ckpt_dir=None,
               ckpt_every: int = 50, mesh=None, seed: int = 0,
               log_every: int = 10, lr: float = 3e-4, verbose=True,
               total_steps=None):
    """`steps` = stop point this invocation; `total_steps` = schedule
    horizon (defaults to steps; pass the full-run length when a job will
    be preempted/resumed so the LR schedule stays consistent)."""
    total_steps = total_steps or steps
    if mesh is not None:
        shape = ShapeConfig("custom", seq, batch, "train")
        rules, ctx = shd.make_rules(arch, mesh, shape)
        pspecs = shd.sharding_tree(lm.param_specs(arch), rules, mesh)
    else:
        ctx = ShardCtx(active=False)
        pspecs = None

    opt_cfg = adamw.AdamWConfig(
        lr=cosine_with_warmup(lr, total_steps, min(50, total_steps // 10)),
        weight_decay=0.1, grad_clip=1.0,
        state_dtype=jnp.dtype(arch.parallel.opt_state_dtype))
    step_fn = jax.jit(make_train_step(arch, ctx, opt_cfg))

    params = init_params(lm.param_specs(arch), jax.random.key(seed))
    opt_state = adamw.init(params, opt_cfg)
    if pspecs is not None:
        params = jax.tree.map(jax.device_put, params, pspecs)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr is not None:
        got = mgr.restore_latest((params, opt_state))
        if got is not None:
            start_step, (params, opt_state), extra = got
            start_step += 1
            if verbose:
                print(f"[train] resumed from step {start_step - 1}")

    from repro.data.synthetic import batch_at
    loader = ShardedLoader(
        lambda s: batch_at(arch.vocab_size, seq, batch, s, seed=seed),
        start_step=start_step)
    guard = PreemptionGuard()
    monitor = StragglerMonitor()
    losses = []
    t_start = time.perf_counter()
    for step, data in loader:
        if step >= steps:
            break
        if arch.frontend_stub and arch.family == "encdec":
            data = dict(data, frames=np.zeros(
                (batch, arch.encoder_context, arch.d_model), np.float32))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, data)
        loss = float(metrics["loss"])
        losses.append(loss)
        straggle = monitor.record(step, time.perf_counter() - t0)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"[train] step {step} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e}"
                  + (" STRAGGLER" if straggle else ""), flush=True)
        if mgr is not None and (
                step % ckpt_every == 0 and step > start_step
                or guard.should_checkpoint()):
            mgr.save(step, (params, opt_state),
                     extra={"loss": loss, "arch": arch.name})
            if guard.should_checkpoint():
                print(f"[train] preemption checkpoint at step {step}; "
                      "exiting")
                break
    loader.close()
    guard.restore_handlers()
    if verbose:
        print(f"[train] done in {time.perf_counter()-t_start:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    train_loop(arch, steps=args.steps, batch=args.batch, seq=args.seq,
               ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
               lr=args.lr)


if __name__ == "__main__":
    main()
