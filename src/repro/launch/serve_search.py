"""Batched query serving over a store-loaded index.

The query-side counterpart of `index/builder.py`: load a persistent
packed-code store (`repro.index.IndexStore`), warm up ONE compiled
`search()` executable at a fixed micro-batch shape, then drain a query
stream through it with micro-batch accumulation — arrivals are grouped
until the batch fills or a wait deadline passes, exactly the trade the
production serving loop makes between latency and MXU utilization.

Latency accounting runs on a virtual clock fed by measured wall-clock
service times, so the reported p50/p99 include queueing delay and are
reproducible under CI load.

    PYTHONPATH=src python -m repro.launch.serve_search --store /tmp/idx \
        --queries 256 --micro-batch 32 --rate 2000

With ``--out-of-core`` the store is served through a `ShardedIndexView`
(`core/search.search_sharded`): shards stay mmap'd on disk, device
residency is bounded by the shard LRU (``--max-resident-shards``), and
results are bit-identical to resident serving — database size becomes
independent of device memory.

With ``--port`` the in-process server goes behind the socket front door
(framed TCP, continuous batching, shedding, graceful drain — see
docs/SERVING.md); adding ``--refresh-ms N`` makes the serving loop poll
the store every N ms and adopt published mutations (delta shards,
tombstones, compacted generations) without a restart. A refresh swaps
an immutable snapshot: already-admitted batches answer from the state
they were dispatched against, never a mixed generation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import threading
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import search as search_mod

# Serving telemetry (docs/OBSERVABILITY.md): per-query latency/queueing
# histograms plus the per-batch stall-vs-compute split. `ServeStats`
# p50/p99 are derived from a windowed quantile over
# `serve_latency_seconds` (collect-before / quantile-since-after), so
# the server keeps NO per-query latency array — the stats cost is
# O(buckets) however long the stream runs.
_H_LATENCY = obs.histogram(
    "serve_latency_seconds", "end-to-end per-query latency incl. queueing")
_H_QUEUE = obs.histogram(
    "serve_queue_seconds", "arrival -> batch-dispatch queueing delay")
_C_QUERIES = obs.counter("serve_queries_total", "queries served")
_C_BATCHES = obs.counter("serve_batches_total", "micro-batches dispatched")
_C_STALL = obs.counter(
    "serve_stall_seconds_total",
    "service time spent blocked on shard staging (pool stall delta)")
_C_COMPUTE = obs.counter(
    "serve_compute_seconds_total",
    "service time spent computing (scan + merge + re-rank)")
_G_OCCUPANCY = obs.gauge(
    "serve_batch_occupancy", "fraction of micro-batch slots used (last)")
_C_DEGRADED = obs.counter(
    "serve_degraded_queries_total",
    "served queries answered with shard coverage < 1.0 (skipped/"
    "quarantined/deadline-ejected shards)")


@dataclasses.dataclass
class ServeStats:
    n_queries: int
    n_batches: int
    warmup_s: float           # jit compile + first dispatch
    p50_ms: float             # end-to-end latency incl. queueing
    p99_ms: float
    mean_batch_occupancy: float   # fraction of micro-batch slots used
    qps: float
    # staging-stall vs compute breakdown (out-of-core serving): stall is
    # the time batches spent blocked waiting on a shard to stage (the
    # pool's `stall_s` delta over the stream — what prefetch hides),
    # compute is the remaining service time (adc_topk scans, merges, the
    # re-rank tail). Resident serving reports stall 0.
    stall_ms: float = 0.0
    compute_ms: float = 0.0
    # graceful-degradation accounting (out-of-core serving under faults
    # or deadlines): queries whose shard coverage came back < 1.0, and
    # the mean per-query coverage over the stream. A clean run reports
    # 0 / 1.0.
    degraded_queries: int = 0
    mean_coverage: float = 1.0

    def row(self) -> str:
        return (f"queries={self.n_queries} batches={self.n_batches} "
                f"occupancy={self.mean_batch_occupancy:.2f} "
                f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"qps={self.qps:.0f} "
                f"stall={self.stall_ms:.1f}ms compute={self.compute_ms:.1f}ms "
                f"degraded={self.degraded_queries} "
                f"coverage={self.mean_coverage:.3f} "
                f"(warmup {self.warmup_s:.2f}s)")

    def to_json(self, *, staging: Optional[dict] = None) -> str:
        """One machine-readable JSON line (the ``--stats-json`` record):
        the stats fields plus, when serving out-of-core, the staging
        metrics snapshot — so bench tooling consumes a line instead of
        scraping the `row()` print."""
        rec = dataclasses.asdict(self)
        if staging is not None:
            rec["staging"] = staging
        return json.dumps(rec, sort_keys=True)


class SearchServer:
    """One compiled cascade executable + a micro-batching front door.

    The whole cascade — ADC/pairwise scoring and the step-4 neural
    re-rank — dispatches through `kernels/ops` (`backend=`), so the
    re-ranking decode runs the fused `ops.f_theta` kernel on TPU.
    ``tile_table`` points at a `kernels/tuning.py` JSON artifact from a
    native-TPU autotune sweep; it is applied BEFORE the warmup compile so
    the one warmed executable already uses the tuned tile sizes.

    ``index`` may be a resident `SearchIndex` OR an out-of-core
    `repro.index.ShardedIndexView` — the latter serves through
    `search_sharded` (bit-identical results), with the database staying
    mmap'd on disk and device residency bounded by the view's shard LRU.
    """

    def __init__(self, index, *, micro_batch: int = 32, n_probe: int = 8,
                 n_short_aq: int = 64, n_short_pw: int = 16, topk: int = 10,
                 backend: str = "auto", tile_table=None,
                 prefetch: bool = True, deadline_s: Optional[float] = None,
                 on_shard_error: str = "raise"):
        if tile_table is not None:
            from repro.kernels import tuning
            tuning.load(tile_table)
        self.index = index
        self.micro_batch = micro_batch
        self.out_of_core = hasattr(index, "gather_rows")
        # per-query wall-clock budget: a batch whose budget runs out mid-
        # scan ejects its remaining shards and answers degraded (coverage
        # < 1.0) instead of stalling the queue behind it. Resident serving
        # has no shard loop — both knobs are out-of-core only.
        self.deadline_s = deadline_s
        self.last_coverage: Optional[np.ndarray] = None
        if self.out_of_core:
            self.d = int(index.centroids.shape[1])
            # prefetched staging is the default serving path: shard s+1
            # stages in the background while s is scanned
            search_fn = partial(search_mod.search_sharded,
                                prefetch=prefetch,
                                on_shard_error=on_shard_error,
                                return_coverage=True)
        else:
            self.d = int(index.ivf.centroids.shape[1])
            search_fn = search_mod.search
        self._search = partial(
            search_fn, n_probe=n_probe, n_short_aq=n_short_aq,
            n_short_pw=n_short_pw, topk=topk, cfg=index.cfg, backend=backend)
        t0 = time.perf_counter()
        # warmup runs with NO deadline: it pays the jit compiles, which
        # would otherwise eat any realistic per-query budget and warm
        # nothing
        jax.block_until_ready(
            self._search(index, jnp.zeros((micro_batch, self.d),
                                          jnp.float32)))
        self.warmup_s = time.perf_counter() - t0

    def search_batch(self, q, *, deadline_s: Optional[float] = None,
                     t_start_s: Optional[float] = None):
        """q: (n <= micro_batch, d) -> (ids (n, topk), dists (n, topk)).

        Pads to the fixed micro-batch shape so every call hits the one
        warmed executable (no stray recompiles at serve time).
        ``deadline_s`` overrides the server's per-query budget for this
        batch (out-of-core only — it is a host-side argument, so it
        never triggers a recompile); ``t_start_s`` moves the budget's
        origin to an earlier `time.perf_counter` timestamp (the front
        door passes the batch's arrival time, so queueing delay is
        charged against the same budget — the `search_sharded`
        remaining-budget machinery). Per-query coverage of the last
        batch lands in ``self.last_coverage`` (None for resident)."""
        with obs.span("serve/batch"):
            q = np.asarray(q, np.float32)
            n = q.shape[0]
            if n > self.micro_batch:
                raise ValueError(f"batch of {n} exceeds micro_batch="
                                 f"{self.micro_batch}")
            if n < self.micro_batch:
                q = np.concatenate(
                    [q, np.zeros((self.micro_batch - n, self.d),
                                 np.float32)])
        with obs.span("serve/dispatch"):
            # span already fences at exit when tracing; the explicit
            # block stays because serve-time latency accounting needs
            # device-complete timing even with tracing off
            if self.out_of_core:
                dl = deadline_s if deadline_s is not None else self.deadline_s
                kw = {} if dl is None else {"deadline_s": dl}
                if dl is not None and t_start_s is not None:
                    kw["t_start_s"] = t_start_s
                ids, dists, cov = self._search(self.index, jnp.asarray(q),
                                               **kw)
                self.last_coverage = np.asarray(cov)[:n]
            else:
                ids, dists = self._search(self.index, jnp.asarray(q))
                self.last_coverage = None
            jax.block_until_ready((ids, dists))
        return np.asarray(ids)[:n], np.asarray(dists)[:n]

    def serve_stream(self, queries, arrival_s, *,
                     max_wait_s: float = 2e-3) -> ServeStats:
        """Drain a pre-timed query stream through micro-batches.

        queries: (n, d); arrival_s: (n,) nondecreasing arrival offsets.
        A batch launches when it is full OR when ``max_wait_s`` has passed
        since its first query arrived — a non-full batch always pays the
        full wait (the server cannot know no more queries are coming), so
        the reported latencies include the real accumulation cost.
        Service time is measured wall clock; queueing is tracked on the
        virtual arrival clock.
        """
        queries = np.asarray(queries, np.float32)
        arrival_s = np.asarray(arrival_s, np.float64)
        n = len(queries)
        if n == 0:
            # empty stream: a zeroed record, not an IndexError on
            # arrival_s[0] (regression: tests/test_transport.py)
            return ServeStats(n_queries=0, n_batches=0,
                              warmup_s=self.warmup_s, p50_ms=0.0,
                              p99_ms=0.0, mean_batch_occupancy=0.0,
                              qps=0.0)
        occ, batches = [], 0
        clock = 0.0
        service_total = 0.0
        degraded = 0
        cov_sum = 0.0
        stall0 = self._staging_stall_s()
        # p50/p99 come from a *windowed* quantile over the process-wide
        # latency histogram: snapshot before, interpolate over the delta
        # after — per-run percentiles with no stored latency array. The
        # fallback list only exists for the metrics-disabled registry.
        lat_win = _H_LATENCY.collect()
        lat_fallback = [] if not obs.enabled() else None
        i = 0
        while i < n:
            with obs.span("serve/admission"):
                t_open = max(clock, arrival_s[i])  # first query in batch
                deadline = t_open + max_wait_s
                j = i + 1
                while (j < n and j - i < self.micro_batch
                       and arrival_s[j] <= deadline):
                    j += 1
                full = j - i == self.micro_batch
                start = max(t_open, arrival_s[j - 1]) if full else deadline
            dl = None
            if self.deadline_s is not None:
                # remaining per-query budget at dispatch: the oldest query
                # in the batch has already spent its (virtual-clock)
                # queueing delay; the shard scan gets what is left
                dl = max(0.0, self.deadline_s - max(0.0,
                                                    start - arrival_s[i]))
            t0 = time.perf_counter()
            with obs.query_trace("serve_batch", size=j - i):
                self.search_batch(queries[i:j], deadline_s=dl)
            service = time.perf_counter() - t0
            service_total += service
            clock = start + service
            if self.last_coverage is not None:
                d = int(np.count_nonzero(self.last_coverage < 1.0))
                if d:
                    degraded += d
                    _C_DEGRADED.inc(d)
                cov_sum += float(self.last_coverage.sum())
            else:
                cov_sum += j - i
            for k in range(i, j):
                _H_QUEUE.observe(max(0.0, start - arrival_s[k]))
                lat_k = clock - arrival_s[k]
                _H_LATENCY.observe(lat_k)
                if lat_fallback is not None:
                    lat_fallback.append(lat_k)
            occ.append((j - i) / self.micro_batch)
            _G_OCCUPANCY.set((j - i) / self.micro_batch)
            _C_QUERIES.inc(j - i)
            _C_BATCHES.inc()
            batches += 1
            i = j
        span = max(clock - arrival_s[0], 1e-9)
        stall_s = max(0.0, self._staging_stall_s() - stall0)
        _C_STALL.inc(stall_s)
        _C_COMPUTE.inc(max(0.0, service_total - stall_s))
        if lat_fallback is not None:
            p50 = float(np.percentile(np.asarray(lat_fallback), 50))
            p99 = float(np.percentile(np.asarray(lat_fallback), 99))
        else:
            p50 = _H_LATENCY.quantile(0.5, since=lat_win)
            p99 = _H_LATENCY.quantile(0.99, since=lat_win)
        return ServeStats(
            n_queries=n, n_batches=batches, warmup_s=self.warmup_s,
            p50_ms=p50 * 1e3, p99_ms=p99 * 1e3,
            mean_batch_occupancy=float(np.mean(occ)),
            qps=float(n / span),
            stall_ms=stall_s * 1e3,
            compute_ms=max(0.0, service_total - stall_s) * 1e3,
            degraded_queries=degraded,
            mean_coverage=float(cov_sum / max(1, n)))

    def _staging_stall_s(self) -> float:
        """Cumulative time search batches spent blocked on shard staging
        (the view's pool counter; 0 for resident serving)."""
        pool = getattr(self.index, "pool", None)
        return float(pool.stats()["stall_s"]) if pool is not None else 0.0


def synthetic_stream(index, n_queries: int, rate_qps: float, *,
                     noise: float = 0.05, seed: int = 0):
    """Queries near stored vectors (AQ reconstructions + noise) with
    Poisson arrivals at ``rate_qps`` — a self-contained load generator
    for any store (no raw database needed). Accepts a resident
    `SearchIndex` or an out-of-core `ShardedIndexView` (rows are gathered
    from the mmap'd shards; the database never loads)."""
    from repro.core import aq as aq_mod
    rng = np.random.default_rng(seed)
    if hasattr(index, "gather_rows"):
        sids = np.asarray(index.shard_ids)
        pick_s = sids[rng.integers(0, len(sids), size=n_queries)]
        rows = np.array([rng.integers(0, index.store.shard_rows(int(s)))
                         for s in pick_s])
        gids = pick_s * index.shard_size + rows
        codes, assign, _ = index.gather_rows(gids)
        recon = (aq_mod.aq_decode(index.aq_books, jnp.asarray(codes))
                 + index.centroids[jnp.asarray(assign)])
    else:
        pick = rng.integers(0, index.codes.shape[0], size=n_queries)
        recon = (aq_mod.aq_decode(index.aq_books, index.codes[pick])
                 + index.ivf.centroids[index.ivf.assignments[pick]])
    q = np.asarray(recon) + noise * rng.normal(
        size=(n_queries, recon.shape[1])).astype(np.float32)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_queries))
    return q.astype(np.float32), arrivals


# ---------------------------------------------------------------------------
# The network front door: real transport + continuous-batching admission
# ---------------------------------------------------------------------------

# Front-door telemetry (docs/SERVING.md, docs/OBSERVABILITY.md). Latency/
# queue histograms and the accepted/answered/shed counters carry a
# `tenant=` label (one child series per registered store/view); the
# unlabeled default series aggregates across tenants and feeds
# `FrontDoorStats` percentiles.
_G_FD_DEPTH = obs.gauge(
    "frontdoor_queue_depth",
    "admitted queries awaiting dispatch (unlabeled = global, "
    "tenant= children = per tenant)")
_G_FD_READY = obs.gauge(
    "frontdoor_ready", "1 while accepting, 0 while draining/stopped")
_C_FD_ACCEPTED = obs.counter(
    "frontdoor_accepted_total", "queries admitted to the batch queue")
_C_FD_ANSWERED = obs.counter(
    "frontdoor_answered_total",
    "admitted queries answered (response dispatched, whether or not the "
    "client was still there to read it)")
_C_FD_SHED = obs.counter(
    "frontdoor_shed_total",
    "queries rejected RESOURCE_EXHAUSTED (queue watermark / tenant quota)")
_C_FD_REJECTED = obs.counter(
    "frontdoor_rejected_total",
    "requests rejected before admission (label reason=invalid|not_found|"
    "unavailable)")
_C_FD_DRAINED = obs.counter(
    "frontdoor_drained_queries_total",
    "queries answered during graceful drain (accepted before shutdown)")
_C_FD_BATCHES = obs.counter(
    "frontdoor_batches_total", "continuous micro-batches dispatched")
_H_FD_LATENCY = obs.histogram(
    "frontdoor_latency_seconds",
    "admission -> response-dispatched latency (label tenant=)")
_H_FD_QUEUE = obs.histogram(
    "frontdoor_queue_seconds",
    "admission -> batch-dispatch queueing delay (label tenant=)")
_G_FD_OCC = obs.gauge(
    "frontdoor_batch_occupancy",
    "fraction of micro-batch slots used by the last dispatched batch")


class _PendingRequest:
    """One admitted search request (1..micro_batch query rows) waiting in
    a tenant's queue for the forming micro-batch."""

    __slots__ = ("conn", "req_id", "q", "n", "arrival", "deadline_s")

    def __init__(self, conn, req_id, q, arrival, deadline_s):
        self.conn = conn
        self.req_id = req_id
        self.q = q
        self.n = q.shape[0]
        self.arrival = arrival
        self.deadline_s = deadline_s


class _Tenant:
    """One registered store/view: a warmed `SearchServer` executable, a
    pending-request queue, and a queued-row quota."""

    def __init__(self, name: str, server: SearchServer, quota: int):
        import collections
        self.name = name
        self.server = server
        self.quota = quota
        self.pending = collections.deque()
        self.queued = 0                       # rows, not requests
        self.accepted = 0
        self.answered = 0
        self.shed = 0
        self.g_depth = _G_FD_DEPTH.labels(tenant=name)
        self.c_accepted = _C_FD_ACCEPTED.labels(tenant=name)
        self.c_answered = _C_FD_ANSWERED.labels(tenant=name)
        self.c_shed = _C_FD_SHED.labels(tenant=name)
        self.h_latency = _H_FD_LATENCY.labels(tenant=name)
        self.h_queue = _H_FD_QUEUE.labels(tenant=name)

    def formed_rows(self, mb: int):
        """(rows that would dispatch now, batch-is-full) without popping:
        leading requests that fit in ``mb`` rows, never splitting a
        request across batches (each response frame answers one request
        exactly once).

        Deadline-carrying requests are never co-batched: the shard loop
        takes ONE ``deadline_s`` per `search_batch` call, so mixing
        deadlines would eject shards for every query in the batch and
        degrade co-batched requests that never asked for a budget
        (violating admission-never-changes-what-is-computed). A request
        with a deadline dispatches as its own immediately-full batch; a
        deadline request behind no-deadline ones closes the forming
        batch at the boundary (it goes next, alone)."""
        rows = 0
        for r in self.pending:
            if r.deadline_s is not None:
                # head: solo immediately-full batch; non-head: boundary
                return (r.n, True) if rows == 0 else (rows, True)
            if rows + r.n > mb:
                return rows, True              # next request doesn't fit
            rows += r.n
            if rows == mb:
                return rows, True
        return rows, False


@dataclasses.dataclass
class FrontDoorStats:
    """Lifetime totals of one `SearchFrontDoor` (the socket-serving
    analogue of `ServeStats`; written as the ``--stats-json`` line).
    Every *accepted* query is eventually *answered* — the drain
    invariant CI asserts (`accepted == answered`)."""
    n_accepted: int
    n_answered: int
    n_shed: int
    n_rejected: int
    n_drained: int
    n_batches: int
    p50_ms: float
    p99_ms: float
    mean_batch_occupancy: float
    qps: float                     # answered / serving wall-clock
    drained_clean: bool            # shutdown finished with an empty queue
    per_tenant: dict = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        return (f"accepted={self.n_accepted} answered={self.n_answered} "
                f"shed={self.n_shed} rejected={self.n_rejected} "
                f"drained={self.n_drained} batches={self.n_batches} "
                f"occupancy={self.mean_batch_occupancy:.2f} "
                f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"qps={self.qps:.0f} clean_drain={self.drained_clean}")

    def to_json(self, *, staging: Optional[dict] = None) -> str:
        rec = dataclasses.asdict(self)
        if staging is not None:
            rec["staging"] = staging
        return json.dumps(rec, sort_keys=True)


class SearchFrontDoor:
    """Overload-robust socket front door over one or more `SearchServer`
    tenants (docs/SERVING.md).

    - **Real transport**: length-prefixed JSON+binary frames over TCP
      (`repro.launch.transport`), one accept thread, per-connection
      readers that only validate + enqueue.
    - **Continuous-batching admission**: an arriving query joins the
      *currently forming* micro-batch of its tenant; the batch
      dispatches when full or when its oldest query has waited
      ``max_wait_s`` — no fixed windows, no next-window wait.
    - **Bounded queue + shedding**: admission is capped at ``max_queue``
      queued rows; past ``shed_watermark * max_queue`` (and past a
      tenant's ``quota``) requests are shed with a typed
      `RESOURCE_EXHAUSTED` rejection carrying a ``retry_after_ms`` hint
      derived from the backlog and the EWMA batch service time.
    - **Deadline propagation**: a request's ``deadline_ms`` budget runs
      from ADMISSION — at dispatch its (arrival, budget) pair goes into
      `search_sharded(deadline_s=, t_start_s=)`, so queueing delay
      spends the same budget the shard loop checks and an exhausted
      budget answers degraded instead of stalling the queue. A deadline
      request dispatches as its own single-request batch (its budget
      must never eject shards for co-batched neighbors that asked for
      none) and is rejected `INVALID_ARGUMENT` on resident tenants
      (no shard loop — mirrors the ``--deadline-ms``-requires-
      ``--out-of-core`` CLI rule).
    - **Multi-tenancy**: several named stores/views register under one
      scheduler; ready tenants are served round-robin so one hot tenant
      cannot starve the rest, and per-tenant quotas bound each tenant's
      share of the queue.
    - **Graceful drain**: `shutdown()` (or SIGTERM via `main`) stops
      accepting, answers every already-admitted query (dispatching
      part-full batches immediately), replies `UNAVAILABLE` to requests
      racing in on live connections, then closes the transport.
      `/healthz` / `/readyz` hang off the obs metrics endpoint via
      `attach_health`.

    Results are bit-identical to the in-process `serve_stream` path:
    admission only decides *when* `SearchServer.search_batch` runs and
    with which rows — never what it computes.
    """

    def __init__(self, *, host: str = "127.0.0.1", port: int = 0,
                 max_wait_s: float = 2e-3, max_queue: int = 256,
                 shed_watermark: float = 0.75):
        if not 0.0 < shed_watermark <= 1.0:
            raise ValueError(f"shed_watermark={shed_watermark} outside "
                             f"(0, 1]")
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} < 1")
        self._host, self._want_port = host, port
        self.max_wait_s = float(max_wait_s)
        self.max_queue = int(max_queue)
        self.watermark = max(1, int(shed_watermark * max_queue))
        self._tenants: dict = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._rr = 0
        self._queued_total = 0
        self._draining = False
        self._drained_clean = False
        self._transport = None
        self._dispatcher: Optional[threading.Thread] = None
        self._ewma_batch_s: Optional[float] = None
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        # lifetime totals (registry-independent, so stats work with the
        # registry disabled too)
        self.n_accepted = 0
        self.n_answered = 0
        self.n_shed = 0
        self.n_rejected = 0
        self.n_drained = 0
        self.n_batches = 0
        self._occ: list = []
        self._lat_win = _H_FD_LATENCY.collect()
        self._lat_fallback: Optional[list] = [] if not obs.enabled() else None

    # -- tenancy -------------------------------------------------------------

    def register(self, name: str, index, *, quota: Optional[int] = None,
                 **server_kw) -> SearchServer:
        """Register a store/view as tenant ``name`` (warms one
        `SearchServer` executable). ``quota`` caps the tenant's queued
        rows (default: the whole queue)."""
        if self._draining:
            raise RuntimeError("front door is draining")
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        server = (index if isinstance(index, SearchServer)
                  else SearchServer(index, **server_kw))
        with self._lock:
            self._tenants[name] = _Tenant(
                name, server, int(quota) if quota else self.max_queue)
        return server

    @property
    def tenants(self) -> tuple:
        return tuple(self._tenants)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> int:
        """Bind the transport and start the dispatcher; returns the
        bound port."""
        from repro.launch import transport as tp
        if self._transport is not None:
            raise RuntimeError("already started")
        if not self._tenants:
            raise RuntimeError("register at least one tenant before start")
        self._transport = tp.TransportServer(
            self._handle_frame, host=self._host, port=self._want_port)
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="frontdoor-dispatch",
            daemon=True)
        self._dispatcher.start()
        _G_FD_READY.set(1)
        return self._transport.port

    @property
    def port(self) -> int:
        return self._transport.port

    @property
    def accepting(self) -> bool:
        return (self._transport is not None and not self._draining
                and self._transport.accepting)

    def attach_health(self, metrics_server) -> None:
        """Hang ``/healthz`` (process liveness) and ``/readyz``
        (accepting vs draining) off an `obs.MetricsServer`."""
        def healthz():
            return 200, "text/plain", b"ok\n"

        def readyz():
            if self.accepting:
                return 200, "text/plain", b"ready\n"
            return 503, "text/plain", b"draining\n"

        metrics_server.add_route("/healthz", healthz)
        metrics_server.add_route("/readyz", readyz)

    def shutdown(self, *, timeout_s: float = 30.0) -> bool:
        """Graceful drain: stop accepting, answer everything admitted,
        close the transport. True if the queue drained fully inside
        ``timeout_s`` (the clean-drain invariant). Idempotent."""
        with self._cond:
            already = self._draining
            self._draining = True
            self._cond.notify_all()
        _G_FD_READY.set(0)
        if self._transport is not None:
            self._transport.stop_accepting()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=timeout_s)
            clean = not self._dispatcher.is_alive()
        else:
            clean = True
        with self._lock:
            self._drained_clean = clean and self._queued_total == 0
        if self._transport is not None and not already:
            self._transport.close()
        return self._drained_clean

    # -- admission (transport reader threads) --------------------------------

    def _reject(self, conn, req_id, status, msg, *, reason=None,
                retry_after_ms=None, tenant: Optional[_Tenant] = None,
                n: int = 1) -> None:
        hdr = {"id": req_id, "status": status, "error": msg}
        from repro.launch import transport as tp
        # rejections arrive on concurrent transport reader threads and
        # Python `+=` on attributes is not atomic: the shed/rejected
        # totals (the accepted/shed/rejected accounting CI asserts on)
        # mutate under the scheduler lock. Only the SEND stays outside
        # it — a client that stopped reading must stall its own socket,
        # never the scheduler.
        with self._lock:
            if status == tp.STATUS_SHED:
                hdr["retry_after_ms"] = (retry_after_ms
                                         if retry_after_ms is not None
                                         else self._retry_after_ms())
                self.n_shed += n
                _C_FD_SHED.inc(n)
                if tenant is not None:
                    tenant.shed += n
                    tenant.c_shed.inc(n)
            else:
                self.n_rejected += 1
                _C_FD_REJECTED.labels(reason=reason or status.lower()).inc()
        conn.send(hdr)

    def _retry_after_ms(self) -> float:
        """Backlog-derived backoff hint: how long the current queue
        takes to drain at the EWMA batch service rate (clamped to
        [1 ms, 2 s]; 25 ms before any batch has been timed)."""
        svc = self._ewma_batch_s
        if svc is None:
            return 25.0
        mb = max(t.server.micro_batch for t in self._tenants.values())
        est = (self._queued_total / max(1, mb)) * svc * 1e3
        return float(min(2000.0, max(1.0, est)))

    def _handle_frame(self, conn, header: dict, body: bytes) -> None:
        from repro.launch import transport as tp
        req_id = header.get("id")
        op = header.get("op")
        if op == "ping":
            # pong carries the serving shapes so a client can build
            # well-formed queries without out-of-band config
            conn.send({"id": req_id, "status": tp.STATUS_OK, "op": "pong",
                       "accepting": self.accepting,
                       "tenants": {name: {"d": t.server.d,
                                          "micro_batch": t.server.micro_batch}
                                   for name, t in self._tenants.items()}})
            return
        if op != "search":
            self._reject(conn, req_id, tp.STATUS_INVALID,
                         f"unknown op {op!r}", reason="invalid")
            return
        tenant = self._tenants.get(header.get("tenant", "default"))
        if tenant is None:
            self._reject(conn, req_id, tp.STATUS_NOT_FOUND,
                         f"unknown tenant {header.get('tenant')!r}; "
                         f"registered: {list(self._tenants)}",
                         reason="not_found")
            return
        srv = tenant.server
        try:
            n, d = int(header["n"]), int(header["d"])
        except (KeyError, TypeError, ValueError):
            self._reject(conn, req_id, tp.STATUS_INVALID,
                         "header needs integer n and d", reason="invalid")
            return
        if d != srv.d or not 1 <= n <= srv.micro_batch:
            self._reject(conn, req_id, tp.STATUS_INVALID,
                         f"bad shape n={n} d={d} (tenant serves d={srv.d}, "
                         f"micro_batch={srv.micro_batch})", reason="invalid")
            return
        if len(body) != n * d * 4:
            self._reject(conn, req_id, tp.STATUS_INVALID,
                         f"body is {len(body)} bytes, expected {n * d * 4}",
                         reason="invalid")
            return
        deadline_s = None
        if header.get("deadline_ms") is not None:
            try:
                deadline_s = float(header["deadline_ms"]) / 1e3
            except (TypeError, ValueError):
                self._reject(conn, req_id, tp.STATUS_INVALID,
                             "deadline_ms must be a number",
                             reason="invalid")
                return
            if deadline_s <= 0:
                self._reject(conn, req_id, tp.STATUS_INVALID,
                             "deadline_ms must be > 0", reason="invalid")
                return
            if not srv.out_of_core:
                # the network mirror of the --deadline-ms/--out-of-core
                # argparse rule: a resident tenant has no shard loop to
                # eject, so the knob must fail loud, never silently no-op
                self._reject(conn, req_id, tp.STATUS_INVALID,
                             f"deadline_ms requires an out-of-core "
                             f"tenant; {tenant.name!r} serves a resident "
                             f"index (no shard loop to eject)",
                             reason="invalid")
                return
        q = np.frombuffer(body, "<f4").reshape(n, d).astype(np.float32)
        req = _PendingRequest(conn, req_id, q, time.perf_counter(),
                              deadline_s)
        # admission decision under the lock, rejection SEND outside it —
        # a client that stopped reading must stall its own socket, never
        # the scheduler's condition variable
        verdict = None
        with self._cond:
            if self._draining or not self._transport.accepting:
                verdict = (tp.STATUS_UNAVAILABLE, "draining", "unavailable")
            elif tenant.queued + n > tenant.quota:
                verdict = (tp.STATUS_SHED,
                           f"tenant {tenant.name!r} over quota "
                           f"({tenant.queued}+{n} > {tenant.quota})", None)
            elif (self._queued_total + n > self.watermark
                    or self._queued_total + n > self.max_queue):
                verdict = (tp.STATUS_SHED,
                           f"queue depth {self._queued_total}+{n} past "
                           f"watermark {self.watermark}", None)
            else:
                self._admit_locked(tenant, req, n)
        if verdict is not None:
            status, msg, reason = verdict
            self._reject(conn, req_id, status, msg, reason=reason,
                         tenant=tenant, n=n)

    def _admit_locked(self, tenant: _Tenant, req: _PendingRequest,
                      n: int) -> None:
        tenant.pending.append(req)
        tenant.queued += n
        self._queued_total += n
        tenant.accepted += n
        self.n_accepted += n
        if self._t_first is None:
            self._t_first = req.arrival
        tenant.c_accepted.inc(n)
        _C_FD_ACCEPTED.inc(n)
        tenant.g_depth.set(tenant.queued)
        _G_FD_DEPTH.set(self._queued_total)
        self._cond.notify_all()

    # -- continuous batching + dispatch (one scheduler thread) ---------------

    def _pick_tenant(self) -> Optional[_Tenant]:
        """Round-robin over tenants with pending work (called under the
        lock): the cursor advances past the served tenant, so a hot
        tenant hands the scheduler to the next ready one every batch."""
        names = list(self._tenants)
        for off in range(len(names)):
            t = self._tenants[names[(self._rr + off) % len(names)]]
            if t.pending:
                self._rr = (self._rr + off + 1) % len(names)
                return t
        return None

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                t = self._pick_tenant()
                while t is None:
                    if self._draining:
                        return                    # drained: queue is empty
                    self._cond.wait(timeout=0.1)
                    t = self._pick_tenant()
                # continuous batching: wait for the forming batch to
                # fill, but never past the oldest query's max_wait — new
                # arrivals notify the condition and JOIN this batch
                mb = t.server.micro_batch
                while not self._draining:
                    rows, full = t.formed_rows(mb)
                    expire = t.pending[0].arrival + self.max_wait_s
                    now = time.perf_counter()
                    if full or now >= expire:
                        break
                    self._cond.wait(timeout=min(expire - now, 0.05))
                # pop the formed batch, honoring the same boundaries as
                # `formed_rows`: a deadline request is always alone
                batch, rows = [], 0
                if t.pending and t.pending[0].deadline_s is not None:
                    r = t.pending.popleft()
                    batch.append(r)
                    rows = r.n
                else:
                    while (t.pending
                           and t.pending[0].deadline_s is None
                           and rows + t.pending[0].n <= mb):
                        r = t.pending.popleft()
                        batch.append(r)
                        rows += r.n
                t.queued -= rows
                self._queued_total -= rows
                t.g_depth.set(t.queued)
                _G_FD_DEPTH.set(self._queued_total)
                draining = self._draining
            try:
                self._dispatch(t, batch, rows, draining)
            except Exception as e:                # noqa: BLE001
                # a dispatch failure must not kill the scheduler: every
                # request in the batch gets a typed error, the loop lives
                from repro.launch import transport as tp
                from repro.index.store import ShardIntegrityError
                status = (tp.STATUS_INTEGRITY
                          if isinstance(e, ShardIntegrityError)
                          else tp.STATUS_INTERNAL)
                for r in batch:
                    self._count_answered(t, r, draining)
                    r.conn.send({"id": r.req_id, "status": status,
                                 "error": f"{type(e).__name__}: {e}"})

    def _count_answered(self, t: _Tenant, r: _PendingRequest,
                        draining: bool) -> None:
        t.answered += r.n
        self.n_answered += r.n
        t.c_answered.inc(r.n)
        _C_FD_ANSWERED.inc(r.n)
        if draining:
            self.n_drained += r.n
            _C_FD_DRAINED.inc(r.n)

    def _dispatch(self, t: _Tenant, batch, rows: int, draining: bool
                  ) -> None:
        from repro.launch import transport as tp
        q = np.concatenate([r.q for r in batch])
        t_dispatch = time.perf_counter()
        # a deadline request dispatches alone (`formed_rows` boundary)
        # and admission rejects deadlines on resident tenants, so the
        # batch's budget — measured from ITS admission (t_start_s), so
        # queueing delay is already spent when the shard loop starts —
        # only ever bounds the one request that asked for it
        kw = {}
        if batch[0].deadline_s is not None:
            assert len(batch) == 1, "deadline requests dispatch solo"
            kw = {"deadline_s": batch[0].deadline_s,
                  "t_start_s": batch[0].arrival}
        t0 = time.perf_counter()
        with obs.query_trace("frontdoor_batch", size=rows, tenant=t.name):
            ids, dists = t.server.search_batch(q, **kw)
        service = time.perf_counter() - t0
        self._ewma_batch_s = (service if self._ewma_batch_s is None
                              else 0.8 * self._ewma_batch_s + 0.2 * service)
        cov = t.server.last_coverage
        t_done = time.perf_counter()
        # same no-read-your-own-answer rule as _count_answered below:
        # once a client holds its reply, the batch must already be in
        # the counters
        self.n_batches += 1
        _C_FD_BATCHES.inc()
        off = 0
        for r in batch:
            body = (np.ascontiguousarray(ids[off:off + r.n], "<i4").tobytes()
                    + np.ascontiguousarray(dists[off:off + r.n],
                                           "<f4").tobytes())
            hdr = {"id": r.req_id, "status": tp.STATUS_OK, "n": r.n,
                   "topk": int(ids.shape[1]), "has_coverage": False}
            if cov is not None:
                hdr["has_coverage"] = True
                body += np.ascontiguousarray(cov[off:off + r.n],
                                             "<f4").tobytes()
            # count BEFORE the send: a client acting on its reply must
            # already see the answer in the counters (no read-your-own-
            # answer race for harnesses asserting accepted == answered)
            self._count_answered(t, r, draining)
            r.conn.send(hdr, body)
            lat = t_done - r.arrival
            t.h_latency.observe(lat)
            _H_FD_LATENCY.observe(lat)
            t.h_queue.observe(t_dispatch - r.arrival)
            _H_FD_QUEUE.observe(t_dispatch - r.arrival)
            if self._lat_fallback is not None:
                self._lat_fallback.append(lat)
            off += r.n
        occ = rows / t.server.micro_batch
        self._occ.append(occ)
        _G_FD_OCC.set(occ)
        self._t_last = t_done

    # -- stats ---------------------------------------------------------------

    def stats(self) -> FrontDoorStats:
        if self._lat_fallback is not None:
            arr = np.asarray(self._lat_fallback or [0.0])
            p50, p99 = (float(np.percentile(arr, 50)),
                        float(np.percentile(arr, 99)))
        else:
            p50 = _H_FD_LATENCY.quantile(0.5, since=self._lat_win)
            p99 = _H_FD_LATENCY.quantile(0.99, since=self._lat_win)
        span = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last is not None
                else 0.0)
        return FrontDoorStats(
            n_accepted=self.n_accepted, n_answered=self.n_answered,
            n_shed=self.n_shed, n_rejected=self.n_rejected,
            n_drained=self.n_drained, n_batches=self.n_batches,
            p50_ms=p50 * 1e3, p99_ms=p99 * 1e3,
            mean_batch_occupancy=float(np.mean(self._occ)) if self._occ
            else 0.0,
            qps=float(self.n_answered / span) if span > 0 else 0.0,
            drained_clean=self._drained_clean,
            per_tenant={name: {"accepted": t.accepted,
                               "answered": t.answered, "shed": t.shed}
                        for name, t in self._tenants.items()})


def _serve_socket(args, server: SearchServer, index) -> FrontDoorStats:
    """Socket mode body of `main`: bind the front door, serve until
    SIGTERM/SIGINT (or `last_front_door.shutdown()` from a harness
    thread), drain, flush stats, close the metrics endpoint."""
    global last_front_door
    front = SearchFrontDoor(
        port=args.port, max_wait_s=args.max_wait_ms / 1e3,
        max_queue=args.max_queue, shed_watermark=args.shed_watermark)
    front.register(args.tenant, server, quota=args.quota)
    last_front_door = front
    port = front.start()
    if last_metrics_server is not None:
        front.attach_health(last_metrics_server)
    print(f"[serve_search] front door on :{port} "
          f"(tenant={args.tenant!r} micro_batch={server.micro_batch} "
          f"max_queue={front.max_queue} watermark={front.watermark})",
          flush=True)
    if args.port_file:
        with open(args.port_file, "w") as f:
            json.dump({"port": port,
                       "metrics_port": (last_metrics_server.port
                                        if last_metrics_server else None)},
                      f)
    stop = threading.Event()
    if threading.current_thread() is threading.main_thread():
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, lambda *_: stop.set())
    # serve until told to stop; a harness embedding main() on a side
    # thread calls last_front_door.shutdown() instead of signaling.
    # --refresh-ms: poll the store for published mutations (delta
    # shards, tombstones, a compacted generation) between waits; the
    # swap is atomic and pinned in-flight batches keep their snapshot,
    # so answers mid-refresh are never mixed-generation
    refresh_s = (args.refresh_ms / 1e3) if args.refresh_ms else None
    next_refresh = (time.monotonic() + refresh_s) if refresh_s else None
    while not stop.is_set():
        if front._dispatcher is not None and not front._dispatcher.is_alive():
            break                              # drained via shutdown()
        if next_refresh is not None and time.monotonic() >= next_refresh:
            try:
                if index.refresh():
                    print(f"[serve_search] refreshed: "
                          f"generation={index.generation} "
                          f"rows={index.n_alive} alive", flush=True)
            except Exception as e:             # keep serving the old state
                print(f"[serve_search] refresh failed ({e}); retrying",
                      flush=True)
            next_refresh = time.monotonic() + refresh_s
        stop.wait(timeout=0.2)
    print("[serve_search] draining...", flush=True)
    clean = front.shutdown()
    stats = front.stats()
    if args.trace:
        obs.disable_tracing()
    print(f"[serve_search] {stats.row()}")
    staging = None
    if args.out_of_core:
        ps = index.pool.stats()
        staging = dict(ps, skipped_shards=index.skipped_shards_total,
                       quarantined_shards=len(index.quarantined))
    if args.stats_json:
        with open(args.stats_json, "a") as f:
            f.write(stats.to_json(staging=staging) + "\n")
    if last_metrics_server is not None:
        last_metrics_server.close()
    print(f"[serve_search] drain {'clean' if clean else 'DIRTY'}; "
          f"sockets closed", flush=True)
    return stats


def main(argv: Optional[list] = None):
    """Entry point. Two modes:

    - **stream** (default): generate a synthetic Poisson stream and
      drain it in-process through `SearchServer.serve_stream`; returns
      `ServeStats`.
    - **socket** (``--port``): bind the `SearchFrontDoor` transport and
      serve framed requests until SIGTERM/SIGINT, then drain gracefully;
      returns `FrontDoorStats`.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--micro-batch", type=int, default=32)
    ap.add_argument("--rate", type=float, default=2000.0, help="offered QPS")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--n-short-aq", type=int, default=64)
    ap.add_argument("--n-short-pw", type=int, default=16)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--tile-table", default=None,
                    help="kernels/tuning.py JSON artifact (autotuned "
                         "per-op tile sizes) to apply before warmup")
    ap.add_argument("--out-of-core", action="store_true",
                    help="serve straight off a ShardedIndexView: shards "
                         "stay mmap'd on disk, device residency bounded "
                         "by --max-resident-shards")
    ap.add_argument("--max-resident-shards", type=int, default=2)
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable background shard prefetch (out-of-core "
                         "only; stages each shard synchronously)")
    ap.add_argument("--allow-partial", action="store_true",
                    help="serve an incomplete store (completed shards "
                         "only; requires --out-of-core or loads a prefix)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query wall-clock budget: eject remaining "
                         "shards when it runs out and answer degraded "
                         "(out-of-core only)")
    ap.add_argument("--on-shard-error", choices=("raise", "skip"),
                    default="raise",
                    help="'skip': serve past failed/quarantined shards "
                         "with coverage < 1.0 instead of crashing "
                         "(out-of-core only)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject storage faults, e.g. "
                         "'p_read_error=0.2,p_corrupt=0.1,seed=7' "
                         "(see repro.index.faults.FaultPlan; "
                         "out-of-core only)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip shard checksum verification at open and "
                         "stage time (out-of-core only)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose a Prometheus /metrics + /metrics.json "
                         "scrape endpoint on this port (0 = ephemeral; "
                         "stays up until process exit)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="append one machine-readable JSON line (stats "
                         "+ staging snapshot) to PATH")
    ap.add_argument("--trace", action="store_true",
                    help="enable per-query stage tracing for the run "
                         "(jit-aware fenced spans; see "
                         "docs/OBSERVABILITY.md for the perturbation "
                         "caveat)")
    # socket mode (docs/SERVING.md): bind the front-door transport
    # instead of draining a synthetic in-process stream
    ap.add_argument("--port", type=int, default=None,
                    help="serve framed requests over TCP on this port "
                         "(0 = ephemeral) until SIGTERM, then drain "
                         "gracefully; omit for in-process stream mode")
    ap.add_argument("--port-file", default=None, metavar="PATH",
                    help="write a JSON line {'port':..,'metrics_port':..} "
                         "once the sockets are bound (how harnesses find "
                         "an ephemeral port)")
    ap.add_argument("--tenant", default="default",
                    help="tenant name this store registers as")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="bound on admitted-but-undispatched query rows")
    ap.add_argument("--shed-watermark", type=float, default=0.75,
                    help="fraction of --max-queue past which requests "
                         "are shed RESOURCE_EXHAUSTED")
    ap.add_argument("--refresh-ms", type=float, default=None,
                    help="poll the store every N ms and pick up published "
                         "delta shards / tombstones / compacted "
                         "generations without restarting (socket mode, "
                         "out-of-core only)")
    ap.add_argument("--quota", type=int, default=None,
                    help="per-tenant queued-row quota (default: the "
                         "whole queue)")
    args = ap.parse_args(argv)

    # out-of-core-only knobs must not silently no-op on a resident
    # server: fail loud at the CLI boundary
    if not args.out_of_core:
        bad = [flag for flag, on in (
            ("--chaos", args.chaos is not None),
            ("--deadline-ms", args.deadline_ms is not None),
            ("--on-shard-error skip", args.on_shard_error == "skip"),
            ("--no-verify", args.no_verify),
            ("--refresh-ms", args.refresh_ms is not None)) if on]
        if bad:
            ap.error(f"{', '.join(bad)} require(s) --out-of-core: these "
                     f"knobs act on the sharded read path (fault "
                     f"injection, shard deadline ejection, skip-on-error, "
                     f"checksum verification) and would silently do "
                     f"nothing on a resident index")
    if args.refresh_ms is not None and args.port is None:
        ap.error("--refresh-ms requires --port: the stream mode drains a "
                 "fixed synthetic batch and never revisits the store")

    global last_metrics_server
    if args.metrics_port is not None:
        last_metrics_server = obs.start_metrics_server(args.metrics_port)
        print(f"[serve_search] metrics at {last_metrics_server.url}/metrics")
    if args.trace:
        obs.enable_tracing()

    from repro.index import IndexStore, ShardedIndexView, parse_chaos
    if args.out_of_core:
        faults = parse_chaos(args.chaos) if args.chaos else None
        index = ShardedIndexView(
            args.store, max_resident_shards=args.max_resident_shards,
            allow_partial=args.allow_partial, verify=not args.no_verify,
            faults=faults)
        print(f"[serve_search] out-of-core: {len(index.shard_ids)} shards "
              f"mmap'd, staging budget {index.budget_bytes / 1e6:.1f} MB")
        if faults is not None:
            print(f"[serve_search] chaos: {args.chaos}")
    else:
        index = IndexStore(args.store).load(
            allow_partial=args.allow_partial)
    server = SearchServer(
        index, micro_batch=args.micro_batch, n_probe=args.n_probe,
        n_short_aq=args.n_short_aq, n_short_pw=args.n_short_pw,
        topk=args.topk, backend=args.backend, tile_table=args.tile_table,
        prefetch=not args.no_prefetch,
        deadline_s=(None if args.deadline_ms is None
                    else args.deadline_ms / 1e3),
        on_shard_error=args.on_shard_error)
    if args.port is not None:
        return _serve_socket(args, server, index)
    q, arrivals = synthetic_stream(index, args.queries, args.rate)
    stats = server.serve_stream(q, arrivals,
                                max_wait_s=args.max_wait_ms / 1e3)
    if args.trace:
        obs.disable_tracing()
    print(f"[serve_search] {stats.row()}")
    staging = None
    if args.out_of_core:
        ps = index.pool.stats()
        staging = dict(ps, skipped_shards=index.skipped_shards_total,
                       quarantined_shards=len(index.quarantined))
        print(f"[serve_search] staging: staged={ps['staged']} "
              f"device_hits={ps['device_hits']} host_hits={ps['host_hits']} "
              f"prefetch_issued={ps['prefetch_issued']} "
              f"prefetch_hits={ps['prefetch_hits']} "
              f"evictions={ps['evictions']} "
              f"skipped_shards={index.skipped_shards_total}")
    if args.stats_json:
        with open(args.stats_json, "a") as f:
            f.write(stats.to_json(staging=staging) + "\n")
    return stats


# the scrape endpoint from the last `main(--metrics-port ...)` call, so
# in-process harnesses (ci.sh serve smoke, tests) can find its bound
# ephemeral port; the server lives until process exit or `.close()`
last_metrics_server: Optional[obs.MetricsServer] = None

# the front door from the last `main(--port ...)` call: harnesses
# embedding socket mode on a side thread (no signals there) stop it by
# calling `last_front_door.shutdown()`
last_front_door: Optional["SearchFrontDoor"] = None


if __name__ == "__main__":
    main()
