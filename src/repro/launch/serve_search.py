"""Batched query serving over a store-loaded index.

The query-side counterpart of `index/builder.py`: load a persistent
packed-code store (`repro.index.IndexStore`), warm up ONE compiled
`search()` executable at a fixed micro-batch shape, then drain a query
stream through it with micro-batch accumulation — arrivals are grouped
until the batch fills or a wait deadline passes, exactly the trade the
production serving loop makes between latency and MXU utilization.

Latency accounting runs on a virtual clock fed by measured wall-clock
service times, so the reported p50/p99 include queueing delay and are
reproducible under CI load.

    PYTHONPATH=src python -m repro.launch.serve_search --store /tmp/idx \
        --queries 256 --micro-batch 32 --rate 2000

With ``--out-of-core`` the store is served through a `ShardedIndexView`
(`core/search.search_sharded`): shards stay mmap'd on disk, device
residency is bounded by the shard LRU (``--max-resident-shards``), and
results are bit-identical to resident serving — database size becomes
independent of device memory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import search as search_mod

# Serving telemetry (docs/OBSERVABILITY.md): per-query latency/queueing
# histograms plus the per-batch stall-vs-compute split. `ServeStats`
# p50/p99 are derived from a windowed quantile over
# `serve_latency_seconds` (collect-before / quantile-since-after), so
# the server keeps NO per-query latency array — the stats cost is
# O(buckets) however long the stream runs.
_H_LATENCY = obs.histogram(
    "serve_latency_seconds", "end-to-end per-query latency incl. queueing")
_H_QUEUE = obs.histogram(
    "serve_queue_seconds", "arrival -> batch-dispatch queueing delay")
_C_QUERIES = obs.counter("serve_queries_total", "queries served")
_C_BATCHES = obs.counter("serve_batches_total", "micro-batches dispatched")
_C_STALL = obs.counter(
    "serve_stall_seconds_total",
    "service time spent blocked on shard staging (pool stall delta)")
_C_COMPUTE = obs.counter(
    "serve_compute_seconds_total",
    "service time spent computing (scan + merge + re-rank)")
_G_OCCUPANCY = obs.gauge(
    "serve_batch_occupancy", "fraction of micro-batch slots used (last)")
_C_DEGRADED = obs.counter(
    "serve_degraded_queries_total",
    "served queries answered with shard coverage < 1.0 (skipped/"
    "quarantined/deadline-ejected shards)")


@dataclasses.dataclass
class ServeStats:
    n_queries: int
    n_batches: int
    warmup_s: float           # jit compile + first dispatch
    p50_ms: float             # end-to-end latency incl. queueing
    p99_ms: float
    mean_batch_occupancy: float   # fraction of micro-batch slots used
    qps: float
    # staging-stall vs compute breakdown (out-of-core serving): stall is
    # the time batches spent blocked waiting on a shard to stage (the
    # pool's `stall_s` delta over the stream — what prefetch hides),
    # compute is the remaining service time (adc_topk scans, merges, the
    # re-rank tail). Resident serving reports stall 0.
    stall_ms: float = 0.0
    compute_ms: float = 0.0
    # graceful-degradation accounting (out-of-core serving under faults
    # or deadlines): queries whose shard coverage came back < 1.0, and
    # the mean per-query coverage over the stream. A clean run reports
    # 0 / 1.0.
    degraded_queries: int = 0
    mean_coverage: float = 1.0

    def row(self) -> str:
        return (f"queries={self.n_queries} batches={self.n_batches} "
                f"occupancy={self.mean_batch_occupancy:.2f} "
                f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"qps={self.qps:.0f} "
                f"stall={self.stall_ms:.1f}ms compute={self.compute_ms:.1f}ms "
                f"degraded={self.degraded_queries} "
                f"coverage={self.mean_coverage:.3f} "
                f"(warmup {self.warmup_s:.2f}s)")

    def to_json(self, *, staging: Optional[dict] = None) -> str:
        """One machine-readable JSON line (the ``--stats-json`` record):
        the stats fields plus, when serving out-of-core, the staging
        metrics snapshot — so bench tooling consumes a line instead of
        scraping the `row()` print."""
        rec = dataclasses.asdict(self)
        if staging is not None:
            rec["staging"] = staging
        return json.dumps(rec, sort_keys=True)


class SearchServer:
    """One compiled cascade executable + a micro-batching front door.

    The whole cascade — ADC/pairwise scoring and the step-4 neural
    re-rank — dispatches through `kernels/ops` (`backend=`), so the
    re-ranking decode runs the fused `ops.f_theta` kernel on TPU.
    ``tile_table`` points at a `kernels/tuning.py` JSON artifact from a
    native-TPU autotune sweep; it is applied BEFORE the warmup compile so
    the one warmed executable already uses the tuned tile sizes.

    ``index`` may be a resident `SearchIndex` OR an out-of-core
    `repro.index.ShardedIndexView` — the latter serves through
    `search_sharded` (bit-identical results), with the database staying
    mmap'd on disk and device residency bounded by the view's shard LRU.
    """

    def __init__(self, index, *, micro_batch: int = 32, n_probe: int = 8,
                 n_short_aq: int = 64, n_short_pw: int = 16, topk: int = 10,
                 backend: str = "auto", tile_table=None,
                 prefetch: bool = True, deadline_s: Optional[float] = None,
                 on_shard_error: str = "raise"):
        if tile_table is not None:
            from repro.kernels import tuning
            tuning.load(tile_table)
        self.index = index
        self.micro_batch = micro_batch
        self.out_of_core = hasattr(index, "gather_rows")
        # per-query wall-clock budget: a batch whose budget runs out mid-
        # scan ejects its remaining shards and answers degraded (coverage
        # < 1.0) instead of stalling the queue behind it. Resident serving
        # has no shard loop — both knobs are out-of-core only.
        self.deadline_s = deadline_s
        self.last_coverage: Optional[np.ndarray] = None
        if self.out_of_core:
            self.d = int(index.centroids.shape[1])
            # prefetched staging is the default serving path: shard s+1
            # stages in the background while s is scanned
            search_fn = partial(search_mod.search_sharded,
                                prefetch=prefetch,
                                on_shard_error=on_shard_error,
                                return_coverage=True)
        else:
            self.d = int(index.ivf.centroids.shape[1])
            search_fn = search_mod.search
        self._search = partial(
            search_fn, n_probe=n_probe, n_short_aq=n_short_aq,
            n_short_pw=n_short_pw, topk=topk, cfg=index.cfg, backend=backend)
        t0 = time.perf_counter()
        # warmup runs with NO deadline: it pays the jit compiles, which
        # would otherwise eat any realistic per-query budget and warm
        # nothing
        jax.block_until_ready(
            self._search(index, jnp.zeros((micro_batch, self.d),
                                          jnp.float32)))
        self.warmup_s = time.perf_counter() - t0

    def search_batch(self, q, *, deadline_s: Optional[float] = None):
        """q: (n <= micro_batch, d) -> (ids (n, topk), dists (n, topk)).

        Pads to the fixed micro-batch shape so every call hits the one
        warmed executable (no stray recompiles at serve time).
        ``deadline_s`` overrides the server's per-query budget for this
        batch (out-of-core only — it is a host-side argument, so it
        never triggers a recompile). Per-query coverage of the last
        batch lands in ``self.last_coverage`` (None for resident)."""
        with obs.span("serve/batch"):
            q = np.asarray(q, np.float32)
            n = q.shape[0]
            if n > self.micro_batch:
                raise ValueError(f"batch of {n} exceeds micro_batch="
                                 f"{self.micro_batch}")
            if n < self.micro_batch:
                q = np.concatenate(
                    [q, np.zeros((self.micro_batch - n, self.d),
                                 np.float32)])
        with obs.span("serve/dispatch"):
            # span already fences at exit when tracing; the explicit
            # block stays because serve-time latency accounting needs
            # device-complete timing even with tracing off
            if self.out_of_core:
                dl = deadline_s if deadline_s is not None else self.deadline_s
                kw = {} if dl is None else {"deadline_s": dl}
                ids, dists, cov = self._search(self.index, jnp.asarray(q),
                                               **kw)
                self.last_coverage = np.asarray(cov)[:n]
            else:
                ids, dists = self._search(self.index, jnp.asarray(q))
                self.last_coverage = None
            jax.block_until_ready((ids, dists))
        return np.asarray(ids)[:n], np.asarray(dists)[:n]

    def serve_stream(self, queries, arrival_s, *,
                     max_wait_s: float = 2e-3) -> ServeStats:
        """Drain a pre-timed query stream through micro-batches.

        queries: (n, d); arrival_s: (n,) nondecreasing arrival offsets.
        A batch launches when it is full OR when ``max_wait_s`` has passed
        since its first query arrived — a non-full batch always pays the
        full wait (the server cannot know no more queries are coming), so
        the reported latencies include the real accumulation cost.
        Service time is measured wall clock; queueing is tracked on the
        virtual arrival clock.
        """
        queries = np.asarray(queries, np.float32)
        arrival_s = np.asarray(arrival_s, np.float64)
        n = len(queries)
        occ, batches = [], 0
        clock = 0.0
        service_total = 0.0
        degraded = 0
        cov_sum = 0.0
        stall0 = self._staging_stall_s()
        # p50/p99 come from a *windowed* quantile over the process-wide
        # latency histogram: snapshot before, interpolate over the delta
        # after — per-run percentiles with no stored latency array. The
        # fallback list only exists for the metrics-disabled registry.
        lat_win = _H_LATENCY.collect()
        lat_fallback = [] if not obs.enabled() else None
        i = 0
        while i < n:
            with obs.span("serve/admission"):
                t_open = max(clock, arrival_s[i])  # first query in batch
                deadline = t_open + max_wait_s
                j = i + 1
                while (j < n and j - i < self.micro_batch
                       and arrival_s[j] <= deadline):
                    j += 1
                full = j - i == self.micro_batch
                start = max(t_open, arrival_s[j - 1]) if full else deadline
            dl = None
            if self.deadline_s is not None:
                # remaining per-query budget at dispatch: the oldest query
                # in the batch has already spent its (virtual-clock)
                # queueing delay; the shard scan gets what is left
                dl = max(0.0, self.deadline_s - max(0.0,
                                                    start - arrival_s[i]))
            t0 = time.perf_counter()
            with obs.query_trace("serve_batch", size=j - i):
                self.search_batch(queries[i:j], deadline_s=dl)
            service = time.perf_counter() - t0
            service_total += service
            clock = start + service
            if self.last_coverage is not None:
                d = int(np.count_nonzero(self.last_coverage < 1.0))
                if d:
                    degraded += d
                    _C_DEGRADED.inc(d)
                cov_sum += float(self.last_coverage.sum())
            else:
                cov_sum += j - i
            for k in range(i, j):
                _H_QUEUE.observe(max(0.0, start - arrival_s[k]))
                lat_k = clock - arrival_s[k]
                _H_LATENCY.observe(lat_k)
                if lat_fallback is not None:
                    lat_fallback.append(lat_k)
            occ.append((j - i) / self.micro_batch)
            _G_OCCUPANCY.set((j - i) / self.micro_batch)
            _C_QUERIES.inc(j - i)
            _C_BATCHES.inc()
            batches += 1
            i = j
        span = max(clock - arrival_s[0], 1e-9)
        stall_s = max(0.0, self._staging_stall_s() - stall0)
        _C_STALL.inc(stall_s)
        _C_COMPUTE.inc(max(0.0, service_total - stall_s))
        if lat_fallback is not None:
            p50 = float(np.percentile(np.asarray(lat_fallback), 50))
            p99 = float(np.percentile(np.asarray(lat_fallback), 99))
        else:
            p50 = _H_LATENCY.quantile(0.5, since=lat_win)
            p99 = _H_LATENCY.quantile(0.99, since=lat_win)
        return ServeStats(
            n_queries=n, n_batches=batches, warmup_s=self.warmup_s,
            p50_ms=p50 * 1e3, p99_ms=p99 * 1e3,
            mean_batch_occupancy=float(np.mean(occ)),
            qps=float(n / span),
            stall_ms=stall_s * 1e3,
            compute_ms=max(0.0, service_total - stall_s) * 1e3,
            degraded_queries=degraded,
            mean_coverage=float(cov_sum / max(1, n)))

    def _staging_stall_s(self) -> float:
        """Cumulative time search batches spent blocked on shard staging
        (the view's pool counter; 0 for resident serving)."""
        pool = getattr(self.index, "pool", None)
        return float(pool.stats()["stall_s"]) if pool is not None else 0.0


def synthetic_stream(index, n_queries: int, rate_qps: float, *,
                     noise: float = 0.05, seed: int = 0):
    """Queries near stored vectors (AQ reconstructions + noise) with
    Poisson arrivals at ``rate_qps`` — a self-contained load generator
    for any store (no raw database needed). Accepts a resident
    `SearchIndex` or an out-of-core `ShardedIndexView` (rows are gathered
    from the mmap'd shards; the database never loads)."""
    from repro.core import aq as aq_mod
    rng = np.random.default_rng(seed)
    if hasattr(index, "gather_rows"):
        sids = np.asarray(index.shard_ids)
        pick_s = sids[rng.integers(0, len(sids), size=n_queries)]
        rows = np.array([rng.integers(0, index.store.shard_rows(int(s)))
                         for s in pick_s])
        gids = pick_s * index.shard_size + rows
        codes, assign, _ = index.gather_rows(gids)
        recon = (aq_mod.aq_decode(index.aq_books, jnp.asarray(codes))
                 + index.centroids[jnp.asarray(assign)])
    else:
        pick = rng.integers(0, index.codes.shape[0], size=n_queries)
        recon = (aq_mod.aq_decode(index.aq_books, index.codes[pick])
                 + index.ivf.centroids[index.ivf.assignments[pick]])
    q = np.asarray(recon) + noise * rng.normal(
        size=(n_queries, recon.shape[1])).astype(np.float32)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_queries))
    return q.astype(np.float32), arrivals


def main(argv: Optional[list] = None) -> ServeStats:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--micro-batch", type=int, default=32)
    ap.add_argument("--rate", type=float, default=2000.0, help="offered QPS")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--n-short-aq", type=int, default=64)
    ap.add_argument("--n-short-pw", type=int, default=16)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--tile-table", default=None,
                    help="kernels/tuning.py JSON artifact (autotuned "
                         "per-op tile sizes) to apply before warmup")
    ap.add_argument("--out-of-core", action="store_true",
                    help="serve straight off a ShardedIndexView: shards "
                         "stay mmap'd on disk, device residency bounded "
                         "by --max-resident-shards")
    ap.add_argument("--max-resident-shards", type=int, default=2)
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable background shard prefetch (out-of-core "
                         "only; stages each shard synchronously)")
    ap.add_argument("--allow-partial", action="store_true",
                    help="serve an incomplete store (completed shards "
                         "only; requires --out-of-core or loads a prefix)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query wall-clock budget: eject remaining "
                         "shards when it runs out and answer degraded "
                         "(out-of-core only)")
    ap.add_argument("--on-shard-error", choices=("raise", "skip"),
                    default="raise",
                    help="'skip': serve past failed/quarantined shards "
                         "with coverage < 1.0 instead of crashing "
                         "(out-of-core only)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject storage faults, e.g. "
                         "'p_read_error=0.2,p_corrupt=0.1,seed=7' "
                         "(see repro.index.faults.FaultPlan; "
                         "out-of-core only)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip shard checksum verification at open and "
                         "stage time (out-of-core only)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose a Prometheus /metrics + /metrics.json "
                         "scrape endpoint on this port (0 = ephemeral; "
                         "stays up until process exit)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="append one machine-readable JSON line (stats "
                         "+ staging snapshot) to PATH")
    ap.add_argument("--trace", action="store_true",
                    help="enable per-query stage tracing for the run "
                         "(jit-aware fenced spans; see "
                         "docs/OBSERVABILITY.md for the perturbation "
                         "caveat)")
    args = ap.parse_args(argv)

    global last_metrics_server
    if args.metrics_port is not None:
        last_metrics_server = obs.start_metrics_server(args.metrics_port)
        print(f"[serve_search] metrics at {last_metrics_server.url}/metrics")
    if args.trace:
        obs.enable_tracing()

    from repro.index import IndexStore, ShardedIndexView, parse_chaos
    if args.out_of_core:
        faults = parse_chaos(args.chaos) if args.chaos else None
        index = ShardedIndexView(
            args.store, max_resident_shards=args.max_resident_shards,
            allow_partial=args.allow_partial, verify=not args.no_verify,
            faults=faults)
        print(f"[serve_search] out-of-core: {len(index.shard_ids)} shards "
              f"mmap'd, staging budget {index.budget_bytes / 1e6:.1f} MB")
        if faults is not None:
            print(f"[serve_search] chaos: {args.chaos}")
    else:
        index = IndexStore(args.store).load(
            allow_partial=args.allow_partial)
    server = SearchServer(
        index, micro_batch=args.micro_batch, n_probe=args.n_probe,
        n_short_aq=args.n_short_aq, n_short_pw=args.n_short_pw,
        topk=args.topk, backend=args.backend, tile_table=args.tile_table,
        prefetch=not args.no_prefetch,
        deadline_s=(None if args.deadline_ms is None
                    else args.deadline_ms / 1e3),
        on_shard_error=args.on_shard_error)
    q, arrivals = synthetic_stream(index, args.queries, args.rate)
    stats = server.serve_stream(q, arrivals,
                                max_wait_s=args.max_wait_ms / 1e3)
    if args.trace:
        obs.disable_tracing()
    print(f"[serve_search] {stats.row()}")
    staging = None
    if args.out_of_core:
        ps = index.pool.stats()
        staging = dict(ps, skipped_shards=index.skipped_shards_total,
                       quarantined_shards=len(index.quarantined))
        print(f"[serve_search] staging: staged={ps['staged']} "
              f"device_hits={ps['device_hits']} host_hits={ps['host_hits']} "
              f"prefetch_issued={ps['prefetch_issued']} "
              f"prefetch_hits={ps['prefetch_hits']} "
              f"evictions={ps['evictions']} "
              f"skipped_shards={index.skipped_shards_total}")
    if args.stats_json:
        with open(args.stats_json, "a") as f:
            f.write(stats.to_json(staging=staging) + "\n")
    return stats


# the scrape endpoint from the last `main(--metrics-port ...)` call, so
# in-process harnesses (ci.sh serve smoke, tests) can find its bound
# ephemeral port; the server lives until process exit or `.close()`
last_metrics_server: Optional[obs.MetricsServer] = None


if __name__ == "__main__":
    main()
