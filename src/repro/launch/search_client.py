"""Framed-TCP search client: typed retries, open-loop load, chaos.

The client half of the docs/SERVING.md wire contract. Three jobs:

  - **A correct retry policy.** `SearchClient.search` retries ONLY
    transient conditions: `RESOURCE_EXHAUSTED` (shed) and `UNAVAILABLE`
    (draining) replies — honoring the server's ``retry_after_ms`` hint,
    else capped exponential backoff — plus transport failures where the
    request frame provably never finished sending (the server admits a
    request only after decoding the FULL frame, so a mid-send failure
    cannot have been admitted and a retry cannot duplicate work).
    `INVALID_ARGUMENT` / `NOT_FOUND` / `INTEGRITY_ERROR` / `INTERNAL`
    return immediately: retrying a persistent failure re-runs it
    (the same rule the storage layer applies to corrupt shards). A
    connection that dies AFTER the frame was fully written is returned
    as ``TRANSPORT_ERROR`` without retry — the server may have admitted
    it, and exactly-once answering beats at-least-once guessing.
  - **Open-loop load.** `run_open_loop` fires requests at Poisson
    arrival times regardless of completions (one thread + connection
    per in-flight request), which is what actually exercises shedding:
    a closed loop self-throttles when the server slows down and can
    never drive the queue past the watermark. `run_closed_loop` is the
    self-throttling baseline the benchmark compares against.
  - **Chaos.** With a `FaultPlan` (`repro.index.faults`), each request
    attempt may be perturbed by the four network fault kinds — connection
    drop mid-frame, slow/partial writes, one malformed frame, client
    vanishing before the response — driving the server's transport
    robustness paths deterministically (same seed, same faults).

Every request attempt uses its own TCP connection (connect / send /
recv / close): response demultiplexing is the server's per-connection
write lock, concurrency is threads, and chaos teardown never poisons a
shared socket.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import socket
import threading
import time
from typing import Optional

import numpy as np

from repro import obs
from repro.launch import transport as tp

_C_REQS = obs.counter("client_requests_total",
                      "client requests completed (label status=)")
_C_RETRIES = obs.counter(
    "client_retries_total",
    "request attempts retried (shed/unavailable/mid-send failures)")
_C_CHAOS = obs.counter(
    "client_chaos_injected_total",
    "network faults the chaos client injected (label kind=)")

#: client-side statuses for outcomes that never got a server reply
STATUS_TRANSPORT = "TRANSPORT_ERROR"     # conn died after full send
STATUS_VANISHED = "CLIENT_VANISHED"      # chaos: left before the reply


@dataclasses.dataclass
class SearchResult:
    """One request's outcome. ``status`` is a `tp.STATUS_*` value or a
    client-side `STATUS_*`; ids/dists/coverage are set iff OK."""
    status: str
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    coverage: Optional[np.ndarray] = None
    attempts: int = 1
    retries: int = 0
    latency_s: float = 0.0
    retry_after_ms: Optional[float] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status == tp.STATUS_OK


class _MidSendFailure(Exception):
    """The connection died before the request frame finished sending:
    the server cannot have admitted the request, so a retry is safe."""


class SearchClient:
    """Client for one `SearchFrontDoor` endpoint (thread-safe: every
    attempt opens its own connection; shared state is counters)."""

    def __init__(self, host: str, port: int, *, timeout_s: float = 30.0,
                 max_retries: int = 5, backoff_base_s: float = 0.01,
                 backoff_cap_s: float = 0.5,
                 faults=None):
        self.host, self.port = host, int(port)
        self.timeout_s = float(timeout_s)
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.faults = faults
        self._id_lock = threading.Lock()
        self._next_id = 0

    def _req_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id

    def _connect(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    # -- chaos mechanics (decisions come from the FaultPlan oracle) ----------

    def _chaos_count(self, kind: str) -> None:
        _C_CHAOS.labels(kind=kind).inc()

    def _send_maybe_chaotic(self, sock: socket.socket, frame: bytes,
                            key, attempt: int) -> None:
        """Write the request frame, possibly perturbed: dropped partway
        (raises `_MidSendFailure` — the retryable kind) or dribbled out
        in small chunks (the server must reassemble)."""
        fp = self.faults
        if fp is not None and fp.conn_drop(key, attempt):
            self._chaos_count("conn_drop")
            cut = max(1, len(frame) // 2)
            try:
                sock.sendall(frame[:cut])
            finally:
                sock.close()
            raise _MidSendFailure(f"injected connection drop after "
                                  f"{cut}/{len(frame)} bytes")
        if fp is not None and fp.slow_write(key, attempt):
            self._chaos_count("slow_write")
            step = max(1, fp.slow_write_chunk)
            for i in range(0, len(frame), step):
                sock.sendall(frame[i:i + step])
                time.sleep(fp.slow_write_s)
            return
        try:
            sock.sendall(frame)
        except (ConnectionError, OSError) as e:
            # sendall gives no byte count on failure; a frame that fits
            # the socket buffer is accepted atomically, so a raising
            # sendall means the kernel rejected the tail mid-write —
            # the frame did not fully reach the server
            raise _MidSendFailure(str(e)) from e

    def _send_malformed(self, key) -> None:
        """One garbage frame on its own connection (a valid length
        prefix around undecodable payload): the server must answer
        `INVALID_ARGUMENT` and close without crashing."""
        self._chaos_count("malformed")
        sock = self._connect()
        try:
            garbage = b"\xff\x00garbage-not-json" * 3
            sock.sendall(tp._U32.pack(len(garbage)) + garbage)
            try:
                reply = tp.recv_frame(sock)       # best-effort: the typed
            except tp.FrameError:                 # error, or the close
                reply = None
            if reply is not None:
                assert reply[0].get("status") == tp.STATUS_INVALID
        finally:
            sock.close()

    # -- the request path ----------------------------------------------------

    def ping(self) -> dict:
        sock = self._connect()
        try:
            tp.send_frame(sock, {"id": self._req_id(), "op": "ping"})
            header, _ = tp.recv_frame(sock)
            return header
        finally:
            sock.close()

    def search(self, q, *, tenant: str = "default",
               deadline_ms: Optional[float] = None,
               req_key=None) -> SearchResult:
        """One search request (``q``: (n, d) float32) with typed
        retries. ``req_key`` seeds the chaos oracle (defaults to the
        request id, so two clients with the same FaultPlan seed AND the
        same keys inject identical faults)."""
        q = np.ascontiguousarray(np.asarray(q, np.float32))
        if q.ndim == 1:
            q = q[None, :]
        rid = self._req_id()
        key = rid if req_key is None else req_key
        header = {"id": rid, "op": "search", "tenant": tenant,
                  "n": int(q.shape[0]), "d": int(q.shape[1])}
        if deadline_ms is not None:
            header["deadline_ms"] = float(deadline_ms)
        frame = tp.encode_frame(header, q.astype("<f4").tobytes())
        if self.faults is not None and self.faults.malformed(key):
            self._send_malformed(key)
        t0 = time.perf_counter()
        retries = 0
        hint: Optional[float] = None
        last: Optional[SearchResult] = None
        for attempt in range(self.max_retries + 1):
            if attempt > 0:
                _C_RETRIES.inc()
                retries += 1
                backoff = min(self.backoff_cap_s,
                              self.backoff_base_s * (2 ** (attempt - 1)))
                if hint is not None:
                    backoff = min(self.backoff_cap_s, hint / 1e3)
                time.sleep(backoff)
            try:
                res = self._attempt(frame, key, attempt)
            except _MidSendFailure:
                continue                          # provably not admitted
            except OSError as e:
                # connect refused/timed out: nothing reached the server,
                # retrying is safe (recv-side failures never raise OSError
                # here — they return TRANSPORT_ERROR results)
                last = SearchResult(status=STATUS_TRANSPORT, error=str(e))
                continue
            if res.status in tp.RETRYABLE_STATUSES:
                hint = res.retry_after_ms
                last = res
                continue
            res.attempts, res.retries = attempt + 1, retries
            res.latency_s = time.perf_counter() - t0
            _C_REQS.labels(status=res.status).inc()
            return res
        # retries exhausted: hand back the last transient rejection
        out = last if last is not None else SearchResult(
            status=STATUS_TRANSPORT, error="mid-send failures exhausted "
                                           "retry budget")
        out.attempts, out.retries = self.max_retries + 1, retries
        out.latency_s = time.perf_counter() - t0
        _C_REQS.labels(status=out.status).inc()
        return out

    def _attempt(self, frame: bytes, key, attempt: int) -> SearchResult:
        sock = self._connect()
        vanish = (self.faults is not None
                  and self.faults.client_vanish(key, attempt))
        try:
            self._send_maybe_chaotic(sock, frame, key, attempt)
            if vanish:
                # the full request went out; leave before the answer.
                # NO retry: the server admitted it and will answer it
                # exactly once (into a dead socket).
                self._chaos_count("client_vanish")
                return SearchResult(status=STATUS_VANISHED)
            try:
                reply = tp.recv_frame(sock)
            except tp.FrameError as e:
                return SearchResult(status=STATUS_TRANSPORT, error=str(e))
            if reply is None:
                return SearchResult(status=STATUS_TRANSPORT,
                                    error="connection closed before reply")
            header, body = reply
            return self._parse_reply(header, body)
        finally:
            sock.close()

    @staticmethod
    def _parse_reply(header: dict, body: bytes) -> SearchResult:
        status = header.get("status", tp.STATUS_INTERNAL)
        if status != tp.STATUS_OK:
            ra = header.get("retry_after_ms")
            return SearchResult(status=status,
                                retry_after_ms=(float(ra) if ra is not None
                                                else None),
                                error=header.get("error"))
        n, topk = int(header["n"]), int(header["topk"])
        ids = np.frombuffer(body, "<i4", count=n * topk).reshape(n, topk)
        off = n * topk * 4
        dists = np.frombuffer(body, "<f4", count=n * topk,
                              offset=off).reshape(n, topk)
        cov = None
        if header.get("has_coverage"):
            cov = np.frombuffer(body, "<f4", count=n,
                                offset=off + n * topk * 4)
        return SearchResult(status=tp.STATUS_OK, ids=ids.copy(),
                            dists=dists.copy(),
                            coverage=None if cov is None else cov.copy())


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoadStats:
    """Outcome of one load run (`run_open_loop` / `run_closed_loop`)."""
    mode: str                      # "open" | "closed"
    n_requests: int
    n_ok: int
    n_shed: int                    # transient rejections seen (pre-retry)
    n_failed: int                  # non-OK final outcomes
    n_retries: int
    offered_qps: float
    achieved_qps: float            # OK responses / wall-clock
    p50_ms: float
    p99_ms: float
    mean_coverage: float

    def row(self) -> str:
        return (f"mode={self.mode} requests={self.n_requests} "
                f"ok={self.n_ok} shed={self.n_shed} failed={self.n_failed} "
                f"retries={self.n_retries} offered={self.offered_qps:.0f}qps "
                f"achieved={self.achieved_qps:.0f}qps "
                f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"coverage={self.mean_coverage:.3f}")

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


def _summarize(mode: str, results, span_s: float,
               offered_qps: float) -> LoadStats:
    """qps figures count query ROWS (not requests), so closed- and
    open-loop rows in BENCH_search.json are comparable to the
    in-process serving rows whatever the request batch size."""
    ok = [r for r in results if r.ok]
    ok_rows = sum(int(r.ids.shape[0]) for r in ok)
    lats = np.asarray([r.latency_s for r in ok]) if ok else np.zeros(1)
    covs = [float(r.coverage.mean()) for r in ok if r.coverage is not None]
    return LoadStats(
        mode=mode, n_requests=len(results), n_ok=len(ok),
        n_shed=sum(1 for r in results
                   if r.status in tp.RETRYABLE_STATUSES or r.retries),
        n_failed=sum(1 for r in results if not r.ok),
        n_retries=sum(r.retries for r in results),
        offered_qps=offered_qps,
        achieved_qps=ok_rows / max(span_s, 1e-9),
        p50_ms=float(np.percentile(lats, 50)) * 1e3,
        p99_ms=float(np.percentile(lats, 99)) * 1e3,
        mean_coverage=float(np.mean(covs)) if covs else 1.0)


def run_closed_loop(client: SearchClient, queries, *,
                    tenant: str = "default",
                    deadline_ms: Optional[float] = None,
                    batch: int = 1) -> LoadStats:
    """Back-to-back requests, one in flight: the classic self-throttling
    load — throughput is gated by (latency x 1), the server never sees a
    queue, and shedding never triggers. The baseline the open-loop rows
    in BENCH_search.json are compared against."""
    queries = np.asarray(queries, np.float32)
    results = []
    t0 = time.perf_counter()
    for i in range(0, len(queries), batch):
        results.append(client.search(queries[i:i + batch], tenant=tenant,
                                     deadline_ms=deadline_ms, req_key=i))
    span = time.perf_counter() - t0
    stats = _summarize("closed", results, span, offered_qps=0.0)
    stats.offered_qps = stats.achieved_qps     # closed loop: self-paced
    return stats


def run_open_loop(client: SearchClient, queries, rate_qps: float, *,
                  tenant: str = "default",
                  deadline_ms: Optional[float] = None,
                  batch: int = 1, seed: int = 0,
                  max_in_flight: int = 64) -> LoadStats:
    """Poisson arrivals at ``rate_qps`` (per REQUEST), fired regardless
    of completions — arrivals do not wait for responses, so when the
    server falls behind the queue genuinely builds and the watermark /
    quota / retry machinery actually runs. ``max_in_flight`` bounds
    client-side threads (a full client is itself backpressure — counted
    arrivals just coalesce onto the next free slot)."""
    queries = np.asarray(queries, np.float32)
    rng = np.random.default_rng(seed)
    n_reqs = (len(queries) + batch - 1) // batch
    gaps = rng.exponential(1.0 / rate_qps, size=n_reqs)
    arrivals = np.cumsum(gaps)
    results = [None] * n_reqs
    sem = threading.Semaphore(max_in_flight)

    def fire(i, lo):
        try:
            results[i] = client.search(
                queries[lo:lo + batch], tenant=tenant,
                deadline_ms=deadline_ms, req_key=i)
        finally:
            sem.release()

    threads = []
    t0 = time.perf_counter()
    for i in range(n_reqs):
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        sem.acquire()
        th = threading.Thread(target=fire, args=(i, i * batch), daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=client.timeout_s)
    span = time.perf_counter() - t0
    results = [r if r is not None
               else SearchResult(status=STATUS_TRANSPORT, error="no result")
               for r in results]
    return _summarize("open", results, span,
                      offered_qps=rate_qps * batch)


def main(argv: Optional[list] = None) -> LoadStats:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--mode", choices=("open", "closed"), default="closed")
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=1,
                    help="query rows per request")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="offered request rate (open loop)")
    ap.add_argument("--tenant", default="default")
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--max-retries", type=int, default=5)
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="network fault spec, e.g. 'p_conn_drop=0.2,"
                         "p_malformed=0.05,seed=7' (repro.index.faults)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats-json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    faults = None
    if args.chaos:
        from repro.index.faults import parse_chaos
        faults = parse_chaos(args.chaos)
    client = SearchClient(args.host, args.port,
                          max_retries=args.max_retries, faults=faults)
    pong = client.ping()
    tinfo = pong["tenants"].get(args.tenant)
    if tinfo is None:
        raise SystemExit(f"tenant {args.tenant!r} not served "
                         f"(have: {list(pong['tenants'])})")
    rng = np.random.default_rng(args.seed)
    q = rng.normal(size=(args.queries, tinfo["d"])).astype(np.float32)
    if args.mode == "open":
        stats = run_open_loop(client, q, args.rate, tenant=args.tenant,
                              deadline_ms=args.deadline_ms,
                              batch=args.batch, seed=args.seed)
    else:
        stats = run_closed_loop(client, q, tenant=args.tenant,
                                deadline_ms=args.deadline_ms,
                                batch=args.batch)
    print(f"[search_client] {stats.row()}")
    if args.stats_json:
        with open(args.stats_json, "a") as f:
            f.write(stats.to_json() + "\n")
    return stats


if __name__ == "__main__":
    main()
