"""Version bridges for jax APIs that moved between 0.4.x and 0.5+.

The repo targets the modern spellings (`jax.shard_map` with ``check_vma``
and ``axis_names``, `jax.set_mesh`); the pinned jax (0.4.37) only ships
`jax.experimental.shard_map.shard_map` (``check_rep`` / ``auto``) and uses
the Mesh object itself as the ambient-mesh context manager. Every caller
in this codebase goes through these two wrappers instead of touching the
jax namespace directly.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    """`jax.shard_map` signature, runnable on both old and new jax.

    ``axis_names`` (new API: the axes that go manual) maps onto the old
    API's ``auto`` (the complementary set that stays under GSPMD).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return sm(f, **kw)
    from jax.experimental.shard_map import shard_map as esm
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return esm(f, **kw)


def use_mesh(mesh):
    """Context manager equivalent of `jax.set_mesh(mesh)` on any jax."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh  # 0.4.x: Mesh is itself the ambient-mesh context manager
