"""Reusable distributed collectives (DESIGN.md §6).

- distributed_topk: per-shard top-k + all-gather + merge (billion-scale
  search; also used by core/search.make_distributed_adc).
- sp_decode_merge: sequence-parallel decode attention combine — merges
  per-shard partial softmax statistics (max / denominator / weighted sum).
- compressed_psum_pods: re-exported from core/grad_compress.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.grad_compress import compressed_psum_pods  # noqa: F401


def merge_topk(vals_local, gids_local, k: int, axis: str):
    """Inside shard_map: per-shard (Q, k) top-k lists (values + GLOBAL
    ids) -> merged global (Q, k) ids+scores. The entry point for callers
    that already shortlist locally (e.g. the fused `ops.adc_topk` kernel,
    whose per-shard scores never leave VMEM).

    Wire cost: 2 * Q * k * (bytes) instead of gathering Q * N scores."""
    s_all = jax.lax.all_gather(vals_local, axis, axis=1, tiled=True)
    g_all = jax.lax.all_gather(gids_local, axis, axis=1, tiled=True)
    s2, i2 = jax.lax.top_k(s_all, k)
    return jnp.take_along_axis(g_all, i2, axis=1), s2


def distributed_topk(scores_local, base_index, k: int, axis: str):
    """Inside shard_map: local (Q, N_loc) scores -> global (Q, k)
    ids+scores (the materialized-scores form of `merge_topk`)."""
    s, i = jax.lax.top_k(scores_local, k)
    return merge_topk(s, base_index + i, k, axis)


def sp_decode_merge(m_loc, denom_loc, acc_loc, axis: str):
    """Merge flash-decoding partials across a sequence-sharded KV cache.

    m_loc: (...,) local max; denom_loc: (...,) local sum exp(s - m_loc);
    acc_loc: (..., D) local sum p*V. Returns the exact global attention
    output. Wire: 2 scalars + one D-vector per head — independent of T."""
    m_glob = jax.lax.pmax(m_loc, axis)
    corr = jnp.exp(m_loc - m_glob)
    denom = jax.lax.psum(denom_loc * corr, axis)
    acc = jax.lax.psum(acc_loc * corr[..., None], axis)
    return acc / jnp.maximum(denom, 1e-30)[..., None]
