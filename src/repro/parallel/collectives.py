"""Reusable distributed collectives (DESIGN.md §6).

- distributed_topk: per-shard top-k + all-gather + merge (billion-scale
  search; also used by core/search.make_distributed_adc).
- merge_topk_ranked: the same merge for SEQUENTIAL shard scans (the
  out-of-core `core/search.search_sharded` running merge), with explicit
  candidate ranks so tie-breaking matches one big `lax.top_k`.
- sp_decode_merge: sequence-parallel decode attention combine — merges
  per-shard partial softmax statistics (max / denominator / weighted sum).
- compressed_psum_pods: re-exported from core/grad_compress.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


from repro.core.grad_compress import compressed_psum_pods  # noqa: F401


def topk_lists(vals, ids, k: int):
    """Concatenated per-shard shortlists (..., L) -> merged (..., k)
    (values desc, ids carried along). Ties resolve lowest-position-first
    in the concatenation order (the `lax.top_k` contract) — the shared
    merge body of the shard_map collective and the out-of-core running
    merge."""
    s, i = jax.lax.top_k(vals, k)
    return s, jnp.take_along_axis(ids, i, axis=-1)


def merge_topk(vals_local, gids_local, k: int, axis: str):
    """Inside shard_map: per-shard (Q, k) top-k lists (values + GLOBAL
    ids) -> merged global (Q, k) ids+scores. The entry point for callers
    that already shortlist locally (e.g. the fused `ops.adc_topk` kernel,
    whose per-shard scores never leave VMEM).

    Wire cost: 2 * Q * k * (bytes) instead of gathering Q * N scores."""
    s_all = jax.lax.all_gather(vals_local, axis, axis=1, tiled=True)
    g_all = jax.lax.all_gather(gids_local, axis, axis=1, tiled=True)
    s2, g2 = topk_lists(s_all, g_all, k)
    return g2, s2


@partial(jax.jit, static_argnames=("k",))
def merge_topk_ranked(vals, pos, gids, k: int):
    """Rank-aware shortlist merge: top-k by (value desc, pos asc).

    The sequential counterpart of `merge_topk` for the out-of-core scan
    (`core/search.search_sharded`), where per-shard lists arrive one at a
    time instead of via all_gather. ``pos`` is each candidate's position
    in the resident `search()` candidate ordering (probe-rank major,
    within-bucket rank minor), so ties — including the all--inf padding
    slots a small probe produces — resolve exactly as one `lax.top_k`
    over the full resident candidate array would: the inputs are sorted
    by ``pos`` (stable) before `topk_lists`, whose tie-break is then
    lowest-pos-first by construction.

    Because the merge key is the (value, pos) PAIR and pos is a global
    coordinate independent of which shard contributed the entry or when,
    the fold is order-independent: folding shards in any order — or
    skipping shards that contribute only (-inf, sentinel) entries, as
    the probe-aware scheduler does — yields the same final top-k. The
    scan-order-independence argument is written out in docs/KERNELS.md.

    vals/pos/gids: (Q, L) with k <= L -> (Q, k) each, value-descending.
    """
    order = jnp.argsort(pos, axis=-1)                  # stable in jnp
    v = jnp.take_along_axis(vals, order, axis=-1)
    p = jnp.take_along_axis(pos, order, axis=-1)
    g = jnp.take_along_axis(gids, order, axis=-1)
    s, i = jax.lax.top_k(v, k)
    return (s, jnp.take_along_axis(p, i, axis=-1),
            jnp.take_along_axis(g, i, axis=-1))


def distributed_topk(scores_local, base_index, k: int, axis: str):
    """Inside shard_map: local (Q, N_loc) scores -> global (Q, k)
    ids+scores (the materialized-scores form of `merge_topk`)."""
    s, i = jax.lax.top_k(scores_local, k)
    return merge_topk(s, base_index + i, k, axis)


def sp_decode_merge(m_loc, denom_loc, acc_loc, axis: str):
    """Merge flash-decoding partials across a sequence-sharded KV cache.

    m_loc: (...,) local max; denom_loc: (...,) local sum exp(s - m_loc);
    acc_loc: (..., D) local sum p*V. Returns the exact global attention
    output. Wire: 2 scalars + one D-vector per head — independent of T."""
    m_glob = jax.lax.pmax(m_loc, axis)
    corr = jnp.exp(m_loc - m_glob)
    denom = jax.lax.psum(denom_loc * corr, axis)
    acc = jax.lax.psum(acc_loc * corr[..., None], axis)
    return acc / jnp.maximum(denom, 1e-30)[..., None]
