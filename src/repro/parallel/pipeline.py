"""GPipe-style pipeline parallelism over the `pod` axis (DESIGN.md §6).

The default multi-pod strategy in this framework is DP-over-pods (gradient
exchange only crosses DCN). This module provides the alternative: the layer
stack is split into one stage per pod and microbatches stream through via
`ppermute`, so *activations* cross DCN instead of gradients — preferable
when params/pod is large relative to the per-step gradient volume
(activation bytes/microbatch << 2 x param bytes).

Implementation: full-manual shard_map over `pod`; each stage holds its
layer slice (params sharded over `pod` on the layer axis); the schedule is
the classic (M + S - 1)-tick loop with bubble fraction (S-1)/(M+S-1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel import compat


def pipelined_forward(layer_body: Callable, stage_params, x_microbatches,
                      *, mesh, axis: str = "pod"):
    """Run x through S pipeline stages over `axis`.

    layer_body(params_slice, x) -> x : applies ONE stage's layer stack.
    stage_params: pytree with leading dim S (sharded over `axis`).
    x_microbatches: (M, mb, ...) microbatched inputs (replicated).
    Returns (M, mb, ...) outputs (replicated; valid after the drain).
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    ticks = M + S - 1

    def stage_fn(params_sl, xs):
        params_sl = jax.tree.map(lambda a: a[0], params_sl)  # my slice
        stage = lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            buf, outs = carry
            # feed: stage 0 injects microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = xs[mb_idx]
            cur = jnp.where(stage == 0, inject, buf)
            cur = layer_body(params_sl, cur)
            # drain: last stage writes its result at slot t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            write = jnp.logical_and(stage == S - 1, t >= S - 1)
            outs = lax.cond(
                write,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, cur, out_idx, 0),
                lambda o: o, outs)
            # rotate activations one stage forward
            nxt = lax.ppermute(cur, axis, perm)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (buf, outs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outs = lax.psum(jnp.where(stage == S - 1, outs, 0.0), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return compat.shard_map(stage_fn, mesh=mesh,
                            in_specs=(pspec, P()), out_specs=P(),
                            check_vma=False)(stage_params, x_microbatches)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
