"""Logical-axis -> mesh-axis resolution with divisibility checking.

Every ParamSpec / cache spec / batch spec carries logical axis names; this
module turns them into PartitionSpecs for a concrete mesh. A rule is dropped
(dim left replicated) when the mesh axis size does not divide the dim — the
safe default for e.g. 56 attention heads over a 16-way model axis (the
*activation* constraints still shard heads; GSPMD pads those internally).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.common import ParamSpec, ShardCtx, is_spec

AxisVal = Union[None, str, Tuple[str, ...]]


def data_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig
               ) -> Tuple[Dict[str, AxisVal], ShardCtx]:
    """Resolve the arch's parallel policy against a mesh + input shape."""
    pol = arch.parallel
    daxes = data_axes_for(mesh)
    if pol.dp_only:
        # no tensor parallelism: the model axis joins data parallelism
        daxes = daxes + ("model",)
    dp = int(np.prod([mesh.shape[a] for a in daxes]))
    batch_sharded = shape.global_batch % dp == 0 and shape.global_batch >= dp
    sp_decode = (shape.kind == "decode") and not batch_sharded

    batch_axes = daxes
    if pol.grad_compress_in_graph and "pod" in mesh.axis_names:
        # the pod axis goes manual (shard_map) in train_step: batch enters
        # sharded over pod only; inside, activations shard over data alone
        batch_axes = ("pod",)
        daxes = tuple(a for a in daxes if a != "pod")

    tp_axis = None if pol.dp_only else "model"
    fsdp_axes = ("data", "model") if (pol.fsdp and pol.dp_only) else "data"
    rules: Dict[str, AxisVal] = {
        "mlp": tp_axis, "heads": tp_axis, "kv_heads": tp_axis,
        "vocab": tp_axis, "experts": tp_axis, "ssm_inner": tp_axis,
        "ssm_heads": tp_axis,
        "layers": None, "groups": None, "seq": None,
        "embed": fsdp_axes if pol.fsdp else None,
        "moe_ffn": "data" if pol.moe_2d else None,
        "batch": batch_axes if batch_sharded else None,
        "cache_seq": daxes if sp_decode else None,
    }
    ctx = ShardCtx(data_axes=daxes, model_axis=tp_axis,
                   batch_sharded=batch_sharded,
                   cache_seq_sharded=sp_decode, active=True,
                   moe_ffn_axis="data" if pol.moe_2d else None,
                   axis_sizes={a: int(mesh.shape[a])
                               for a in mesh.axis_names})
    return rules, ctx


def _resolve(spec: ParamSpec, rules: Dict[str, AxisVal], mesh: Mesh) -> P:
    parts = []
    used = set()
    for dim, ax in zip(spec.shape, spec.axes):
        r = rules.get(ax) if ax is not None else None
        if r is None:
            parts.append(None)
            continue
        axes = (r,) if isinstance(r, str) else tuple(r)
        if any(a in used for a in axes):
            parts.append(None)         # an axis may appear once per spec
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            parts.append(None)         # replicate non-divisible dims
            continue
        used.update(axes)
        parts.append(r)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def pspec_tree(spec_tree, rules: Dict[str, AxisVal], mesh: Mesh):
    return jax.tree.map(lambda s: _resolve(s, rules, mesh), spec_tree,
                        is_leaf=is_spec)


def sharding_tree(spec_tree, rules: Dict[str, AxisVal], mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, _resolve(s, rules, mesh)),
                        spec_tree, is_leaf=is_spec)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def bytes_per_device(spec_tree, rules, mesh: Mesh) -> int:
    """Static per-device byte footprint of a spec tree under the rules."""
    total = 0
    for s in jax.tree.leaves(spec_tree, is_leaf=is_spec):
        ps = _resolve(s, rules, mesh)
        shard_elems = int(np.prod(s.shape))
        for dim, part in zip(s.shape, tuple(ps) + (None,) * 8):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else tuple(part)
            shard_elems //= int(np.prod([mesh.shape[a] for a in axes]))
        total += shard_elems * jax.dtypes.canonicalize_dtype(s.dtype).itemsize
    return total
