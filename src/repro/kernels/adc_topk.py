"""Fused ADC scoring + running local top-k (ROADMAP: 'scores never leave
VMEM before shortlisting').

The shared-codes ADC scan (`adc_onehot.adc_scores`) writes the full (Q, N)
score matrix to HBM and then runs `lax.top_k` over it — at billion scale
the score matrix is far larger than the shortlist that survives it. This
kernel fuses the reduction into the scan: the grid is (Q_tiles, N_tiles)
with N innermost (sequential on TPU), each step computes one (TQ, TN)
score tile on the MXU exactly as `adc_scores` does, and merges it into a
running (TQ, k) top-k held in a revisited output block. Only 2*Q*k values
ever reach HBM — the shape the distributed per-shard search path ships
over the wire anyway (`collectives.distributed_topk`).

Selection is k sequential masked argmaxes (`beam_topk.masked_topk`, the
shared selection primitive of every fused-shortlist kernel — no sort, no
gather: the winning global index is recovered by a masked sum). Because
the running list keeps equal-valued entries in ascending-index order and
earlier tiles precede later ones in the merge candidates, ties resolve
lowest-index-first — bit-identical to `lax.top_k` over the full matrix.

Codes may be packed uint8 (K <= 256): the packed bytes are what crosses
HBM -> VMEM, widened in-kernel before the iota comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc_onehot import score_tile
from repro.kernels.beam_topk import masked_topk


def _kernel(*refs, k: int, N: int, tile_n: int, has_norms: bool):
    if has_norms:
        codes_ref, lut_ref, norms_ref, v_ref, i_ref = refs
    else:
        codes_ref, lut_ref, v_ref, i_ref = refs
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        v_ref[...] = jnp.full(v_ref.shape, -jnp.inf, jnp.float32)
        i_ref[...] = jnp.zeros(i_ref.shape, jnp.int32)

    # one (TQ, TN) score tile through the SAME body as adc_onehot's scan
    # (shared helper: fused == unfused stays bitwise by construction)
    s = score_tile(codes_ref[...], lut_ref[...])
    if has_norms:
        s = 2.0 * s - norms_ref[...]                      # (1, TN) broadcast
    gidx = ni * tile_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(gidx < N, s, -jnp.inf)                  # padded rows out

    # -- merge into the running top-k (k masked argmaxes on the VPU) --------
    cand_v = jnp.concatenate([v_ref[...], s], axis=1)     # (TQ, k + TN)
    cand_i = jnp.concatenate([i_ref[...], gidx], axis=1)
    vals, ids = masked_topk(cand_v, k, idx=cand_i)
    v_ref[...] = vals
    i_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n",
                                             "interpret"))
def adc_topk(codes, lut, k: int, *, norms=None, tile_q: int = 64,
             tile_n: int = 256, interpret: bool = True):
    """codes: (N, M) int (uint8 or int32); lut: (Q, M, K); k <= N ->
    (vals (Q, k) f32 descending, ids (Q, k) int32). With ``norms`` the
    merged values are the score surrogate ``2 * ip - norms``."""
    N, M = codes.shape
    Q, _, K = lut.shape
    tile_q = min(tile_q, Q)
    tile_n = min(tile_n, N)
    pq, pn = (-Q) % tile_q, (-N) % tile_n
    if pq:
        lut = jnp.pad(lut, ((0, pq), (0, 0), (0, 0)))
    if pn:
        codes = jnp.pad(codes, ((0, pn), (0, 0)))
    if codes.dtype != jnp.uint8:
        codes = codes.astype(jnp.int32)
    lut_flat = lut.reshape(Q + pq, M * K)
    ins = [codes, lut_flat]
    in_specs = [
        pl.BlockSpec((tile_n, M), lambda qi, ni: (ni, 0)),
        pl.BlockSpec((tile_q, M * K), lambda qi, ni: (qi, 0)),
    ]
    if norms is not None:
        nrm = norms.reshape(1, N).astype(jnp.float32)
        if pn:
            nrm = jnp.pad(nrm, ((0, 0), (0, pn)))
        ins.append(nrm)
        in_specs.append(pl.BlockSpec((1, tile_n), lambda qi, ni: (0, ni)))
    vals, ids = pl.pallas_call(
        functools.partial(_kernel, k=k, N=N, tile_n=tile_n,
                          has_norms=norms is not None),
        grid=((Q + pq) // tile_q, (N + pn) // tile_n),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((tile_q, k), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q + pq, k), jnp.float32),
            jax.ShapeDtypeStruct((Q + pq, k), jnp.int32),
        ],
        interpret=interpret,
    )(*ins)
    return vals[:Q], ids[:Q]
