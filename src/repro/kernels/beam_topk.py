"""Fused beam-search selection kernels (ROADMAP: 'the (N, B, A, d)
expansion tensor also stops round-tripping HBM before top-k').

Two pieces live here:

`masked_topk` is the SHARED kernel-body selection primitive — the running
masked-argmax idiom that `kernels/adc_topk.py` and `kernels/l2_topk.py`
each used to inline, factored out so the fused beam ops reuse one
implementation. It reproduces `lax.top_k` exactly, including the case the
inlined loops never had to face: when the surviving candidates tie at
-inf (a beam whose hypotheses are all still unpopulated), `lax.top_k`
emits the remaining positions in ascending order, whereas a bare
argmax-over-masked loop would return position 0 repeatedly. A per-row
`taken` mask (instead of destructive -inf masking) makes the tie-break
bit-identical in every case.

`preselect_topk` is the fused pre-selector for the L_s >= 1 encode path
(paper Eq. 6): the g_phi candidate network evaluated on ALL K codewords,
the squared distance to the step residual, and the top-A selection in ONE
`pallas_call`. The grid is (N_tiles, L_s) with L_s innermost (sequential
on TPU); the (tile, K, 128) activation lives in VMEM scratch across the
L_s iterations and the (tile, K) score block is reduced in place — neither
the (N, B, K, d) candidate tensor nor the (N, B, K) score matrix ever
reaches HBM. Only the selected (N, A) indices and distances do.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import stepnet


def masked_topk(neg, k: int, idx=None):
    """Select the k largest entries per row of ``neg`` (R, C), bit-identical
    to ``lax.top_k(neg, k)`` — values AND tie-break (lowest position first,
    including ties at -inf).

    Returns (vals (R, k) descending, ids (R, k) int32). ``ids`` are the
    column positions, or ``idx[r, pos]`` when an ``idx`` (R, C) int32 map
    is given (the adc_topk running-merge shape, where positions carry
    global database ids). Static unroll over k — kernel-body safe.
    """
    R, C = neg.shape
    cio = jax.lax.broadcasted_iota(jnp.int32, (R, C), 1)
    taken = jnp.zeros((R, C), jnp.bool_)
    vals, ids = [], []
    for _ in range(k):                                    # static unroll
        masked = jnp.where(taken, -jnp.inf, neg)
        vmax = jnp.max(masked, axis=1)
        # eligible = not-yet-taken entries achieving the max; argmax of the
        # bool mask = first True = lowest position (the lax.top_k order,
        # correct even when every survivor is -inf)
        elig = jnp.logical_and(jnp.logical_not(taken),
                               masked == vmax[:, None])
        arg = jnp.argmax(elig, axis=1).astype(jnp.int32)
        hit = cio == arg[:, None]
        vals.append(vmax)
        ids.append(arg if idx is None
                   else jnp.sum(jnp.where(hit, idx, 0), axis=1))
        taken = jnp.logical_or(taken, hit)
    return jnp.stack(vals, axis=1), jnp.stack(ids, axis=1)


# ---------------------------------------------------------------------------
# Fused g_phi pre-selection: candidate network + L2 + top-A (Eq. 6, L_s >= 1)
# ---------------------------------------------------------------------------


def _preselect_kernel(*refs, Ls: int, A: int, has_proj: bool):
    """All-K candidate evaluation + in-VMEM top-A. The candidate 'gather'
    is the identity (every row scores the full codebook), so no index
    tensor crosses HBM at all; v_ref (VMEM scratch) carries the (tile, K,
    de) activations across the sequential L_s iterations."""
    if has_proj:
        (cbk_ref, xh_ref, r_ref, cw_ref, cb_ref, w1_ref, w2_ref, ip_ref,
         op_ref, idx_ref, d2_ref, v_ref) = refs
    else:
        (cbk_ref, xh_ref, r_ref, cw_ref, cb_ref, w1_ref, w2_ref,
         idx_ref, d2_ref, v_ref) = refs
    l = pl.program_id(1)
    tn, K, de = v_ref.shape
    d = cbk_ref.shape[1]

    @pl.when(l == 0)
    def _concat_in():                                     # Eq. 10-11
        c = cbk_ref[...]                                  # (K, d)
        # the codebook is shared across rows: in-project once per tile,
        # then broadcast (same bits as per-row projection — same matmul)
        c_emb = c @ ip_ref[...] if has_proj else c        # (K, de)
        dec = c_emb.shape[-1]
        ce = jnp.broadcast_to(c_emb[None], (tn, K, dec)).reshape(tn * K, dec)
        xb = jnp.broadcast_to(xh_ref[...][:, None, :],
                              (tn, K, d)).reshape(tn * K, d)
        v = stepnet.concat_in(ce, xb, cw_ref[...], cb_ref[...])
        v_ref[...] = v.reshape(tn, K, de)

    v = v_ref[...].reshape(tn * K, de)                    # Eq. 12
    v = stepnet.residual_block(v, w1_ref[0], w2_ref[0])
    v_ref[...] = v.reshape(tn, K, de)

    @pl.when(l == Ls - 1)
    def _score_select():                                  # Eq. 13 + Eq. 6
        vL = v_ref[...].reshape(tn * K, de)
        cb_flat = jnp.broadcast_to(cbk_ref[...][None],
                                   (tn, K, d)).reshape(tn * K, d)
        cand = stepnet.out_add(
            cb_flat, vL,
            op_ref[...] if has_proj else None).reshape(tn, K, d)
        d2 = jnp.sum(jnp.square(r_ref[...][:, None, :] - cand),
                     axis=-1)                             # (tn, K)
        vals, args = masked_topk(-d2, A)
        idx_ref[...] = args
        d2_ref[...] = -vals


@functools.partial(jax.jit, static_argnames=("A", "tile_n", "interpret"))
def preselect_topk(codebook, xhat, r, A: int, concat_w, concat_b, w1, w2,
                   in_proj=None, out_proj=None, *, tile_n: int = 8,
                   interpret: bool = True):
    """codebook: (K, d) pre-codebook C~; xhat, r: (N, d) flattened beam
    rows -> (idx (N, A) int32, d2 (N, A) f32 ascending) — the top-A of
    ||r - g_phi(C~_k | xhat)||^2 over all K codewords, tie-break
    bit-identical to `lax.top_k(-d2, A)`."""
    N, d = xhat.shape
    K = codebook.shape[0]
    Ls, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
    Np = N + pad
    ins = [codebook, xhat, r, concat_w, concat_b.reshape(1, de), w1, w2]
    in_specs = [
        pl.BlockSpec((K, d), lambda ni, li: (0, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    idx, d2 = pl.pallas_call(
        functools.partial(_preselect_kernel, Ls=Ls, A=A, has_proj=has_proj),
        grid=(Np // tile_n, Ls),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tile_n, A), lambda ni, li: (ni, 0)),
            pl.BlockSpec((tile_n, A), lambda ni, li: (ni, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, A), jnp.int32),
            jax.ShapeDtypeStruct((Np, A), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_n, K, de), jnp.float32)],
        interpret=interpret,
    )(*ins)
    return idx[:N], d2[:N]
