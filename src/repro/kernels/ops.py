"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else they run in
interpret=True mode (the kernel body executed op-by-op on CPU), which is
how the test suite validates them against the `ref.py` oracles.
"""
from __future__ import annotations

import jax

from repro.kernels import adc_onehot as _adc
from repro.kernels import kv_dequant_attn as _kva
from repro.kernels import l2_topk as _l2
from repro.kernels import resmlp as _rm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def l2_topk(r, cb, A: int, **kw):
    kw.setdefault("interpret", _interpret())
    return _l2.l2_topk(r, cb, A, **kw)


def adc_scores(codes, lut, **kw):
    kw.setdefault("interpret", _interpret())
    return _adc.adc_scores(codes, lut, **kw)


def resmlp_chain(v, w1, w2, **kw):
    kw.setdefault("interpret", _interpret())
    return _rm.resmlp_chain(v, w1, w2, **kw)


def kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len, **kw):
    kw.setdefault("interpret", _interpret())
    return _kva.kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len,
                                **kw)
