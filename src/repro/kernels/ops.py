"""Backend-selectable ops facade: the single dispatch point for every
compute hot path in `core/` (the dispatch contract).

Each op takes ``backend`` — one of:

  - ``"pallas"``: the hand-written Pallas kernel. Compiles natively on TPU;
    elsewhere it runs in ``interpret=True`` mode (the kernel body executed
    op-by-op), which is how the test suite validates kernel bodies on CPU.
  - ``"xla"``: the pure-jnp reference implementation from `kernels/ref.py`
    (gather forms — the cheap path off-TPU). The fallback on CPU/GPU, and
    the comparison baseline for the parity tests and backend benchmarks.
  - ``"xla_onehot"``: same results as ``"xla"`` but with the ADC scan
    expressed as the one-hot MXU einsum — for AOT dry-run lowering that
    must see TPU-shaped HLO (see `launch/qinco_cells`), not for real
    non-TPU execution.
  - ``"auto"`` (default): ``"pallas"`` on TPU, ``"xla"`` everywhere else.

Contract highlights:

  - Input padding/tiling is handled HERE, once. Callers may pass any
    N/Q/C — not just tile multiples; outputs are sliced back to caller
    shapes and padded rows never leak into results.
  - Scoring ops accept an optional ``norms`` operand and then return the
    asymmetric-distance surrogate ``2 * <q, xhat> - ||xhat||^2`` directly,
    so callers never re-implement score assembly.
  - `adc_scores` dispatches on the codes rank: ``(N, M)`` scores every
    query against a shared code matrix (database scan, one (Q, N) tile
    grid); ``(Q, C, M)`` scores each query against its own candidate list
    (IVF shortlists, batched one-hot matvec).
  - Codes may be **packed uint8** (K <= 256; see `index/codes.py`) or
    int32 — results are bit-identical. On the pallas path the packed
    bytes are what crosses HBM -> VMEM (4x less wire than int32); the
    widening to int32 happens inside the kernel body.
  - `pairwise_scores` reuses the same one-hot ADC machinery on the
    K^2-alphabet combined codes of the pairwise decoder (paper Eq. 8-9):
    bucket indices i*K+j are formed here and fed to the ADC backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import adc_onehot as _adc
from repro.kernels import kv_dequant_attn as _kva
from repro.kernels import l2_topk as _l2
from repro.kernels import ref as _ref
from repro.kernels import resmlp as _rm

BACKENDS = ("auto", "pallas", "xla", "xla_onehot")


def resolve_backend(backend: str | None) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere.

    'xla_onehot' is the xla fallback with the ADC scan expressed as the
    one-hot MXU einsum instead of a gather: same results, TPU-shaped HLO.
    Meant for AOT dry-run lowering (launch/qinco_cells), NOT for real
    non-TPU execution — the (N, M, K) one-hot intermediate is exactly what
    the gather form avoids.
    """
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS}")
    return backend


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Pre-selection: fused L2 distance + top-A (paper Eq. 6, L_s = 0)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("A", "backend", "tile_n", "interpret"))
def l2_topk(r, cb, A: int, *, backend: str = "auto", tile_n: int = 256,
            interpret: bool | None = None):
    """r: (N, d); cb: (K, d) -> (idx (N, A) int32, d2 (N, A)) ascending."""
    A = min(A, cb.shape[0])
    if resolve_backend(backend) != "pallas":
        return _ref.l2_topk_ref(r, cb, A)
    if interpret is None:
        interpret = _interpret()
    return _l2.l2_topk(r, cb, A, tile_n=tile_n, interpret=interpret)


# ---------------------------------------------------------------------------
# ADC scoring (paper Fig. 3 step 2; the billion-scale scan hot loop)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_q", "tile_n",
                                   "interpret"))
def adc_scores(codes, lut, *, norms=None, backend: str = "auto",
               tile_q: int = 64, tile_n: int = 256,
               interpret: bool | None = None):
    """Additive-decoder inner products (one-hot MXU form on the pallas
    path, gather form on the xla fallback).

    codes (N, M) uint8|int32, lut (Q, M, K)    -> (Q, N)  [shared codes]
    codes (Q, C, M) uint8|int32, lut (Q, M, K) -> (Q, C)  [per-query codes]

    With ``norms`` (||xhat||^2, shaped (N,) or (Q, C) to match) the result
    is the score ``2 * ip - norms``; otherwise the raw inner products.
    """
    be = resolve_backend(backend)
    if interpret is None:
        interpret = _interpret()
    if codes.ndim == 2:
        if be == "xla":
            ip = _ref.adc_ref(codes, lut)
        elif be == "xla_onehot":
            ip = _ref.adc_onehot_ref(codes, lut)
        else:
            ip = _adc.adc_scores(codes, lut, tile_q=tile_q, tile_n=tile_n,
                                 interpret=interpret)
        if norms is not None:
            return 2.0 * ip - norms[None, :]
        return ip
    if codes.ndim != 3:
        raise ValueError(f"codes must be (N, M) or (Q, C, M); got "
                         f"{codes.shape}")
    if be in ("xla", "xla_onehot"):
        ip = _ref.adc_batched_ref(codes, lut)
    else:
        ip = _adc.adc_scores_batched(codes, lut, tile_q=min(tile_q, 8),
                                     tile_c=tile_n, interpret=interpret)
    if norms is not None:
        return 2.0 * ip - norms
    return ip


# ---------------------------------------------------------------------------
# Pairwise-decoder scoring (paper §3.3 Eq. 8-9; Fig. 3 step 3)
# ---------------------------------------------------------------------------


def pairwise_buckets(codes, pairs, K: int):
    """Combined codes I^{i,j} = I^i * K + I^j over the selected column
    pairs. codes (..., M_all) int -> (..., M') int32 with alphabet K^2.

    Codes are widened BEFORE the multiply: packed uint8 columns would
    wrap at 256 (the K^2 alphabet needs up to 16 bits)."""
    codes = codes.astype(jnp.int32)
    return jnp.stack([codes[..., i] * K + codes[..., j] for i, j in pairs],
                     axis=-1)


@partial(jax.jit, static_argnames=("pairs", "K", "backend", "tile_q",
                                   "tile_n", "interpret"))
def pairwise_scores(codes, lut, pairs, K: int, *, norms=None,
                    backend: str = "auto", tile_q: int = 64,
                    tile_n: int = 256, interpret: bool | None = None):
    """Pairwise additive-decoder scores, reusing the one-hot ADC matmul on
    the K^2-alphabet bucket codes.

    codes (..., M_all) int32 raw code columns (QINCo2 codes ++ I~);
    lut (Q, M', K^2) per-pair inner-product LUTs; pairs: static tuple of
    (i, j) column pairs. Shapes dispatch exactly like `adc_scores`.
    """
    buckets = pairwise_buckets(codes, pairs, K)
    return adc_scores(buckets, lut, norms=norms, backend=backend,
                      tile_q=tile_q, tile_n=tile_n, interpret=interpret)


# ---------------------------------------------------------------------------
# Residual-MLP chain + compressed-KV attention (non-QINCo hot paths)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_n", "interpret"))
def resmlp_chain(v, w1, w2, *, backend: str = "auto", tile_n: int = 256,
                 interpret: bool | None = None):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de) -> (N, de)."""
    if resolve_backend(backend) != "pallas":
        return _ref.resmlp_ref(v, w1, w2)
    if interpret is None:
        interpret = _interpret()
    return _rm.resmlp_chain(v, w1, w2, tile_n=tile_n, interpret=interpret)


def kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len, *,
                    backend: str = "auto", **kw):
    """Decode attention over an RQ-compressed KV cache."""
    if resolve_backend(backend) != "pallas":
        return _ref.kv_dequant_attn_ref(q, codes_k, codes_v, cb_k, cb_v,
                                        valid_len)
    kw.setdefault("interpret", _interpret())
    return _kva.kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len,
                                **kw)
