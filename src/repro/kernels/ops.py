"""Backend-selectable ops facade: the single dispatch point for every
compute hot path in `core/` (the dispatch contract).

Each op takes ``backend`` — one of:

  - ``"pallas"``: the hand-written Pallas kernel. Compiles natively on TPU;
    elsewhere it runs in ``interpret=True`` mode (the kernel body executed
    op-by-op), which is how the test suite validates kernel bodies on CPU.
  - ``"xla"``: the pure-jnp reference implementation from `kernels/ref.py`
    (gather forms — the cheap path off-TPU). The fallback on CPU/GPU, and
    the comparison baseline for the parity tests and backend benchmarks.
  - ``"xla_onehot"``: same results as ``"xla"`` but with the ADC scan
    expressed as the one-hot MXU einsum — for AOT dry-run lowering that
    must see TPU-shaped HLO (see `launch/qinco_cells`), not for real
    non-TPU execution.
  - ``"auto"`` (default): ``"pallas"`` on TPU, ``"xla"`` everywhere else.

Contract highlights:

  - Input padding/tiling is handled HERE, once. Callers may pass any
    N/Q/C — not just tile multiples; outputs are sliced back to caller
    shapes and padded rows never leak into results. Empty inputs (any
    zero-sized batch dim) return early with correctly-shaped empties —
    no op may divide by a degenerate tile size.
  - Tile sizes default to the per-op `kernels/tuning.py` table (the one
    place TPU autotuning writes results); explicit arguments still win.
    Resolution happens in the non-jitted facade wrapper, at call time —
    so `tuning.load`/`set_tiles` affects the NEXT call (fresh jit key on
    the concrete tile ints) instead of being baked into a stale
    executable keyed on tile=None.
  - Scoring ops accept an optional ``norms`` operand and then return the
    asymmetric-distance surrogate ``2 * <q, xhat> - ||xhat||^2`` directly,
    so callers never re-implement score assembly.
  - `adc_scores` dispatches on the codes rank: ``(N, M)`` scores every
    query against a shared code matrix (database scan, one (Q, N) tile
    grid); ``(Q, C, M)`` scores each query against its own candidate list
    (IVF shortlists, batched one-hot matvec). `adc_topk` is the fused
    shared-codes variant that reduces each score tile to a running local
    top-k without leaving VMEM (the distributed per-shard shape).
  - Codes may be **packed uint8** (K <= 256; see `index/codes.py`) or
    int32 — results are bit-identical. On the pallas path the packed
    bytes are what crosses HBM -> VMEM (4x less wire than int32); the
    widening to int32 happens inside the kernel body. The same rule
    applies to `f_theta`'s candidate indices.
  - `pairwise_scores` reuses the same one-hot ADC machinery on the
    K^2-alphabet combined codes of the pairwise decoder (paper Eq. 8-9):
    bucket indices i*K+j are formed here and fed to the ADC backend.
  - `f_theta` is the QINCo2 step network (Eq. 10-13) — gather, concat
    projection, residual chain, and in/out projections fused into one
    `pallas_call` on the kernel backend, bit-identical to the historical
    `qinco.f_apply` jnp path on the xla backend. Every step-network hot
    path (beam expansion, decode, re-ranking) dispatches through it.
  - `f_theta_err` is the FULL beam step (§3.2): the indexed f_theta
    expansion, the per-expansion squared error against the target, the
    invalid-beam mask, and the flat top-B selection over the B*A
    expansions in one launch. Only the selected (N, B) indices/errors and
    the (N, B, d) winning reconstructions reach HBM — the (N, B, A, d)
    expansion and (N, B, A) error tensors never do. `preselect_topk` is
    the matching fusion of the L_s >= 1 pre-selector (Eq. 6): g_phi on
    all K codewords + L2-to-residual + top-A, with no (.., K, d)
    candidate or (.., K) score tensor leaving VMEM. Both are
    bit-identical (values and `lax.top_k` tie-breaks) to the unfused
    composites they replace; `core/encode.py` routes every beam step
    through them.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import adc_onehot as _adc
from repro.kernels import adc_topk as _adct
from repro.kernels import beam_topk as _bt
from repro.kernels import kv_dequant_attn as _kva
from repro.kernels import l2_topk as _l2
from repro.kernels import ref as _ref
from repro.kernels import resmlp as _rm
from repro.kernels import tuning

BACKENDS = ("auto", "pallas", "xla", "xla_onehot")


def resolve_backend(backend: str | None) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere.

    'xla_onehot' is the xla fallback with the ADC scan expressed as the
    one-hot MXU einsum instead of a gather: same results, TPU-shaped HLO.
    Meant for AOT dry-run lowering (launch/qinco_cells), NOT for real
    non-TPU execution — the (N, M, K) one-hot intermediate is exactly what
    the gather form avoids.
    """
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS}")
    return backend


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Pre-selection: fused L2 distance + top-A (paper Eq. 6, L_s = 0)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("A", "backend", "tile_n", "interpret"))
def _l2_topk_impl(r, cb, A: int, *, backend, tile_n, interpret):
    A = min(A, cb.shape[0])
    if r.shape[0] == 0 or A == 0:
        return (jnp.zeros((r.shape[0], A), jnp.int32),
                jnp.zeros((r.shape[0], A), jnp.float32))
    if resolve_backend(backend) != "pallas":
        return _ref.l2_topk_ref(r, cb, A)
    if interpret is None:
        interpret = _interpret()
    return _l2.l2_topk(r, cb, A, tile_n=tile_n, interpret=interpret)


def l2_topk(r, cb, A: int, *, backend: str = "auto", tile_n: int = None,
            interpret: bool | None = None):
    """r: (N, d); cb: (K, d) -> (idx (N, A) int32, d2 (N, A)) ascending."""
    # tile sizes resolve HERE, outside the jit cache, so a tuning.load /
    # set_tiles takes effect on the next call rather than being baked
    # into an executable keyed on tile=None (same pattern for every op)
    return _l2_topk_impl(r, cb, A, backend=backend,
                         tile_n=tuning.tile("l2_topk", "tile_n", tile_n),
                         interpret=interpret)


# ---------------------------------------------------------------------------
# Step network f_theta (paper Eq. 10-13; beam expansion / decode hot loop)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_n", "interpret"))
def _f_theta_impl(step_params, c, xhat, *, idx, backend, tile_n,
                  interpret):
    p = step_params
    d = xhat.shape[-1]
    L = p["blocks_w1"].shape[0]
    be = resolve_backend(backend)
    if interpret is None:
        interpret = _interpret()
    if idx is None:
        bshape = jnp.broadcast_shapes(c.shape[:-1], xhat.shape[:-1])
        n = math.prod(bshape)
        if be != "pallas" or n == 0 or L == 0:
            return _ref.f_theta_ref(p, c, xhat)
        cf = jnp.broadcast_to(c, bshape + (d,)).reshape(n, d)
        xf = jnp.broadcast_to(xhat, bshape + (d,)).reshape(n, d)
        out = _rm.f_theta_fused(
            cf, xf, p["concat_w"], p["concat_b"], p["blocks_w1"],
            p["blocks_w2"], p.get("in_proj"), p.get("out_proj"),
            tile_n=tile_n, interpret=interpret)
        return out.reshape(bshape + (d,))
    A = idx.shape[-1]
    lead = idx.shape[:-1]
    n = math.prod(lead)
    if be != "pallas" or n == 0 or A == 0 or L == 0:
        return _ref.f_theta_gather_ref(p, c, idx, xhat)
    out = _rm.f_theta_gather(
        idx.reshape(n, A), c, xhat.reshape(n, d), p["concat_w"],
        p["concat_b"], p["blocks_w1"], p["blocks_w2"], p.get("in_proj"),
        p.get("out_proj"), tile_n=tile_n, interpret=interpret)
    return out.reshape(lead + (A, d))


def f_theta(step_params, c, xhat, *, idx=None, backend: str = "auto",
            tile_n: int = None, interpret: bool | None = None):
    """Fused QINCo2 step network f_theta^m. Two call forms:

    gathered (``idx=None``): c (..., d) candidates broadcast jointly with
        xhat (..., d) -> (..., d). The in-projection runs BEFORE the
        broadcast on the xla path (a shared (K, d) candidate list is
        projected once — the L_s >= 1 pre-selector shape). The pallas
        path flattens the broadcast into one (N', d) tiled launch and
        projects per row: for heavily-broadcast shared candidates prefer
        the indexed form (broadcast `arange(K)` indices), which ships
        4-byte indices instead of d-float rows.

    indexed (``idx`` given): c = codebook (K, d); idx (..., A) int (uint8
        packed or int32) with idx.shape[:-1] == xhat.shape[:-1]; xhat
        (..., d) -> (..., A, d) = f(codebook[idx], xhat[..., None, :]).
        On the pallas path the codebook gather happens in-kernel, so only
        the indices — never the (..., A, d) candidate expansion — cross
        HBM. This is the beam-search expansion / decode / re-rank form.

    ``backend="xla"`` is bit-identical to the pre-refactor
    `qinco.f_apply`; both backends keep every intermediate of one row tile
    resident across the concat/residual/projection stages.
    """
    if idx is not None and idx.shape[:-1] != xhat.shape[:-1]:
        raise ValueError(f"indexed f_theta wants idx (..., A) matching "
                         f"xhat (..., d) batch dims; got {idx.shape} vs "
                         f"{xhat.shape}")
    op = "f_theta" if idx is None else "f_theta_gather"
    return _f_theta_impl(step_params, c, xhat, idx=idx, backend=backend,
                         tile_n=tuning.tile(op, "tile_n", tile_n),
                         interpret=interpret)


# ---------------------------------------------------------------------------
# Fused beam step: expansion + scoring + top-B selection (paper §3.2)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_n", "interpret"))
def _f_theta_err_impl(step_params, cb, xhat, idx, x, err, *, backend,
                      tile_n, interpret):
    p = step_params
    N, Bb, d = xhat.shape
    A = idx.shape[-1]
    L = p["blocks_w1"].shape[0]
    be = resolve_backend(backend)
    if interpret is None:
        interpret = _interpret()
    if N == 0 or Bb == 0:
        return (jnp.zeros((N, Bb), jnp.float32),
                jnp.zeros((N, Bb), jnp.int32),
                jnp.zeros((N, Bb, d), jnp.float32))
    if be != "pallas" or L == 0:
        return _ref.f_theta_err_ref(p, cb, xhat, idx, x, err)
    return _rm.f_theta_err(
        idx.reshape(N, Bb * A), cb, xhat, x, err, p["concat_w"],
        p["concat_b"], p["blocks_w1"], p["blocks_w2"], p.get("in_proj"),
        p.get("out_proj"), B=Bb, tile_n=tile_n, interpret=interpret)


def f_theta_err(step_params, cb, xhat, idx, x, err, *, backend: str = "auto",
                tile_n: int = None, interpret: bool | None = None):
    """Fused beam-search step: indexed f_theta expansion + in-VMEM
    squared-error scoring + flat top-B selection, in ONE launch.

    cb: (K, d) step codebook; xhat: (N, B, d) beam reconstructions;
    idx: (N, B, A) int candidate indices (uint8 packed or int32);
    x: (N, d) encode targets; err: (N, B) current beam errors, where
    +inf marks a not-yet-populated slot (its expansions are masked out).

    Returns (sel_err (N, B) f32, sel_flat (N, B) int32 indices into the
    flattened B*A expansion, sel_xhat (N, B, d) f32) — bit-identical,
    values and tie-breaks, to the unfused composite
    ``ops.f_theta(idx=...)`` + error + ``lax.top_k`` on the same backend.
    On the pallas path neither the (N, B, A, d) expansion nor the
    (N, B, A) error tensor reaches HBM: both live in VMEM scratch and
    only the three selected outputs are kernel outputs.
    """
    if idx.shape[:-1] != xhat.shape[:-1]:
        raise ValueError(f"f_theta_err wants idx (N, B, A) matching xhat "
                         f"(N, B, d); got {idx.shape} vs {xhat.shape}")
    if idx.shape[-1] == 0:
        raise ValueError("f_theta_err needs at least one expansion per "
                         "beam (A >= 1)")
    return _f_theta_err_impl(
        step_params, cb, xhat, idx, x, err, backend=backend,
        tile_n=tuning.tile("f_theta_err", "tile_n", tile_n),
        interpret=interpret)


@partial(jax.jit, static_argnames=("A", "backend", "tile_n", "interpret"))
def _preselect_topk_impl(step_params, cb, xhat, r, A, *, backend, tile_n,
                         interpret):
    p = step_params
    K, d = cb.shape
    A = min(A, K)
    Ls = p["blocks_w1"].shape[0]
    lead = xhat.shape[:-1]
    n = math.prod(lead)
    be = resolve_backend(backend)
    if interpret is None:
        interpret = _interpret()
    if n == 0 or A == 0:
        return (jnp.zeros(lead + (A,), jnp.int32),
                jnp.zeros(lead + (A,), jnp.float32))
    if be != "pallas" or Ls == 0:
        return _ref.preselect_topk_ref(p, cb, xhat, r, A)
    idx, d2 = _bt.preselect_topk(
        cb, xhat.reshape(n, d), r.reshape(n, d), A, p["concat_w"],
        p["concat_b"], p["blocks_w1"], p["blocks_w2"], p.get("in_proj"),
        p.get("out_proj"), tile_n=tile_n, interpret=interpret)
    return idx.reshape(lead + (A,)), d2.reshape(lead + (A,))


def preselect_topk(step_params, cb, xhat, r, A: int, *,
                   backend: str = "auto", tile_n: int = None,
                   interpret: bool | None = None):
    """Fused L_s >= 1 pre-selection (Eq. 6): the g_phi candidate network
    evaluated on ALL K codewords + L2 distance to the step residual +
    top-A, in ONE launch.

    cb: (K, d) pre-codebook C~; xhat, r: (..., d) beam state / residual
    rows (batch dims match). Returns (idx (..., A) int32, d2 (..., A)
    ascending) — bit-identical to the unfused
    ``ops.f_theta(xhat[..., None, :])`` + distance + ``lax.top_k(-d2, A)``
    composite. On the pallas path neither the (..., K, d) candidate
    tensor nor the (..., K) score tensor reaches HBM (and unlike the
    unfused pallas path, no identity index tensor is shipped at all:
    every row scores the full codebook implicitly).
    """
    if xhat.shape != r.shape:
        raise ValueError(f"preselect_topk wants matching xhat/r shapes; "
                         f"got {xhat.shape} vs {r.shape}")
    return _preselect_topk_impl(
        step_params, cb, xhat, r, A, backend=backend,
        tile_n=tuning.tile("preselect_topk", "tile_n", tile_n),
        interpret=interpret)


# ---------------------------------------------------------------------------
# ADC scoring (paper Fig. 3 step 2; the billion-scale scan hot loop)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_q", "tile_n",
                                   "interpret"))
def _adc_scores_impl(codes, lut, *, norms, backend, tile_q, tile_n,
                     interpret):
    be = resolve_backend(backend)
    if interpret is None:
        interpret = _interpret()
    if codes.ndim == 2:
        N, M = codes.shape
        Q = lut.shape[0]
        if N == 0 or Q == 0 or M == 0:
            return jnp.zeros((Q, N), jnp.float32)
        if be == "xla":
            ip = _ref.adc_ref(codes, lut)
        elif be == "xla_onehot":
            ip = _ref.adc_onehot_ref(codes, lut)
        else:
            ip = _adc.adc_scores(codes, lut, tile_q=tile_q, tile_n=tile_n,
                                 interpret=interpret)
        if norms is not None:
            return 2.0 * ip - norms[None, :]
        return ip
    Q, C, M = codes.shape
    if Q == 0 or C == 0 or M == 0:
        return jnp.zeros((Q, C), jnp.float32)
    if be in ("xla", "xla_onehot"):
        ip = _ref.adc_batched_ref(codes, lut)
    else:
        ip = _adc.adc_scores_batched(codes, lut, tile_q=tile_q,
                                     tile_c=tile_n, interpret=interpret)
    if norms is not None:
        return 2.0 * ip - norms
    return ip


def adc_scores(codes, lut, *, norms=None, backend: str = "auto",
               tile_q: int = None, tile_n: int = None,
               interpret: bool | None = None):
    """Additive-decoder inner products (one-hot MXU form on the pallas
    path, gather form on the xla fallback).

    codes (N, M) uint8|int32, lut (Q, M, K)    -> (Q, N)  [shared codes]
    codes (Q, C, M) uint8|int32, lut (Q, M, K) -> (Q, C)  [per-query codes]

    With ``norms`` (||xhat||^2, shaped (N,) or (Q, C) to match) the result
    is the score ``2 * ip - norms``; otherwise the raw inner products.
    """
    if codes.ndim == 2:
        tile_q = tuning.tile("adc_scores", "tile_q", tile_q)
        tile_n = tuning.tile("adc_scores", "tile_n", tile_n)
    elif codes.ndim == 3:
        tile_q = tuning.tile("adc_scores_batched", "tile_q", tile_q)
        tile_n = tuning.tile("adc_scores_batched", "tile_c", tile_n)
    else:
        raise ValueError(f"codes must be (N, M) or (Q, C, M); got "
                         f"{codes.shape}")
    return _adc_scores_impl(codes, lut, norms=norms, backend=backend,
                            tile_q=tile_q, tile_n=tile_n,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("k", "backend", "tile_q", "tile_n",
                                   "interpret"))
def _adc_topk_impl(codes, lut, k, *, norms, backend, tile_q, tile_n,
                   interpret):
    N = codes.shape[0]
    Q = lut.shape[0]
    k = min(k, N)
    if N == 0 or Q == 0 or k == 0:
        return (jnp.full((Q, k), -jnp.inf, jnp.float32),
                jnp.zeros((Q, k), jnp.int32))
    if resolve_backend(backend) != "pallas":
        return _ref.adc_topk_ref(codes, lut, k, norms=norms)
    if interpret is None:
        interpret = _interpret()
    return _adct.adc_topk(codes, lut, k, norms=norms, tile_q=tile_q,
                          tile_n=tile_n, interpret=interpret)


# Tombstone masking penalty (docs/INDEX_FORMAT.md "Mutation"): a deleted
# row's norms are inflated by this finite constant, so its score
# 2*ip - (norms + penalty) lands around -2e30 — below every live
# candidate AND below the -1e30 non-probed LUT entries — without ever
# introducing an inf/NaN into the one-hot matmul (the same reason the
# probe mask uses -1e30 instead of -inf). The caller post-masks the few
# surviving tombstoned entries to exact -inf by id, so the penalty only
# needs to keep dead rows out of the per-shard shortlist, not to be
# numerically exact.
TOMBSTONE_PENALTY = np.float32(2e30)


def adc_topk(codes, lut, k: int, *, norms=None, dead=None,
             backend: str = "auto", tile_q: int = None, tile_n: int = None,
             interpret: bool | None = None):
    """Fused shared-codes ADC scan + local top-k shortlist.

    codes (N, M) uint8|int32; lut (Q, M, K) -> (vals (Q, k') f32
    descending, ids (Q, k') int32) with k' = min(k, N). On the pallas
    path the (Q, N) score matrix never reaches HBM: each (TQ, TN) tile is
    merged into a running per-query top-k inside VMEM. Tie-breaking is
    lowest-index-first on both backends (the `lax.top_k` contract).
    With ``norms`` the merged values are ``2 * ip - norms``.

    ``dead`` ((N,) bool, optional) tombstone-masks rows inside the fused
    scan: `TOMBSTONE_PENALTY` is folded into the norms the kernel already
    subtracts, so dead rows score ~-2e30 and lose to every live (and even
    every non-probed) candidate on both backends — no kernel change, no
    extra scan pass. ``dead=None`` (the default) adds nothing, keeping
    unmutated stores bit-exactly on their historical path.
    """
    if dead is not None:
        penalty = jnp.where(dead, TOMBSTONE_PENALTY, np.float32(0.0))
        norms = penalty if norms is None else norms + penalty
    return _adc_topk_impl(codes, lut, k, norms=norms, backend=backend,
                          tile_q=tuning.tile("adc_topk", "tile_q", tile_q),
                          tile_n=tuning.tile("adc_topk", "tile_n", tile_n),
                          interpret=interpret)


# ---------------------------------------------------------------------------
# Pairwise-decoder scoring (paper §3.3 Eq. 8-9; Fig. 3 step 3)
# ---------------------------------------------------------------------------


def pairwise_buckets(codes, pairs, K: int):
    """Combined codes I^{i,j} = I^i * K + I^j over the selected column
    pairs. codes (..., M_all) int -> (..., M') int32 with alphabet K^2.

    One fused gather per operand (`take` over the static pair index
    arrays) instead of 2*M' per-pair slices + a stack. Codes are widened
    BEFORE the multiply: packed uint8 columns would wrap at 256 (the K^2
    alphabet needs up to 16 bits)."""
    codes = codes.astype(jnp.int32)
    pi = jnp.asarray(np.array([i for i, _ in pairs], np.int32))
    pj = jnp.asarray(np.array([j for _, j in pairs], np.int32))
    return jnp.take(codes, pi, axis=-1) * K + jnp.take(codes, pj, axis=-1)


def pairwise_scores(codes, lut, pairs, K: int, *, norms=None,
                    backend: str = "auto", tile_q: int = None,
                    tile_n: int = None, interpret: bool | None = None):
    """Pairwise additive-decoder scores, reusing the one-hot ADC matmul on
    the K^2-alphabet bucket codes.

    codes (..., M_all) int32 raw code columns (QINCo2 codes ++ I~);
    lut (Q, M', K^2) per-pair inner-product LUTs; pairs: static tuple of
    (i, j) column pairs. Shapes dispatch exactly like `adc_scores`.
    """
    buckets = pairwise_buckets(codes, pairs, K)
    return adc_scores(buckets, lut, norms=norms, backend=backend,
                      tile_q=tile_q, tile_n=tile_n, interpret=interpret)


# ---------------------------------------------------------------------------
# Residual-MLP chain + compressed-KV attention (non-QINCo hot paths)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "tile_n", "interpret"))
def _resmlp_chain_impl(v, w1, w2, *, backend, tile_n, interpret):
    if v.shape[0] == 0 or w1.shape[0] == 0:
        return v
    if resolve_backend(backend) != "pallas":
        return _ref.resmlp_ref(v, w1, w2)
    if interpret is None:
        interpret = _interpret()
    return _rm.resmlp_chain(v, w1, w2, tile_n=tile_n, interpret=interpret)


def resmlp_chain(v, w1, w2, *, backend: str = "auto", tile_n: int = None,
                 interpret: bool | None = None):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de) -> (N, de)."""
    return _resmlp_chain_impl(
        v, w1, w2, backend=backend,
        tile_n=tuning.tile("resmlp_chain", "tile_n", tile_n),
        interpret=interpret)


def kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len, *,
                    backend: str = "auto", **kw):
    """Decode attention over an RQ-compressed KV cache."""
    if q.shape[0] == 0 or codes_k.shape[1] == 0:
        return jnp.zeros_like(q)
    if resolve_backend(backend) != "pallas":
        return _ref.kv_dequant_attn_ref(q, codes_k, codes_v, cb_k, cb_v,
                                        valid_len)
    kw.setdefault("interpret", _interpret())
    kw.setdefault("tile_t", tuning.tile("kv_dequant_attn", "tile_t"))
    return _kva.kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len,
                                **kw)
