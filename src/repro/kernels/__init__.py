"""Compute-kernel layer: Pallas kernels + reference ops + the dispatch
facade.

Layout (the dispatch contract — see `ops.py` for the full statement):

  - ``ops.py``     public entry points. Every core/ hot path calls these;
                   each op takes ``backend="pallas" | "xla" | "auto"`` and
                   handles tile padding once so callers never think about
                   tile-multiple shapes.
  - ``ref.py``     pure-jnp reference implementations: the oracles the
                   kernel tests compare against AND the ``backend="xla"``
                   fallbacks used on CPU/GPU.
  - ``tuning.py``  the per-op tile-size table (autotune / save / load) —
                   ops resolve their default tiles here.
  - ``l2_topk.py``        fused L2 distance + top-A pre-selection (Eq. 6).
  - ``adc_onehot.py``     one-hot MXU ADC scan, shared-codes and per-query
                          batched variants (Fig. 3; also serves the K^2
                          pairwise alphabet via `ops.pairwise_scores`).
  - ``adc_topk.py``       fused ADC scan + running local top-k: the score
                          matrix never leaves VMEM before shortlisting
                          (the distributed per-shard path).
  - ``resmlp.py``         the fused f_theta step network (gather + concat
                          projection + residual chain + in/out projections
                          in one pallas_call) and the bare residual chain.
  - ``kv_dequant_attn.py`` decode attention over an RQ-compressed KV cache.

Kernels compile natively on TPU and run with ``interpret=True`` elsewhere;
``backend="auto"`` therefore lowers to the Pallas kernels on TPU and to the
ref ops everywhere else.
"""
