"""Pure-jnp reference ops: the correctness contracts for every Pallas
kernel AND the `backend="xla"` implementations behind `kernels/ops.py`.

Two flavors coexist on purpose:
  - gather forms (`adc_ref`, `adc_batched_ref`): take_along_axis lookups —
    the cheap path on CPU/GPU and the oracle the kernel tests check against;
  - one-hot forms (`adc_onehot_ref`): the same math as an MXU matmul —
    what `ops` lowers on the shared-codes hot path so that AOT dry-runs see
    the TPU-shaped HLO even under the XLA backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(r, cb, A: int):
    """r: (N, d); cb: (K, d) -> (idx (N, A) int32, d2 (N, A)) ascending."""
    d2 = (jnp.sum(r * r, -1, keepdims=True)
          - 2.0 * r @ cb.T + jnp.sum(cb * cb, -1))
    neg, idx = jax.lax.top_k(-d2, A)
    return idx.astype(jnp.int32), -neg


def adc_ref(codes, lut):
    """codes: (N, M) int (uint8 packed or int32); lut: (Q, M, K) ->
    scores (Q, N) = sum_m lut[q,m,codes[n,m]]."""
    codes = codes.astype(jnp.int32)
    return jnp.sum(jnp.take_along_axis(
        lut[:, None], codes[None, ..., None], axis=3)[..., 0], axis=2)


def adc_onehot_ref(codes, lut):
    """`adc_ref` as the one-hot einsum (the kernel's own matmul form)."""
    K = lut.shape[2]
    oh = jax.nn.one_hot(codes.astype(jnp.int32), K, dtype=jnp.float32)
    return jnp.einsum("qmk,nmk->qn", lut.astype(jnp.float32), oh)


def adc_batched_ref(codes, lut):
    """Per-query candidates: codes (Q, C, M) int; lut (Q, M, K) -> (Q, C)."""
    codes = codes.astype(jnp.int32)
    return jnp.sum(jnp.take_along_axis(
        lut[:, None], codes[..., None], axis=3)[..., 0], axis=2)


def f_theta_ref(step_params, c, xhat):
    """QINCo2 step network f_theta^m (paper Eq. 10-13), gathered form.

    c: (..., d); xhat: (..., d) -> (..., d). Batch dims broadcast jointly
    AFTER the optional in-projection (so a shared (K, d) candidate list is
    projected once, then broadcast — the L_s >= 1 pre-selector shape).
    This is, verbatim, the pre-refactor `qinco.f_apply` math: the bitwise
    contract for `ops.f_theta(backend="xla")` and the oracle the fused
    Pallas kernel is tested against.
    """
    p = step_params
    d = xhat.shape[-1]
    if "in_proj" in p:
        c_emb = c @ p["in_proj"]
    else:
        c_emb = c
    bshape = jnp.broadcast_shapes(c_emb.shape[:-1], xhat.shape[:-1])
    c_emb = jnp.broadcast_to(c_emb, bshape + c_emb.shape[-1:])
    xb = jnp.broadcast_to(xhat, bshape + (d,))
    v = c_emb + jnp.concatenate([c_emb, xb], axis=-1) @ p["concat_w"] \
        + p["concat_b"]

    def block(v, wb):
        w1, w2 = wb
        return v + jax.nn.relu(v @ w1) @ w2, None

    v, _ = jax.lax.scan(block, v, (p["blocks_w1"], p["blocks_w2"]))
    if "out_proj" in p:
        return c + v @ p["out_proj"]
    return c + v


def f_theta_gather_ref(step_params, codebook, idx, xhat):
    """Indexed form: codebook (K, d); idx (..., A) int; xhat (..., d) ->
    (..., A, d) = f_theta(codebook[idx], xhat[..., None, :])."""
    return f_theta_ref(step_params, codebook[idx], xhat[..., None, :])


def f_theta_err_ref(step_params, codebook, xhat, idx, x, err):
    """Fused beam-step oracle: the full expansion-score-select composite,
    verbatim the pre-fusion `encode._beam_step` math.

    codebook (K, d); xhat (N, B, d); idx (N, B, A) int; x (N, d);
    err (N, B) with +inf marking unpopulated beam slots ->
    (sel_err (N, B), sel_flat (N, B) int32 indices into B*A,
    sel_xhat (N, B, d)). `lax.top_k` tie-breaking (lowest flat index
    first, including ties at +inf error) is part of the contract the
    fused kernel reproduces."""
    N, B, d = xhat.shape
    A = idx.shape[-1]
    f_out = f_theta_gather_ref(step_params, codebook, idx, xhat)
    new_xhat = xhat[..., None, :] + f_out                 # (N, B, A, d)
    new_err = jnp.sum(jnp.square(x[:, None, None, :] - new_xhat), -1)
    new_err = jnp.where(jnp.isinf(err)[..., None], jnp.inf, new_err)
    flat_err = new_err.reshape(N, B * A)
    top_err, flat_idx = jax.lax.top_k(-flat_err, B)       # (N, B)
    sel_xhat = jnp.take_along_axis(
        new_xhat.reshape(N, B * A, d), flat_idx[..., None], axis=1)
    return -top_err, flat_idx.astype(jnp.int32), sel_xhat


def preselect_topk_ref(step_params, codebook, xhat, r, A: int):
    """Fused pre-selector oracle (Eq. 6, L_s >= 1): g_phi on all K
    codewords, L2 distance to the residual, `lax.top_k` — verbatim the
    pre-fusion `encode.preselect` math (the in-projection runs BEFORE the
    broadcast, exactly as `f_theta_ref` does).

    codebook (K, d); xhat, r (..., d) -> (idx (..., A) int32,
    d2 (..., A) ascending)."""
    cand = f_theta_ref(step_params, codebook, xhat[..., None, :])
    d2 = jnp.sum(jnp.square(r[..., None, :] - cand), axis=-1)
    neg, idx = jax.lax.top_k(-d2, A)
    return idx.astype(jnp.int32), -neg


def adc_topk_ref(codes, lut, k: int, *, norms=None):
    """Fused-shortlist oracle: full (Q, N) ADC scores (gather form, with
    the `2*ip - norms` surrogate when norms given) reduced by `lax.top_k`.
    Returns (vals (Q, k) desc, ids (Q, k) int32); top_k tie-breaking (lowest
    index first) is part of the contract the streaming kernel reproduces."""
    s = adc_ref(codes, lut)
    if norms is not None:
        s = 2.0 * s - norms[None, :]
    v, i = jax.lax.top_k(s, k)
    return v, i.astype(jnp.int32)


def resmlp_ref(v, w1, w2):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de): chained residual MLPs."""
    L = w1.shape[0]
    for l in range(L):
        v = v + jax.nn.relu(v @ w1[l]) @ w2[l]
    return v


def kv_dequant_attn_ref(q, codes_k, codes_v, cb_k, cb_v, valid_len):
    """Decode attention over an RQ-compressed KV cache.

    q: (B, KVH, G, D); codes_*: (B, T, KVH, Mq) int32;
    cb_*: (KVH, Mq, Kq, D); valid_len: int.
    Returns (B, KVH, G, D)."""
    B, T, KVH, Mq = codes_k.shape
    Kq = cb_k.shape[2]

    def dequant(codes, cb):
        onehot = jax.nn.one_hot(codes, Kq, dtype=jnp.float32)
        return jnp.einsum("bthmk,hmkd->bthd", onehot, cb.astype(jnp.float32))

    k = dequant(codes_k, cb_k)
    v = dequant(codes_v, cb_v)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32) * scale, k)
    mask = jnp.arange(T)[None] < valid_len
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", p, v).astype(q.dtype)
