"""Shared step-network kernel-body stages (paper Eq. 10-13).

Three Pallas kernels execute the f_theta/g_phi step network on a
VMEM-resident tile — `resmlp._f_theta_kernel` / `_f_theta_gather_kernel`
/ `_f_theta_err_kernel` and `beam_topk._preselect_kernel`. They MUST all
build their activations through these helpers (the `adc_onehot.score_tile`
pattern): the fused == unfused bit-identical contract is then structural
— one implementation of each stage — instead of four hand-kept copies.

Callers own candidate acquisition (gathered rows, in-kernel one-hot
gather, or the implicit all-K list) and the in-projection (per row or
once per tile for a shared codebook); everything downstream of `c_emb`
goes through here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def onehot_gather(idx, codebook):
    """In-kernel codebook gather as a one-hot MXU matmul (exact: each
    output row sums one selected codeword and zeros).
    idx: (R,) int32; codebook: (K, d) -> (R, d)."""
    R = idx.shape[0]
    K = codebook.shape[0]
    kio = jax.lax.broadcasted_iota(jnp.int32, (R, K), 1)
    onehot = (idx[:, None] == kio).astype(jnp.float32)
    return onehot @ codebook


def concat_in(c_emb, xb, concat_w, concat_b):
    """Eq. 10-11 input stage: v_0 = c_emb + L(concat[c_emb ; xhat]) + b.
    c_emb: (R, de) (already in-projected); xb: (R, d) -> (R, de)."""
    return c_emb + jnp.concatenate([c_emb, xb], axis=-1) @ concat_w \
        + concat_b


def residual_block(v, w1, w2):
    """Eq. 12 one residual block: v + relu(v @ w1) @ w2."""
    return v + jax.nn.relu(v @ w1) @ w2


def out_add(c, vL, out_proj=None):
    """Eq. 13 output stage: f = c + P(v_L) (identity P when no
    projection)."""
    return c + (vL @ out_proj if out_proj is not None else vL)
