"""ADC shortlist scan as a one-hot MXU matmul (paper Fig. 3, 'Faiss search').

CPU Faiss computes additive-decoder distances with per-byte table lookups;
TPU gathers are slow, so the TPU-native form is:

    scores[q, n] = sum_m lut[q, m, codes[n, m]]
                 = lut_flat[q] . onehot_flat[n]          (MK-dim dot)

i.e. a (TQ, M*K) x (M*K, TN) matmul on the systolic array. The one-hot
expansion is built in VMEM from an iota comparison (broadcast + reshape:
no gather anywhere). This is the billion-scale search hot loop.

Codes may be packed uint8 (K <= 256, `index/codes.py`): the packed bytes
are what crosses HBM -> VMEM (4x less wire than int32) and are widened to
int32 only inside the kernel, right before the iota comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _code_wire_dtype(codes):
    """Packed uint8 stays uint8 across the HBM->VMEM boundary; any other
    integer dtype is normalized to int32."""
    if codes.dtype == jnp.uint8:
        return codes
    return codes.astype(jnp.int32)


def score_tile(codes, lut_flat):
    """One (TQ, TN) ADC score tile: codes (TN, M) int (widened in-VMEM),
    lut_flat (TQ, M*K) -> lut_flat @ onehot(codes).T on the MXU.

    The shared kernel-body primitive for the shared-codes scan here AND
    the fused `kernels/adc_topk.py` — both MUST compute score tiles
    through this one function so the fused == unfused bitwise contract
    is structural, not coincidental."""
    codes = codes.astype(jnp.int32)
    lut = lut_flat.astype(jnp.float32)
    tn, M = codes.shape
    MK = lut.shape[1]
    K = MK // M
    codes_b = jnp.broadcast_to(codes[:, :, None], (tn, M, K))
    kio = jax.lax.broadcasted_iota(jnp.int32, (tn, M, K), 2)
    onehot = (codes_b == kio).astype(jnp.float32).reshape(tn, MK)
    return jax.lax.dot_general(
        lut, onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (TQ, TN)


def _kernel(codes_ref, lut_ref, out_ref):
    out_ref[...] = score_tile(codes_ref[...], lut_ref[...])


def _kernel_batched(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)              # (TQ, TC, M)
    lut = lut_ref[...].astype(jnp.float32)                # (TQ, M*K)
    tq, tc, M = codes.shape
    MK = lut.shape[1]
    K = MK // M
    codes_b = jnp.broadcast_to(codes[..., None], (tq, tc, M, K))
    kio = jax.lax.broadcasted_iota(jnp.int32, (tq, tc, M, K), 3)
    onehot = (codes_b == kio).astype(jnp.float32).reshape(tq, tc, MK)
    out_ref[...] = jax.lax.dot_general(
        lut, onehot, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (TQ, TC)


@functools.partial(jax.jit,
                   static_argnames=("tile_q", "tile_c", "interpret"))
def adc_scores_batched(codes, lut, *, tile_q: int = 8, tile_c: int = 256,
                       interpret: bool = True):
    """Per-query candidate scan: codes (Q, C, M) int (uint8 or int32);
    lut (Q, M, K) ->
    (Q, C) scores. Same one-hot MXU form as `adc_scores`, batched over Q —
    the shape of the IVF-shortlist steps of the search cascade, where each
    query scores its own candidate set rather than the whole database."""
    Q, C, M = codes.shape
    K = lut.shape[2]
    tile_q = min(tile_q, Q)
    tile_c = min(tile_c, C)
    pq, pc = (-Q) % tile_q, (-C) % tile_c
    if pq:
        lut = jnp.pad(lut, ((0, pq), (0, 0), (0, 0)))
        codes = jnp.pad(codes, ((0, pq), (0, 0), (0, 0)))
    if pc:
        codes = jnp.pad(codes, ((0, 0), (0, pc), (0, 0)))
    lut_flat = lut.reshape(Q + pq, M * K)
    out = pl.pallas_call(
        _kernel_batched,
        grid=((Q + pq) // tile_q, (C + pc) // tile_c),
        in_specs=[
            pl.BlockSpec((tile_q, tile_c, M), lambda qi, ci: (qi, ci, 0)),
            pl.BlockSpec((tile_q, M * K), lambda qi, ci: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_c), lambda qi, ci: (qi, ci)),
        out_shape=jax.ShapeDtypeStruct((Q + pq, C + pc), jnp.float32),
        interpret=interpret,
    )(_code_wire_dtype(codes), lut_flat)
    return out[:Q, :C]


@functools.partial(jax.jit,
                   static_argnames=("tile_q", "tile_n", "interpret"))
def adc_scores(codes, lut, *, tile_q: int = 64, tile_n: int = 256,
               interpret: bool = True):
    """codes: (N, M) int (uint8 or int32); lut: (Q, M, K) -> (Q, N)."""
    N, M = codes.shape
    Q, _, K = lut.shape
    tile_q = min(tile_q, Q)
    tile_n = min(tile_n, N)
    pq, pn = (-Q) % tile_q, (-N) % tile_n
    if pq:
        lut = jnp.pad(lut, ((0, pq), (0, 0), (0, 0)))
    if pn:
        codes = jnp.pad(codes, ((0, pn), (0, 0)))
    lut_flat = lut.reshape(Q + pq, M * K)
    out = pl.pallas_call(
        _kernel,
        grid=((Q + pq) // tile_q, (N + pn) // tile_n),
        in_specs=[
            pl.BlockSpec((tile_n, M), lambda qi, ni: (ni, 0)),
            pl.BlockSpec((tile_q, M * K), lambda qi, ni: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda qi, ni: (qi, ni)),
        out_shape=jax.ShapeDtypeStruct((Q + pq, N + pn), jnp.float32),
        interpret=interpret,
    )(_code_wire_dtype(codes), lut_flat)
    return out[:Q, :N]
