"""Fused QINCo step-network kernels (paper Eq. 10-13).

`resmlp_chain` is the bare residual chain (Eq. 12): v <- v + relu(v @
w1_l) @ w2_l for l = 0..L-1 without writing the intermediate v to HBM
between blocks. The grid is (N_tiles, L) with L as the innermost
(sequential on TPU) dimension, the activation tile stays resident in a
revisited VMEM block across the L iterations, and only the two (de, dh)
weight slices stream in per step.

`f_theta_fused` / `f_theta_gather` extend the same schedule to the FULL
step network f_theta: the optional in-projection, the concat-projection
input stage (Eq. 11), the L residual blocks, and the optional
out-projection + candidate add (Eq. 13) all execute inside one
`pallas_call` — the pre-stage fires at l == 0, the post-stage at
l == L - 1, and the (tile, de) activation never round-trips HBM in
between. `f_theta_gather` additionally performs the codebook gather
in-kernel as a one-hot MXU matmul (exact: one selected row plus zeros),
so the beam-search expansion ships (N*B, A) indices — packed uint8 stays
uint8 across HBM -> VMEM — instead of the (N, B, A, d) candidate tensor.

`f_theta_err` extends the `f_theta_gather` grid through the rest of the
beam step (paper §3.2): after the candidate add, the same launch computes
each expansion's squared error against the target x in-VMEM and reduces
the (tile, B*A) error block to the per-row top-B (via
`beam_topk.masked_topk` — tie-break bit-identical to `lax.top_k`). Only
the selected (N, B) flat indices + errors and the (N, B, d) winning
reconstructions reach HBM; the (N, B, A, d) expansion tensor and the
(N, B, A) error tensor never do.

This is the decoder hot loop: QINCo2 search re-ranking decodes n_short
candidates per query, and encoding runs A*B f_theta evaluations per
vector per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import stepnet
from repro.kernels.beam_topk import masked_topk


def _kernel(v_ref, w1_ref, w2_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = v_ref[...]

    v = out_ref[...].astype(jnp.float32)                  # (TN, de)
    w1 = w1_ref[0].astype(jnp.float32)                    # (de, dh)
    w2 = w2_ref[0].astype(jnp.float32)                    # (dh, de)
    h = jnp.maximum(jax.lax.dot_general(
        v, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32), 0.0)
    out_ref[...] = (v + jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def resmlp_chain(v, w1, w2, *, tile_n: int = 256, interpret: bool = True):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de) -> (N, de)."""
    N, de = v.shape
    L, _, dh = w1.shape
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    Np = N + pad
    out = pl.pallas_call(
        _kernel,
        grid=(Np // tile_n, L),
        in_specs=[
            pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
            pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
            pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, de), v.dtype),
        interpret=interpret,
    )(v, w1, w2)
    return out[:N]


# ---------------------------------------------------------------------------
# Full step network f_theta, fused end to end
# ---------------------------------------------------------------------------


def _f_theta_kernel(*refs, L: int, has_proj: bool):
    """Gathered form: candidates already materialized as (TN, d) rows.

    v_ref is a VMEM scratch buffer carrying the activation across the
    sequential L iterations of one row tile (scratch persists across grid
    steps on TPU); it never reaches HBM."""
    if has_proj:
        (c_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref, ip_ref, op_ref,
         out_ref, v_ref) = refs
    else:
        (c_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref,
         out_ref, v_ref) = refs
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _concat_in():                                     # Eq. 10-11
        c = c_ref[...]
        c_emb = c @ ip_ref[...] if has_proj else c
        v_ref[...] = stepnet.concat_in(c_emb, x_ref[...], cw_ref[...],
                                       cb_ref[...])

    v_ref[...] = stepnet.residual_block(v_ref[...], w1_ref[0],
                                        w2_ref[0])        # Eq. 12

    @pl.when(l == L - 1)
    def _out():                                           # Eq. 13
        out_ref[...] = stepnet.out_add(
            c_ref[...], v_ref[...], op_ref[...] if has_proj else None)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def f_theta_fused(c, x, concat_w, concat_b, w1, w2, in_proj=None,
                  out_proj=None, *, tile_n: int = 128,
                  interpret: bool = True):
    """c, x: (N, d) flattened candidate/xhat rows -> (N, d). Callers own
    the broadcast/flatten; padding happens here (padded rows sliced off).
    """
    N, d = c.shape
    L, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Np = N + pad
    ins = [c, x, concat_w, concat_b.reshape(1, de), w1, w2]
    in_specs = [
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    out = pl.pallas_call(
        functools.partial(_f_theta_kernel, L=L, has_proj=has_proj),
        grid=(Np // tile_n, L),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_n, de), jnp.float32)],
        interpret=interpret,
    )(*ins)
    return out[:N]


def _f_theta_gather_kernel(*refs, L: int, has_proj: bool):
    """Indexed form: the codebook gather happens HERE (one-hot matmul —
    exact, since each output row sums one selected codeword and zeros).
    cg_ref (VMEM scratch) caches the gathered candidates across the L
    iterations for the final `c +` add; v_ref (VMEM scratch) carries the
    activations. Neither ever reaches HBM."""
    if has_proj:
        (idx_ref, cbk_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref, ip_ref,
         op_ref, out_ref, v_ref, cg_ref) = refs
    else:
        (idx_ref, cbk_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref,
         out_ref, v_ref, cg_ref) = refs
    l = pl.program_id(1)
    tn, A, de = v_ref.shape
    d = out_ref.shape[-1]

    @pl.when(l == 0)
    def _gather_concat_in():                              # Eq. 10-11
        idx = idx_ref[...].astype(jnp.int32)              # (TN, A)
        c = stepnet.onehot_gather(idx.reshape(tn * A),
                                  cbk_ref[...])           # (TN*A, d)
        cg_ref[...] = c.reshape(tn, A, d)
        c_emb = c @ ip_ref[...] if has_proj else c
        xb = jnp.broadcast_to(x_ref[...][:, None, :],
                              (tn, A, d)).reshape(tn * A, d)
        v = stepnet.concat_in(c_emb, xb, cw_ref[...], cb_ref[...])
        v_ref[...] = v.reshape(tn, A, de)

    v = v_ref[...].reshape(tn * A, de)                    # Eq. 12
    v = stepnet.residual_block(v, w1_ref[0], w2_ref[0])
    v_ref[...] = v.reshape(tn, A, de)

    @pl.when(l == L - 1)
    def _out():                                           # Eq. 13
        vL = v_ref[...].reshape(tn * A, de)
        out = stepnet.out_add(cg_ref[...].reshape(tn * A, d), vL,
                              op_ref[...] if has_proj else None)
        out_ref[...] = out.reshape(tn, A, d)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def f_theta_gather(idx, codebook, x, concat_w, concat_b, w1, w2,
                   in_proj=None, out_proj=None, *, tile_n: int = 8,
                   interpret: bool = True):
    """idx: (N, A) int (uint8 packed or int32); codebook: (K, d);
    x: (N, d) xhat rows, shared across each row's A expansions
    -> (N, A, d) = f_theta(codebook[idx], x[:, None, :])."""
    N, A = idx.shape
    K, d = codebook.shape
    L, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    if idx.dtype != jnp.uint8:       # packed bytes stay bytes on the wire
        idx = idx.astype(jnp.int32)
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))    # pad index 0: valid row,
        x = jnp.pad(x, ((0, pad), (0, 0)))        # output sliced off below
    Np = N + pad
    ins = [idx, codebook, x, concat_w, concat_b.reshape(1, de), w1, w2]
    in_specs = [
        pl.BlockSpec((tile_n, A), lambda ni, li: (ni, 0)),
        pl.BlockSpec((K, d), lambda ni, li: (0, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    out = pl.pallas_call(
        functools.partial(_f_theta_gather_kernel, L=L, has_proj=has_proj),
        grid=(Np // tile_n, L),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_n, A, d), lambda ni, li: (ni, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, A, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_n, A, de), jnp.float32),
            pltpu.VMEM((tile_n, A, d), jnp.float32),
        ],
        interpret=interpret,
    )(*ins)
    return out[:N]


# ---------------------------------------------------------------------------
# Full beam step: expansion + in-VMEM scoring + top-B selection
# ---------------------------------------------------------------------------


def _f_theta_err_kernel(*refs, L: int, B: int, A: int, has_proj: bool):
    """`_f_theta_gather_kernel` extended through the rest of the beam step:
    at l == L - 1 the candidate add, the squared error against the target
    x, the invalid-beam mask, and the top-B selection over the B*A
    expansions all happen on the VMEM-resident tile. The winning rows are
    recovered with a one-hot matmul (exact: one selected row plus zeros),
    so the (tile, B*A, d) expansion never leaves the kernel."""
    if has_proj:
        (idx_ref, cbk_ref, xh_ref, x_ref, err_ref, cw_ref, cb_ref, w1_ref,
         w2_ref, ip_ref, op_ref, oerr_ref, oidx_ref, oxh_ref,
         v_ref, cg_ref) = refs
    else:
        (idx_ref, cbk_ref, xh_ref, x_ref, err_ref, cw_ref, cb_ref, w1_ref,
         w2_ref, oerr_ref, oidx_ref, oxh_ref, v_ref, cg_ref) = refs
    l = pl.program_id(1)
    tn, E, de = v_ref.shape                               # E = B * A
    d = x_ref.shape[-1]

    @pl.when(l == 0)
    def _gather_concat_in():                              # Eq. 10-11
        idx = idx_ref[...].astype(jnp.int32)              # (TN, E)
        c = stepnet.onehot_gather(idx.reshape(tn * E),
                                  cbk_ref[...])           # (TN*E, d)
        cg_ref[...] = c.reshape(tn, E, d)
        c_emb = c @ ip_ref[...] if has_proj else c
        xb = jnp.broadcast_to(xh_ref[...][:, :, None, :],
                              (tn, B, A, d)).reshape(tn * E, d)
        v = stepnet.concat_in(c_emb, xb, cw_ref[...], cb_ref[...])
        v_ref[...] = v.reshape(tn, E, de)

    v = v_ref[...].reshape(tn * E, de)                    # Eq. 12
    v = stepnet.residual_block(v, w1_ref[0], w2_ref[0])
    v_ref[...] = v.reshape(tn, E, de)

    @pl.when(l == L - 1)
    def _score_select():                                  # Eq. 13 + Fig. 2
        vL = v_ref[...].reshape(tn * E, de)
        f_out = stepnet.out_add(
            cg_ref[...].reshape(tn * E, d), vL,
            op_ref[...] if has_proj else None).reshape(tn, E, d)
        xb = jnp.broadcast_to(xh_ref[...][:, :, None, :],
                              (tn, B, A, d)).reshape(tn, E, d)
        new_xhat = xb + f_out                             # (tn, E, d)
        err = jnp.sum(jnp.square(x_ref[...][:, None, :] - new_xhat),
                      axis=-1)                            # (tn, E)
        # expansions of not-yet-populated beams must not be selectable
        invalid = jnp.isinf(err_ref[...])                 # (tn, B)
        err = jnp.where(jnp.broadcast_to(invalid[:, :, None],
                                         (tn, B, A)).reshape(tn, E),
                        jnp.inf, err)
        vals, args = masked_topk(-err, B)                 # (tn, B)
        oerr_ref[...] = -vals
        oidx_ref[...] = args
        sel = (args[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tn, B, E), 2)).astype(jnp.float32)
        oxh_ref[...] = jax.lax.dot_general(
            sel, new_xhat, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # (tn, B, d)


@functools.partial(jax.jit, static_argnames=("B", "tile_n", "interpret"))
def f_theta_err(idx, codebook, xhat, x, err, concat_w, concat_b, w1, w2,
                in_proj=None, out_proj=None, *, B: int, tile_n: int = 8,
                interpret: bool = True):
    """idx: (N, B*A) int (uint8 packed or int32); codebook: (K, d);
    xhat: (N, B, d) beam reconstructions; x: (N, d) targets; err: (N, B)
    beam errors (+inf = unpopulated slot) ->
    (sel_err (N, B) f32, sel_flat (N, B) int32 indices into B*A,
    sel_xhat (N, B, d) f32) — the beam step's flat top-B, bit-identical
    to the unfused f_theta / error / `lax.top_k` composite."""
    N, E = idx.shape
    A = E // B
    K, d = codebook.shape
    L, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    if idx.dtype != jnp.uint8:       # packed bytes stay bytes on the wire
        idx = idx.astype(jnp.int32)
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))    # pad index 0: valid row,
        xhat = jnp.pad(xhat, ((0, pad), (0, 0), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))        # outputs sliced off below
        err = jnp.pad(err, ((0, pad), (0, 0)))
    Np = N + pad
    ins = [idx, codebook, xhat, x, err, concat_w, concat_b.reshape(1, de),
           w1, w2]
    in_specs = [
        pl.BlockSpec((tile_n, E), lambda ni, li: (ni, 0)),
        pl.BlockSpec((K, d), lambda ni, li: (0, 0)),
        pl.BlockSpec((tile_n, B, d), lambda ni, li: (ni, 0, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((tile_n, B), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    sel_err, sel_flat, sel_xhat = pl.pallas_call(
        functools.partial(_f_theta_err_kernel, L=L, B=B, A=A,
                          has_proj=has_proj),
        grid=(Np // tile_n, L),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tile_n, B), lambda ni, li: (ni, 0)),
            pl.BlockSpec((tile_n, B), lambda ni, li: (ni, 0)),
            pl.BlockSpec((tile_n, B, d), lambda ni, li: (ni, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, B), jnp.float32),
            jax.ShapeDtypeStruct((Np, B), jnp.int32),
            jax.ShapeDtypeStruct((Np, B, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_n, E, de), jnp.float32),
            pltpu.VMEM((tile_n, E, d), jnp.float32),
        ],
        interpret=interpret,
    )(*ins)
    return sel_err[:N], sel_flat[:N], sel_xhat[:N]
