"""Fused QINCo step-network kernels (paper Eq. 10-13).

`resmlp_chain` is the bare residual chain (Eq. 12): v <- v + relu(v @
w1_l) @ w2_l for l = 0..L-1 without writing the intermediate v to HBM
between blocks. The grid is (N_tiles, L) with L as the innermost
(sequential on TPU) dimension, the activation tile stays resident in a
revisited VMEM block across the L iterations, and only the two (de, dh)
weight slices stream in per step.

`f_theta_fused` / `f_theta_gather` extend the same schedule to the FULL
step network f_theta: the optional in-projection, the concat-projection
input stage (Eq. 11), the L residual blocks, and the optional
out-projection + candidate add (Eq. 13) all execute inside one
`pallas_call` — the pre-stage fires at l == 0, the post-stage at
l == L - 1, and the (tile, de) activation never round-trips HBM in
between. `f_theta_gather` additionally performs the codebook gather
in-kernel as a one-hot MXU matmul (exact: one selected row plus zeros),
so the beam-search expansion ships (N*B, A) indices — packed uint8 stays
uint8 across HBM -> VMEM — instead of the (N, B, A, d) candidate tensor.

This is the decoder hot loop: QINCo2 search re-ranking decodes n_short
candidates per query, and encoding runs A*B f_theta evaluations per
vector per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, w1_ref, w2_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = v_ref[...]

    v = out_ref[...].astype(jnp.float32)                  # (TN, de)
    w1 = w1_ref[0].astype(jnp.float32)                    # (de, dh)
    w2 = w2_ref[0].astype(jnp.float32)                    # (dh, de)
    h = jnp.maximum(jax.lax.dot_general(
        v, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32), 0.0)
    out_ref[...] = (v + jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def resmlp_chain(v, w1, w2, *, tile_n: int = 256, interpret: bool = True):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de) -> (N, de)."""
    N, de = v.shape
    L, _, dh = w1.shape
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    Np = N + pad
    out = pl.pallas_call(
        _kernel,
        grid=(Np // tile_n, L),
        in_specs=[
            pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
            pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
            pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, de), v.dtype),
        interpret=interpret,
    )(v, w1, w2)
    return out[:N]


# ---------------------------------------------------------------------------
# Full step network f_theta, fused end to end
# ---------------------------------------------------------------------------


def _f_theta_kernel(*refs, L: int, has_proj: bool):
    """Gathered form: candidates already materialized as (TN, d) rows.

    v_ref is a VMEM scratch buffer carrying the activation across the
    sequential L iterations of one row tile (scratch persists across grid
    steps on TPU); it never reaches HBM."""
    if has_proj:
        (c_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref, ip_ref, op_ref,
         out_ref, v_ref) = refs
    else:
        (c_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref,
         out_ref, v_ref) = refs
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _concat_in():                                     # Eq. 10-11
        c = c_ref[...]
        c_emb = c @ ip_ref[...] if has_proj else c
        cat = jnp.concatenate([c_emb, x_ref[...]], axis=-1)
        v_ref[...] = c_emb + cat @ cw_ref[...] + cb_ref[...]

    v = v_ref[...]                                        # Eq. 12
    v_ref[...] = v + jax.nn.relu(v @ w1_ref[0]) @ w2_ref[0]

    @pl.when(l == L - 1)
    def _out():                                           # Eq. 13
        vL = v_ref[...]
        out_ref[...] = c_ref[...] + (vL @ op_ref[...] if has_proj else vL)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def f_theta_fused(c, x, concat_w, concat_b, w1, w2, in_proj=None,
                  out_proj=None, *, tile_n: int = 128,
                  interpret: bool = True):
    """c, x: (N, d) flattened candidate/xhat rows -> (N, d). Callers own
    the broadcast/flatten; padding happens here (padded rows sliced off).
    """
    N, d = c.shape
    L, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Np = N + pad
    ins = [c, x, concat_w, concat_b.reshape(1, de), w1, w2]
    in_specs = [
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    out = pl.pallas_call(
        functools.partial(_f_theta_kernel, L=L, has_proj=has_proj),
        grid=(Np // tile_n, L),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_n, de), jnp.float32)],
        interpret=interpret,
    )(*ins)
    return out[:N]


def _f_theta_gather_kernel(*refs, L: int, has_proj: bool):
    """Indexed form: the codebook gather happens HERE (one-hot matmul —
    exact, since each output row sums one selected codeword and zeros).
    cg_ref (VMEM scratch) caches the gathered candidates across the L
    iterations for the final `c +` add; v_ref (VMEM scratch) carries the
    activations. Neither ever reaches HBM."""
    if has_proj:
        (idx_ref, cbk_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref, ip_ref,
         op_ref, out_ref, v_ref, cg_ref) = refs
    else:
        (idx_ref, cbk_ref, x_ref, cw_ref, cb_ref, w1_ref, w2_ref,
         out_ref, v_ref, cg_ref) = refs
    l = pl.program_id(1)
    tn, A, de = v_ref.shape
    d = out_ref.shape[-1]

    @pl.when(l == 0)
    def _gather_concat_in():                              # Eq. 10-11
        idx = idx_ref[...].astype(jnp.int32)              # (TN, A)
        K = cbk_ref.shape[0]
        kio = jax.lax.broadcasted_iota(jnp.int32, (tn * A, K), 1)
        onehot = (idx.reshape(tn * A)[:, None] == kio).astype(jnp.float32)
        c = onehot @ cbk_ref[...]                         # (TN*A, d)
        cg_ref[...] = c.reshape(tn, A, d)
        c_emb = c @ ip_ref[...] if has_proj else c
        xb = jnp.broadcast_to(x_ref[...][:, None, :],
                              (tn, A, d)).reshape(tn * A, d)
        v = c_emb + jnp.concatenate([c_emb, xb], axis=-1) @ cw_ref[...] \
            + cb_ref[...]
        v_ref[...] = v.reshape(tn, A, de)

    v = v_ref[...].reshape(tn * A, de)                    # Eq. 12
    v = v + jax.nn.relu(v @ w1_ref[0]) @ w2_ref[0]
    v_ref[...] = v.reshape(tn, A, de)

    @pl.when(l == L - 1)
    def _out():                                           # Eq. 13
        vL = v_ref[...].reshape(tn * A, de)
        f = vL @ op_ref[...] if has_proj else vL
        out_ref[...] = cg_ref[...] + f.reshape(tn, A, d)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def f_theta_gather(idx, codebook, x, concat_w, concat_b, w1, w2,
                   in_proj=None, out_proj=None, *, tile_n: int = 8,
                   interpret: bool = True):
    """idx: (N, A) int (uint8 packed or int32); codebook: (K, d);
    x: (N, d) xhat rows, shared across each row's A expansions
    -> (N, A, d) = f_theta(codebook[idx], x[:, None, :])."""
    N, A = idx.shape
    K, d = codebook.shape
    L, de, dh = w1.shape[0], w1.shape[1], w1.shape[2]
    has_proj = in_proj is not None
    if idx.dtype != jnp.uint8:       # packed bytes stay bytes on the wire
        idx = idx.astype(jnp.int32)
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))    # pad index 0: valid row,
        x = jnp.pad(x, ((0, pad), (0, 0)))        # output sliced off below
    Np = N + pad
    ins = [idx, codebook, x, concat_w, concat_b.reshape(1, de), w1, w2]
    in_specs = [
        pl.BlockSpec((tile_n, A), lambda ni, li: (ni, 0)),
        pl.BlockSpec((K, d), lambda ni, li: (0, 0)),
        pl.BlockSpec((tile_n, d), lambda ni, li: (ni, 0)),
        pl.BlockSpec((d + de, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de), lambda ni, li: (0, 0)),
        pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
        pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
    ]
    if has_proj:
        ins += [in_proj, out_proj]
        in_specs += [
            pl.BlockSpec((d, de), lambda ni, li: (0, 0)),
            pl.BlockSpec((de, d), lambda ni, li: (0, 0)),
        ]
    out = pl.pallas_call(
        functools.partial(_f_theta_gather_kernel, L=L, has_proj=has_proj),
        grid=(Np // tile_n, L),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_n, A, d), lambda ni, li: (ni, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, A, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_n, A, de), jnp.float32),
            pltpu.VMEM((tile_n, A, d), jnp.float32),
        ],
        interpret=interpret,
    )(*ins)
    return out[:N]
