"""Fused QINCo residual-MLP chain (paper Eq. 12).

Evaluates v <- v + relu(v @ w1_l) @ w2_l for l = 0..L-1 without writing the
intermediate v to HBM between blocks: the grid is (N_tiles, L) with L as the
innermost (sequential on TPU) dimension, the activation tile stays resident
in the output VMEM block across the L iterations, and only the two (de, dh)
weight slices stream in per step.

This is the decoder hot loop: QINCo2 search re-ranking calls it n_short
times per query, and encoding calls it A*B times per vector per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, w1_ref, w2_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = v_ref[...]

    v = out_ref[...].astype(jnp.float32)                  # (TN, de)
    w1 = w1_ref[0].astype(jnp.float32)                    # (de, dh)
    w2 = w2_ref[0].astype(jnp.float32)                    # (dh, de)
    h = jnp.maximum(jax.lax.dot_general(
        v, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32), 0.0)
    out_ref[...] = (v + jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def resmlp_chain(v, w1, w2, *, tile_n: int = 256, interpret: bool = True):
    """v: (N, de); w1: (L, de, dh); w2: (L, dh, de) -> (N, de)."""
    N, de = v.shape
    L, _, dh = w1.shape
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    Np = N + pad
    out = pl.pallas_call(
        _kernel,
        grid=(Np // tile_n, L),
        in_specs=[
            pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
            pl.BlockSpec((1, de, dh), lambda ni, li: (li, 0, 0)),
            pl.BlockSpec((1, dh, de), lambda ni, li: (li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, de), lambda ni, li: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, de), v.dtype),
        interpret=interpret,
    )(v, w1, w2)
    return out[:N]
