"""Fused L2-distance + top-A pre-selection kernel (paper Eq. 6, L_s = 0).

The QINCo2 encoder calls this K->A shortlist once per (step x beam): it is
the inner loop of Q_QI-A/Q_QI-B. Fusing the distance matmul with iterative
top-A selection keeps the (TILE_N, K) distance block in VMEM — the (N, K)
distance matrix never reaches HBM.

Tiling: grid over N; per tile the codebook (K, d) and its squared norms are
resident in VMEM (K=256, d<=768 -> <=0.8 MB), distances computed on the MXU
via r @ cb^T, then A masked argmins on the VPU (`beam_topk.masked_topk`,
the shared selection primitive of every fused-shortlist kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.beam_topk import masked_topk


def _kernel(r_ref, cb_ref, cb2_ref, idx_ref, d2_ref, *, A: int):
    r = r_ref[...].astype(jnp.float32)                   # (TN, d)
    cb = cb_ref[...].astype(jnp.float32)                 # (K, d)
    cb2 = cb2_ref[...].astype(jnp.float32)               # (1, K)
    d2 = (jnp.sum(r * r, axis=1, keepdims=True)
          - 2.0 * jax.lax.dot_general(
              r, cb, (((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32)
          + cb2)                                         # (TN, K)
    vals, args = masked_topk(-d2, A)                     # ascending d2
    idx_ref[...] = args
    d2_ref[...] = -vals


@functools.partial(jax.jit, static_argnames=("A", "tile_n", "interpret"))
def l2_topk(r, cb, A: int, *, tile_n: int = 256, interpret: bool = True):
    """r: (N, d); cb: (K, d) -> (idx (N, A) int32, d2 (N, A)) ascending."""
    N, d = r.shape
    K = cb.shape[0]
    tile_n = min(tile_n, N)
    pad = (-N) % tile_n
    if pad:
        r = jnp.pad(r, ((0, pad), (0, 0)))
    Np = N + pad
    cb2 = jnp.sum(cb.astype(jnp.float32) ** 2, -1)[None]  # (1, K)
    idx, d2 = pl.pallas_call(
        functools.partial(_kernel, A=A),
        grid=(Np // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((K, d), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, A), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, A), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, A), jnp.int32),
            jax.ShapeDtypeStruct((Np, A), jnp.float32),
        ],
        interpret=interpret,
    )(r, cb, cb2)
    return idx[:N], d2[:N]
