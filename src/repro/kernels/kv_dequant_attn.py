"""Fused dequantize + flash-decode attention over an RQ-compressed KV cache
(BEYOND-PAPER; pairs with core/kv_quant.py).

One query step attends a cache stored as uint8 RQ codes. Per (batch, kv-head)
program, the grid walks T in tiles; each tile:
    1. dequantizes K and V codes with the one-hot MXU trick
       (codes (TT, Mq) -> onehot (TT, Mq*Kq) @ cb_flat (Mq*Kq, D)),
    2. scores q . k^T and updates an online-softmax accumulator
       (running max / denominator / weighted V in VMEM scratch).
The dequantized cache tile lives only in VMEM: HBM traffic is the *codes*
(64x smaller than bf16 K/V at Mq=4, D=128), which is the whole point — the
decode roofline is HBM-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant(codes, cb_flat, Kq: int):
    """codes: (TT, Mq) int32; cb_flat: (Mq*Kq, D) -> (TT, D).

    One-hot over the flattened (Mq*Kq) axis, built from an iota comparison
    (no gather), then a single MXU matmul summing the Mq codeword reads."""
    tt, Mq = codes.shape
    kio = jax.lax.broadcasted_iota(jnp.int32, (tt, Mq, Kq), 2)
    onehot = (jnp.broadcast_to(codes[:, :, None], (tt, Mq, Kq)) == kio)
    onehot = onehot.astype(jnp.float32).reshape(tt, Mq * Kq)
    return jax.lax.dot_general(onehot, cb_flat.astype(jnp.float32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _kernel(q_ref, ck_ref, cv_ref, cbk_ref, cbv_ref, mask_ref, out_ref,
            m_scr, l_scr, acc_scr, *, Kq: int, nT: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    scale = q.shape[-1] ** -0.5
    codes_k = ck_ref[0, :, 0].astype(jnp.int32)            # (TT, Mq)
    codes_v = cv_ref[0, :, 0].astype(jnp.int32)
    cbk = cbk_ref[0].reshape(-1, q.shape[-1])              # (Mq*Kq, D)
    cbv = cbv_ref[0].reshape(-1, q.shape[-1])
    k = _dequant(codes_k, cbk, Kq)                         # (TT, D)
    v = _dequant(codes_v, cbv, Kq)
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, TT)
    s = s + mask_ref[...]                                  # (1, TT) 0/-inf

    m_prev = m_scr[...]                                    # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                 # (G, TT)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (G, D)
    m_scr[...] = m_new

    @pl.when(t == nT - 1)
    def _fini():
        out_ref[0, 0] = (acc_scr[...]
                         / jnp.maximum(l_scr[...], 1e-30)).astype(
            out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_t", "interpret"))
def kv_dequant_attn(q, codes_k, codes_v, cb_k, cb_v, valid_len, *,
                    tile_t: int = 512, interpret: bool = True):
    """q: (B, KVH, G, D); codes_*: (B, T, KVH, Mq); cb_*: (KVH, Mq, Kq, D);
    valid_len: int32 scalar. Returns (B, KVH, G, D)."""
    B, KVH, G, D = q.shape
    _, T, _, Mq = codes_k.shape
    Kq = cb_k.shape[2]
    tile_t = min(tile_t, T)
    pad = (-T) % tile_t
    if pad:
        codes_k = jnp.pad(codes_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        codes_v = jnp.pad(codes_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nT = Tp // tile_t
    mask = jnp.where(jnp.arange(Tp) < valid_len, 0.0, NEG_INF)[None]  # (1,Tp)
    grid = (B * KVH, nT)
    out = pl.pallas_call(
        functools.partial(_kernel, Kq=Kq, nT=nT),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda bh, t: (bh // KVH, bh % KVH, 0, 0)),
            pl.BlockSpec((1, tile_t, 1, Mq),
                         lambda bh, t: (bh // KVH, t, bh % KVH, 0)),
            pl.BlockSpec((1, tile_t, 1, Mq),
                         lambda bh, t: (bh // KVH, t, bh % KVH, 0)),
            pl.BlockSpec((1, Mq, Kq, D), lambda bh, t: (bh % KVH, 0, 0, 0)),
            pl.BlockSpec((1, Mq, Kq, D), lambda bh, t: (bh % KVH, 0, 0, 0)),
            pl.BlockSpec((1, tile_t), lambda bh, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda bh, t: (bh // KVH, bh % KVH, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, codes_k.astype(jnp.int32), codes_v.astype(jnp.int32), cb_k, cb_v,
      mask)
    return out
