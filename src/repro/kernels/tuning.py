"""Per-op tile-size table: the single place TPU autotuning writes results.

Every Pallas entry point in `kernels/ops.py` resolves its tile sizes here
when the caller does not pass them explicitly (explicit arguments always
win — the parity tests sweep odd tiles that way). The table replaces the
hardcoded ``tile_q=min(tile_q, 8)``-style constants that used to live at
each call site, so a native-TPU tuning sweep has ONE artifact to produce:

    table = autotune("adc_scores", {"tile_q": (32, 64), "tile_n": (256,
                     512)}, bench_fn)
    save("tiles.json")            # ship next to the index store
    ...
    load("tiles.json")            # serving / builder startup

The defaults are the interpret-mode-validated shapes that also respect the
TPU layout floors (lane dim 128, sublane 8); they are intentionally
conservative — real MXU numbers should overwrite them via `set_tiles`.
"""
from __future__ import annotations

import contextlib
import itertools
import json
from typing import Callable, Dict, Iterable, Mapping

DEFAULTS: Dict[str, Dict[str, int]] = {
    "l2_topk":            {"tile_n": 256},
    "adc_scores":         {"tile_q": 64, "tile_n": 256},
    "adc_scores_batched": {"tile_q": 8, "tile_c": 256},
    "adc_topk":           {"tile_q": 64, "tile_n": 256},
    "resmlp_chain":       {"tile_n": 256},
    "f_theta":            {"tile_n": 128},
    "f_theta_gather":     {"tile_n": 8},
    "f_theta_err":        {"tile_n": 8},
    "preselect_topk":     {"tile_n": 8},
    "kv_dequant_attn":    {"tile_t": 512},
}

_table: Dict[str, Dict[str, int]] = {op: dict(v) for op, v in
                                     DEFAULTS.items()}


def tile(op: str, name: str, override=None) -> int:
    """Resolve one tile size: explicit caller value > table > error."""
    if override is not None:
        return override
    try:
        return _table[op][name]
    except KeyError:
        raise KeyError(f"no tile entry {op!r}/{name!r}; known ops: "
                       f"{sorted(_table)}") from None


def tiles(op: str) -> Dict[str, int]:
    return dict(_table[op])


def set_tiles(op: str, **sizes: int) -> None:
    """Overwrite entries for ``op`` (autotuning writes through here)."""
    if op not in _table:
        raise KeyError(f"unknown op {op!r}; known ops: {sorted(_table)}")
    for name, v in sizes.items():
        if name not in _table[op]:
            raise KeyError(f"op {op!r} has no tile parameter {name!r} "
                           f"(has {sorted(_table[op])})")
        if not isinstance(v, int) or v < 1:
            raise ValueError(f"{op}/{name}: tile sizes are positive ints, "
                             f"got {v!r}")
        _table[op][name] = v


def reset() -> None:
    """Restore the built-in defaults (tests use this to stay hermetic)."""
    for op, v in DEFAULTS.items():
        _table[op] = dict(v)


@contextlib.contextmanager
def overridden(op: str, **sizes: int):
    """Scoped `set_tiles` — restores the previous entries on exit."""
    prev = tiles(op)
    set_tiles(op, **sizes)
    try:
        yield
    finally:
        _table[op] = prev


def save(path) -> None:
    with open(path, "w") as f:
        json.dump(_table, f, indent=2, sort_keys=True)


def load(path) -> Dict[str, Dict[str, int]]:
    """Merge a tuning artifact into the live table (unknown ops/params are
    rejected — a stale artifact should fail loudly, not half-apply):
    every entry is validated BEFORE any is written, so a bad artifact
    leaves the table untouched."""
    with open(path) as f:
        data = json.load(f)
    for op, sizes in data.items():          # validate-only pass, raw values
        if op not in _table:
            raise KeyError(f"unknown op {op!r} in {path}; known ops: "
                           f"{sorted(_table)}")
        for name, v in sizes.items():
            if name not in _table[op]:
                raise KeyError(f"op {op!r} has no tile parameter "
                               f"{name!r} in {path}")
            if isinstance(v, bool) or not isinstance(v, int) or v < 1:
                raise ValueError(f"{op}/{name} in {path}: tile sizes are "
                                 f"positive ints, got {v!r}")
    for op, sizes in data.items():
        set_tiles(op, **sizes)
    return tiles_all()


def tiles_all() -> Dict[str, Dict[str, int]]:
    return {op: dict(v) for op, v in _table.items()}


def autotune(op: str, candidates: Mapping[str, Iterable[int]],
             bench_fn: Callable[..., float], *, reps: int = 3) -> Dict:
    """Grid-sweep ``candidates`` (param -> sizes), timing ``bench_fn``
    (called with the tile kwargs, returns seconds) and write the argmin
    into the table. Returns {"best": {...}, "results": [...]}."""
    names = sorted(candidates)
    results = []
    for combo in itertools.product(*(candidates[n] for n in names)):
        kw = dict(zip(names, combo))
        t = min(bench_fn(**kw) for _ in range(reps))
        results.append({"tiles": kw, "seconds": t})
    best = min(results, key=lambda r: r["seconds"])
    set_tiles(op, **best["tiles"])
    return {"best": best["tiles"], "results": results}
