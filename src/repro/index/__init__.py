"""Persistent packed-code index subsystem (paper §3.3 at storage scale).

    codes.py    PackedCodes: uint8 code container + pack/unpack helpers
    store.py    on-disk sharded index format (manifest + mmap shards,
                per-file checksum sidecars) + ShardedIndexView, the
                out-of-core reader (pool-staged shards with integrity
                verification + quarantine, `core/search.search_sharded`
                consumes it)
    staging.py  StagingPool: shared byte-budgeted device LRU with
                background prefetch + host cache of assembled shards,
                transient-read retries, worker resurrection
    builder.py  resumable streaming build driver (shard cursor), with
                data-axis shard-range ownership for multi-host builds;
                checksum-failing shards are rewritten at resume; its
                `encode_rows` is also what `IndexStore.append` seals
                delta shards through
    compact.py  Compactor: folds delta shards + tombstones into a new
                base-shard generation, byte-identical to a fresh build
                of the survivors (atomic manifest swap, resume cursor,
                unlink deferred to gc_orphans)
    faults.py   FaultPlan: seeded deterministic fault injection (read
                errors, latency, bit flips, worker death) for chaos
                tests and the CI chaos smoke
    fsck.py     `python -m repro.index.fsck`: whole-store integrity audit

The layer that turns the kernel path (`kernels/ops`) into a servable
system: codes live as packed bytes on disk AND in HBM, stores round-trip
`SearchIndex` bit-identically, interrupted billion-vector builds resume
mid-dataset, and serving degrades gracefully (skip + coverage, not
crash) when the storage layer misbehaves.
"""
from repro.index.builder import (StreamingIndexBuilder,  # noqa: F401
                                 encode_rows, owner_range)
from repro.index.codes import (CODE_DTYPE, PackedCodes,  # noqa: F401
                               pack_codes, unpack_codes)
from repro.index.compact import Compactor  # noqa: F401
from repro.index.faults import (FaultPlan,  # noqa: F401
                                TransientReadError, corrupt_file,
                                parse_chaos)
from repro.index.fsck import fsck_store  # noqa: F401
from repro.index.staging import StagingPool  # noqa: F401
from repro.index.store import (FORMAT_VERSION,  # noqa: F401
                               MUTATED_FORMAT_VERSION, IndexStore,
                               ShardIntegrityError, ShardedIndexView)
