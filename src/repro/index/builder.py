"""Resumable streaming index builder (the billion-vector encode driver).

Two phases:

  `prepare`  — fit phase, run once: IVF centroids (kmeans on a training
               sample), AQ + pairwise cascade decoders fit on the sample's
               codes, everything persisted as the store's global state.
               Idempotent: re-running against an initialized store is a
               no-op, so a restarted job just falls through to `build`.

  `build`    — stream phase: walks the database shard by shard. Each shard
               is coarse-assigned (with capacity spill continued across
               shards via the running fill counts), encoded through the
               chunked `encode_dataset` driver (double-buffered host<->
               device staging), scored for cascade norms, and written
               atomically. A cursor (next shard + fill counts) is
               persisted after every shard, so a killed build restarts
               mid-dataset instead of from zero — and produces the SAME
               index an uninterrupted run would: shard content depends
               only on (global state, shard slice, fill-at-shard-entry),
               all of which resume deterministically.

Data-axis sharding (``host_id`` / ``n_hosts``): the stream phase splits
the shard sequence into contiguous ownership ranges (`owner_range`), one
per host, all writing disjoint shard files into ONE store. The capacity
spill is a sequential scan over the whole stream, so each owner derives
the fill state at its range entry by walking the shards before it —
bincounting `assign.i32` when the shard is already on disk, re-running
the (cheap, encode-free) assignment otherwise. Both give the same counts,
so every owner sees the fill an uninterrupted single-process scan would,
and a multi-process build produces BYTE-IDENTICAL shards to a
single-process one. Each owner persists its own cursor
(`cursor_<owner>.json`; owner 0 keeps `cursor.json`) and resumes
independently; whichever owner writes the last missing shard finalizes.

Hook `checkpoint.manager.PreemptionGuard` in via ``guard=`` to turn
SIGTERM into a clean stop at the next shard edge.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.qinco2 import QincoConfig
from repro.core import aq as aq_mod
from repro.core import encode as enc
from repro.core import ivf as ivf_mod
from repro.core import pairwise as pw_mod
from repro.core.kmeans import kmeans
from repro.core import rq as rq_mod
from repro.index.codes import PackedCodes, pack_codes
from repro.index.store import IndexStore, ShardIntegrityError

# build-progress telemetry: long encode jobs expose how far along they
# are (and whether a restart resumed mid-build) without log scraping
_C_SHARDS_SEALED = obs.counter(
    "build_shards_sealed_total", "shards encoded + written to the store")
_C_ROWS = obs.counter("build_rows_total", "database rows encoded")
_C_RESUMES = obs.counter(
    "build_resume_events_total", "builds resumed from a mid-build cursor")
_C_CORRUPT_RESUME = obs.counter(
    "build_corrupt_shards_total",
    "corrupt shards detected at resume and scheduled for rewrite")
_G_ROWS_PER_S = obs.gauge(
    "build_rows_per_s", "encode throughput over the last sealed shard")


def encode_rows(x, global_tree, cfg: QincoConfig, fill, cap: int, *,
                encode_chunk: int = 4096, backend: str = "auto"):
    """The per-shard encode pipeline as a standalone function: coarse
    assignment (continuing the running capacity-spill ``fill``), chunked
    QINCo2 encoding, and both cascade norms, all derived from the store's
    ``global_tree`` (centroids, AQ/pairwise codebooks, QINCo2 params).

    This is THE one implementation `StreamingIndexBuilder.build` runs per
    shard — and the one `IndexStore.append` encodes delta shards through,
    so appended rows get byte-wise the codes a streaming build of the same
    rows at the same fill state would produce (shard content depends only
    on (global state, row block, fill-at-entry)).

    Returns (packed_codes (n, M) uint8, assign (n,) int32,
    aq_norms (n,) f32, pw_norms (n,) f32, updated fill).
    """
    x = np.ascontiguousarray(np.asarray(x, np.float32))
    cent = np.asarray(global_tree["centroids"])
    raw = ivf_mod.assign_to_centroids(cent, x)
    assign, fill = ivf_mod.assign_with_spill(x, cent, raw, cap, fill)
    resid = x - cent[assign]
    codes, _, _ = enc.encode_dataset(
        global_tree["qinco_params"], resid, cfg, cfg.A_eval, cfg.B_eval,
        chunk=min(encode_chunk, len(resid)), backend=backend)
    codes_j = jnp.asarray(codes)
    aq_books = jnp.asarray(global_tree["aq_books"])
    recon_aq = aq_mod.aq_decode(aq_books, codes_j) + jnp.asarray(cent)[assign]
    aq_norms = jnp.sum(recon_aq * recon_aq, axis=-1)
    tilde = global_tree["centroid_codes"]
    if tilde is not None:
        ext = jnp.concatenate([codes_j, jnp.asarray(tilde)[assign]], axis=1)
    else:
        ext = codes_j
    pw = global_tree["_pw_decoder"]
    recon_pw = pw.decode(ext)
    pw_norms = jnp.sum(recon_pw * recon_pw, axis=-1)
    return (pack_codes(codes, cfg.K), assign, np.asarray(aq_norms),
            np.asarray(pw_norms), fill)


def make_pw_decoder(manifest: dict, global_tree: dict):
    """The store's pairwise decoder, and the `global_tree` augmented with
    it under the private ``_pw_decoder`` key `encode_rows` consumes."""
    pw = pw_mod.PairwiseDecoder(
        pairs=tuple(tuple(p) for p in manifest["pw_pairs"]),
        codebooks=jnp.asarray(global_tree["pw_codebooks"]),
        K=manifest["K"])
    return dict(global_tree, _pw_decoder=pw)


def owner_range(n_shards: int, host_id: int, n_hosts: int):
    """Contiguous balanced shard-ownership split: host ``host_id`` of
    ``n_hosts`` owns shards [lo, hi). Ranges partition [0, n_shards)
    exactly (remainder spread over the first hosts), so concurrent owners
    write disjoint shard files."""
    if not 0 <= host_id < n_hosts:
        raise ValueError(f"host_id {host_id} outside [0, {n_hosts})")
    base, rem = divmod(n_shards, n_hosts)
    lo = host_id * base + min(host_id, rem)
    return lo, lo + base + (1 if host_id < rem else 0)


class StreamingIndexBuilder:
    """``backend`` selects the `kernels/ops` dispatch for the whole build
    — the beam-search expansion inside `encode_dataset` runs through the
    fused `ops.f_theta` kernel on TPU. ``tile_table`` (a
    `kernels/tuning.py` JSON artifact) applies autotuned per-op tile
    sizes before the first chunk compiles."""

    def __init__(self, directory, *, shard_size: int = 1 << 16,
                 encode_chunk: int = 4096, backend: str = "auto",
                 tile_table=None, verbose: bool = False,
                 verify_resume: bool = True):
        if tile_table is not None:
            from repro.kernels import tuning
            tuning.load(tile_table)
        self.store = IndexStore(directory)
        self.shard_size = shard_size
        self.encode_chunk = encode_chunk
        self.backend = backend
        self.verbose = verbose
        self.verify_resume = bool(verify_resume)

    def _shard_intact(self, sid: int) -> bool:
        """Shard present AND passing its integrity check — the resume
        notion of "done". A checksum-failing shard is treated exactly
        like an absent one: the prefix walk stops there (so it gets
        re-encoded and atomically rewritten) and `_scan_fill` re-derives
        its assignments instead of bincounting corrupt bytes."""
        if not self.store.shard_done(sid):
            return False
        if not self.verify_resume:
            return True
        try:
            self.store.verify_shard(sid)
        except ShardIntegrityError as e:
            _C_CORRUPT_RESUME.inc()
            self._log(f"resume: shard {sid} failed integrity ({e}); "
                      f"treating as absent and rewriting")
            return False
        return True

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[index.builder] {msg}", flush=True)

    # -- phase 1: fit --------------------------------------------------------

    def prepare(self, key, sample, qinco_params, cfg: QincoConfig, *,
                n_total: int, k_ivf: int = 64, m_tilde: int = 2,
                n_pair_books: Optional[int] = None, cap_factor: float = 2.0,
                kmeans_iters: int = 10) -> None:
        """Fit IVF + cascade decoders on ``sample`` and initialize the store.

        ``n_total`` is the final database size (caps are sized for it; the
        stream phase then writes exactly ceil(n_total / shard_size) shards).
        At demonstration scale pass the whole database as the sample for
        the best decoder fit. (The fit is NOT bit-identical to
        `search.build_index`'s even then: key derivation and the
        spill-before-fit ordering differ — equivalence guarantees in this
        module are between builder runs, interrupted or not.)
        """
        from repro.index.codes import packable
        if not packable(cfg.K):       # fail BEFORE the expensive fit phase
            raise ValueError(f"streaming builds store packed uint8 codes; "
                             f"K={cfg.K} > 256 is not supported")
        if self.store.exists():
            self._log(f"store {self.store.dir} already initialized; "
                      f"resuming with its global state")
            return
        n_pair_books = n_pair_books or 2 * cfg.M
        sample = np.asarray(sample)
        k1, k2 = jax.random.split(key)

        cent, _ = kmeans(k1, jnp.asarray(sample), k_ivf, kmeans_iters)
        centroid_codes = centroid_rq_books = None
        if m_tilde > 0:
            books = rq_mod.rq_train(k2, cent, m_tilde, cfg.K)
            centroid_codes, _ = rq_mod.rq_encode(books, cent, B=4)
            centroid_rq_books = books

        # encode the sample to fit the approximate decoders on its codes
        assign = ivf_mod.assign_to_centroids(cent, sample)
        resid = sample - np.asarray(cent)[assign]
        codes, _, _ = enc.encode_dataset(
            qinco_params, resid, cfg, cfg.A_eval, cfg.B_eval,
            chunk=self.encode_chunk, backend=self.backend)
        codes = jnp.asarray(codes)
        aq_books = aq_mod.fit_aq(codes, jnp.asarray(resid), cfg.M, cfg.K)
        if m_tilde > 0:
            tilde = jnp.asarray(centroid_codes)[assign]
            ext = jnp.concatenate([codes, tilde], axis=1)
        else:
            ext = codes
        pw = pw_mod.fit_pairwise(ext, jnp.asarray(sample), cfg.K,
                                 n_pair_books, verbose=self.verbose)

        cap = ivf_mod.bucket_cap(n_total, k_ivf, cap_factor)
        global_tree = {
            "centroids": cent,
            "centroid_codes": centroid_codes,
            "centroid_rq_books": centroid_rq_books,
            "aq_books": aq_books,
            "pw_codebooks": pw.codebooks,
            "qinco_params": qinco_params,
        }
        self.store.initialize(
            cfg=cfg, global_tree=global_tree, n_total=n_total,
            shard_size=self.shard_size, k_ivf=k_ivf, cap=cap,
            pw_pairs=pw.pairs,
            extra={"m_tilde": m_tilde, "cap_factor": cap_factor,
                   "fit_sample_size": int(len(sample))})
        self._log(f"prepared store: {n_total} vectors / "
                  f"{self.store.manifest['n_shards']} shards, k_ivf={k_ivf}")

    # -- phase 2: stream -----------------------------------------------------

    def _check_db_fingerprint(self, xb) -> None:
        """Refuse to resume against a DIFFERENT same-length database.

        Shards already on disk came from the original dataset; mixing in a
        substitute would finalize a silently corrupt index. A hash of a
        few fixed rows is recorded on the first build call and verified on
        every resume."""
        import hashlib
        n = len(xb)
        probe_rows = sorted({0, n // 3, 2 * n // 3, n - 1})
        h = hashlib.sha256()
        for r in probe_rows:
            h.update(np.ascontiguousarray(
                np.asarray(xb[r], np.float32)).tobytes())
        fp = h.hexdigest()
        extra = self.store.manifest["extra"]
        if "db_fingerprint" not in extra:
            self.store.update_extra(db_fingerprint=fp)
        elif extra["db_fingerprint"] != fp:
            raise ValueError(
                f"database content mismatch: store {self.store.dir} was "
                f"built from a different dataset (fingerprint "
                f"{extra['db_fingerprint'][:12]}… != {fp[:12]}…); resuming "
                f"would produce a corrupt mixed-content index")

    def _shard_assign(self, xb, cent, sid: int, fill):
        """Deterministic coarse assignment of one shard, continuing the
        running spill fill — the cheap (encode-free) half of the shard
        pipeline. Returns (assign, x_s, updated fill)."""
        m = self.store.manifest
        lo = sid * m["shard_size"]
        x_s = np.asarray(xb[lo:lo + self.store.shard_rows(sid)], np.float32)
        raw = ivf_mod.assign_to_centroids(cent, x_s)
        assign, fill = ivf_mod.assign_with_spill(x_s, cent, raw,
                                                 m["cap"], fill)
        return assign, x_s, fill

    def _scan_fill(self, xb, cent, upto: int):
        """Running bucket-fill counts over shards [0, upto), without
        relying on any cursor: bincount the assignments already on disk
        (ground truth), re-run the deterministic assignment for shards
        another owner has not written yet. Both yield the exact fill an
        uninterrupted single-process scan would see at shard ``upto``."""
        m = self.store.manifest
        k_ivf = m["k_ivf"]
        fill = np.zeros(k_ivf, np.int64)
        for sid in range(upto):
            if self._shard_intact(sid):
                fill += np.bincount(self.store.open_shard(sid)["assign"],
                                    minlength=k_ivf)
            else:
                _, _, fill = self._shard_assign(xb, cent, sid, fill)
        return fill

    def _resume_state(self, xb, cent, lo: int, hi: int, owner: int):
        """(next_shard, fill) for one owner: next = the end of the owner's
        contiguous on-disk INTACT prefix within [lo, hi) — a shard that
        fails its checksum counts as absent, so the walk stops there and
        `build` re-encodes and atomically rewrites it; fill covers every
        shard < next (owned or not). The owner's cursor is the fast path,
        validated against the shards actually on disk (ground truth)."""
        next_sid = lo
        while next_sid < hi and self._shard_intact(next_sid):
            next_sid += 1
        cur = self.store.read_cursor(owner=owner)
        if cur is not None and cur["next_shard"] == next_sid:
            return next_sid, np.asarray(cur["fill"], np.int64)
        return next_sid, self._scan_fill(xb, cent, next_sid)

    def build(self, xb, *, guard=None, max_shards: Optional[int] = None,
              progress=None, host_id: int = 0, n_hosts: int = 1) -> bool:
        """Stream this owner's shard range of ``xb`` (array-like,
        sliceable) into the store; resume from the owner's cursor.
        Returns True when the WHOLE store is complete (an owner that
        finishes its range while others are still streaming returns
        False).

        ``host_id``/``n_hosts``: contiguous shard-range ownership
        (`owner_range`) for data-axis sharded multi-process builds; the
        default is the historical single-owner walk of every shard.
        ``guard``: a `PreemptionGuard` — checked at shard edges.
        ``max_shards``: stop after N newly-built shards (tests simulate a
        kill with this). ``progress``: optional callback(shard_id, dt_s).
        """
        store = self.store
        m = store.manifest
        if m["complete"]:
            return True
        if m.get("deltas") or m.get("tombstone") or m.get("generation"):
            raise ValueError(
                f"store {store.dir} carries mutation state (delta shards / "
                f"tombstones / a compacted generation); the streaming "
                f"builder only writes pristine v1 stores — compact first "
                f"or use IndexStore.append")
        if len(xb) != m["n_total"]:
            raise ValueError(f"database has {len(xb)} rows; store was "
                             f"initialized for {m['n_total']}")
        self._check_db_fingerprint(xb)
        lo, hi = owner_range(m["n_shards"], host_id, n_hosts)
        cfg = QincoConfig(**m["cfg"])
        g = store.load_global_tree()
        cent = np.asarray(g["centroids"])
        gt = make_pw_decoder(m, g)
        gt["aq_books"] = jnp.asarray(g["aq_books"])
        gt["qinco_params"] = jax.tree.map(jnp.asarray, g["qinco_params"])

        start, fill = self._resume_state(xb, cent, lo, hi, host_id)
        if start > lo:
            _C_RESUMES.inc()
            self._log(f"owner {host_id}: resuming at shard {start} "
                      f"(range [{lo}, {hi}))")
        elif n_hosts > 1:
            self._log(f"owner {host_id}/{n_hosts}: shards [{lo}, {hi})")
        built = 0
        for sid in range(start, hi):
            t0 = time.perf_counter()
            lo_row = sid * m["shard_size"]
            x_s = np.asarray(xb[lo_row:lo_row + store.shard_rows(sid)],
                             np.float32)
            packed, assign, aq_norms, pw_norms, fill = encode_rows(
                x_s, gt, cfg, fill, m["cap"],
                encode_chunk=self.encode_chunk, backend=self.backend)
            store.write_shard(
                sid, codes=PackedCodes(packed, m["K"]),
                assign=assign, aq_norms=aq_norms, pw_norms=pw_norms)
            store.write_cursor(sid + 1, fill, owner=host_id)
            built += 1
            dt = time.perf_counter() - t0
            _C_SHARDS_SEALED.inc()
            _C_ROWS.inc(len(x_s))
            _G_ROWS_PER_S.set(len(x_s) / max(dt, 1e-9))
            self._log(f"shard {sid + 1}/{m['n_shards']}: {len(x_s)} vectors "
                      f"in {dt:.2f}s ({len(x_s) / dt:.0f} vec/s)")
            if progress is not None:
                progress(sid, dt)
            if guard is not None and guard.should_checkpoint():
                self._log("preemption requested; stopping at shard edge")
                return sid + 1 == hi and self._maybe_finalize()
            if max_shards is not None and built >= max_shards:
                return sid + 1 == hi and self._maybe_finalize()
        return self._maybe_finalize()

    def _maybe_finalize(self) -> bool:
        """Finalize iff every shard (any owner's) is on disk. Safe to race:
        finalize is an atomic manifest rewrite of identical content."""
        m = self.store.manifest
        if m["complete"]:
            return True
        if not all(self.store.shard_done(s) for s in range(m["n_shards"])):
            return False
        self.store.finalize()
        self._log("store complete")
        return True
