"""Resumable streaming index builder (the billion-vector encode driver).

Two phases:

  `prepare`  — fit phase, run once: IVF centroids (kmeans on a training
               sample), AQ + pairwise cascade decoders fit on the sample's
               codes, everything persisted as the store's global state.
               Idempotent: re-running against an initialized store is a
               no-op, so a restarted job just falls through to `build`.

  `build`    — stream phase: walks the database shard by shard. Each shard
               is coarse-assigned (with capacity spill continued across
               shards via the running fill counts), encoded through the
               chunked `encode_dataset` driver (double-buffered host<->
               device staging), scored for cascade norms, and written
               atomically. A cursor (next shard + fill counts) is
               persisted after every shard, so a killed build restarts
               mid-dataset instead of from zero — and produces the SAME
               index an uninterrupted run would: shard content depends
               only on (global state, shard slice, fill-at-shard-entry),
               all of which resume deterministically.

Hook `checkpoint.manager.PreemptionGuard` in via ``guard=`` to turn
SIGTERM into a clean stop at the next shard edge.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qinco2 import QincoConfig
from repro.core import aq as aq_mod
from repro.core import encode as enc
from repro.core import ivf as ivf_mod
from repro.core import pairwise as pw_mod
from repro.core.kmeans import kmeans
from repro.core import rq as rq_mod
from repro.index.codes import PackedCodes, pack_codes
from repro.index.store import IndexStore


class StreamingIndexBuilder:
    """``backend`` selects the `kernels/ops` dispatch for the whole build
    — the beam-search expansion inside `encode_dataset` runs through the
    fused `ops.f_theta` kernel on TPU. ``tile_table`` (a
    `kernels/tuning.py` JSON artifact) applies autotuned per-op tile
    sizes before the first chunk compiles."""

    def __init__(self, directory, *, shard_size: int = 1 << 16,
                 encode_chunk: int = 4096, backend: str = "auto",
                 tile_table=None, verbose: bool = False):
        if tile_table is not None:
            from repro.kernels import tuning
            tuning.load(tile_table)
        self.store = IndexStore(directory)
        self.shard_size = shard_size
        self.encode_chunk = encode_chunk
        self.backend = backend
        self.verbose = verbose

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[index.builder] {msg}", flush=True)

    # -- phase 1: fit --------------------------------------------------------

    def prepare(self, key, sample, qinco_params, cfg: QincoConfig, *,
                n_total: int, k_ivf: int = 64, m_tilde: int = 2,
                n_pair_books: Optional[int] = None, cap_factor: float = 2.0,
                kmeans_iters: int = 10) -> None:
        """Fit IVF + cascade decoders on ``sample`` and initialize the store.

        ``n_total`` is the final database size (caps are sized for it; the
        stream phase then writes exactly ceil(n_total / shard_size) shards).
        At demonstration scale pass the whole database as the sample for
        the best decoder fit. (The fit is NOT bit-identical to
        `search.build_index`'s even then: key derivation and the
        spill-before-fit ordering differ — equivalence guarantees in this
        module are between builder runs, interrupted or not.)
        """
        from repro.index.codes import packable
        if not packable(cfg.K):       # fail BEFORE the expensive fit phase
            raise ValueError(f"streaming builds store packed uint8 codes; "
                             f"K={cfg.K} > 256 is not supported")
        if self.store.exists():
            self._log(f"store {self.store.dir} already initialized; "
                      f"resuming with its global state")
            return
        n_pair_books = n_pair_books or 2 * cfg.M
        sample = np.asarray(sample)
        k1, k2 = jax.random.split(key)

        cent, _ = kmeans(k1, jnp.asarray(sample), k_ivf, kmeans_iters)
        centroid_codes = centroid_rq_books = None
        if m_tilde > 0:
            books = rq_mod.rq_train(k2, cent, m_tilde, cfg.K)
            centroid_codes, _ = rq_mod.rq_encode(books, cent, B=4)
            centroid_rq_books = books

        # encode the sample to fit the approximate decoders on its codes
        assign = ivf_mod.assign_to_centroids(cent, sample)
        resid = sample - np.asarray(cent)[assign]
        codes, _, _ = enc.encode_dataset(
            qinco_params, resid, cfg, cfg.A_eval, cfg.B_eval,
            chunk=self.encode_chunk, backend=self.backend)
        codes = jnp.asarray(codes)
        aq_books = aq_mod.fit_aq(codes, jnp.asarray(resid), cfg.M, cfg.K)
        if m_tilde > 0:
            tilde = jnp.asarray(centroid_codes)[assign]
            ext = jnp.concatenate([codes, tilde], axis=1)
        else:
            ext = codes
        pw = pw_mod.fit_pairwise(ext, jnp.asarray(sample), cfg.K,
                                 n_pair_books, verbose=self.verbose)

        cap = ivf_mod.bucket_cap(n_total, k_ivf, cap_factor)
        global_tree = {
            "centroids": cent,
            "centroid_codes": centroid_codes,
            "centroid_rq_books": centroid_rq_books,
            "aq_books": aq_books,
            "pw_codebooks": pw.codebooks,
            "qinco_params": qinco_params,
        }
        self.store.initialize(
            cfg=cfg, global_tree=global_tree, n_total=n_total,
            shard_size=self.shard_size, k_ivf=k_ivf, cap=cap,
            pw_pairs=pw.pairs,
            extra={"m_tilde": m_tilde, "cap_factor": cap_factor,
                   "fit_sample_size": int(len(sample))})
        self._log(f"prepared store: {n_total} vectors / "
                  f"{self.store.manifest['n_shards']} shards, k_ivf={k_ivf}")

    # -- phase 2: stream -----------------------------------------------------

    def _check_db_fingerprint(self, xb) -> None:
        """Refuse to resume against a DIFFERENT same-length database.

        Shards already on disk came from the original dataset; mixing in a
        substitute would finalize a silently corrupt index. A hash of a
        few fixed rows is recorded on the first build call and verified on
        every resume."""
        import hashlib
        n = len(xb)
        probe_rows = sorted({0, n // 3, 2 * n // 3, n - 1})
        h = hashlib.sha256()
        for r in probe_rows:
            h.update(np.ascontiguousarray(
                np.asarray(xb[r], np.float32)).tobytes())
        fp = h.hexdigest()
        extra = self.store.manifest["extra"]
        if "db_fingerprint" not in extra:
            self.store.update_extra(db_fingerprint=fp)
        elif extra["db_fingerprint"] != fp:
            raise ValueError(
                f"database content mismatch: store {self.store.dir} was "
                f"built from a different dataset (fingerprint "
                f"{extra['db_fingerprint'][:12]}… != {fp[:12]}…); resuming "
                f"would produce a corrupt mixed-content index")

    def _resume_state(self):
        """(next_shard, fill) from the cursor, validated against the shards
        actually on disk (which are ground truth)."""
        store = self.store
        done = store.done_shards()
        cur = store.read_cursor()
        if cur is not None and cur["next_shard"] == done:
            return done, np.asarray(cur["fill"], np.int64)
        # cursor stale/missing (e.g. killed between shard rename and cursor
        # write): rebuild fill counts from the completed shards' assignments
        k_ivf = store.manifest["k_ivf"]
        fill = np.zeros(k_ivf, np.int64)
        for sid in range(done):
            fill += np.bincount(store.open_shard(sid)["assign"],
                                minlength=k_ivf)
        return done, fill

    def build(self, xb, *, guard=None, max_shards: Optional[int] = None,
              progress=None) -> bool:
        """Stream ``xb`` (array-like, sliceable) into shards; resume from
        the cursor. Returns True when the store is complete.

        ``guard``: a `PreemptionGuard` — checked at shard edges.
        ``max_shards``: stop after N newly-built shards (tests simulate a
        kill with this). ``progress``: optional callback(shard_id, dt_s).
        """
        store = self.store
        m = store.manifest
        if m["complete"]:
            return True
        if len(xb) != m["n_total"]:
            raise ValueError(f"database has {len(xb)} rows; store was "
                             f"initialized for {m['n_total']}")
        self._check_db_fingerprint(xb)
        cfg = QincoConfig(**m["cfg"])
        g = store.load_global_tree()
        cent = np.asarray(g["centroids"])
        aq_books = jnp.asarray(g["aq_books"])
        pw = pw_mod.PairwiseDecoder(
            pairs=tuple(tuple(p) for p in m["pw_pairs"]),
            codebooks=jnp.asarray(g["pw_codebooks"]), K=m["K"])
        params = jax.tree.map(jnp.asarray, g["qinco_params"])
        tilde_books = g["centroid_codes"]

        start, fill = self._resume_state()
        if start:
            self._log(f"resuming at shard {start}/{m['n_shards']}")
        built = 0
        for sid in range(start, m["n_shards"]):
            t0 = time.time()
            lo = sid * m["shard_size"]
            hi = lo + store.shard_rows(sid)
            x_s = np.asarray(xb[lo:hi], np.float32)

            raw = ivf_mod.assign_to_centroids(cent, x_s)
            assign, fill = ivf_mod.assign_with_spill(x_s, cent, raw,
                                                     m["cap"], fill)
            resid = x_s - cent[assign]
            codes, _, _ = enc.encode_dataset(
                params, resid, cfg, cfg.A_eval, cfg.B_eval,
                chunk=min(self.encode_chunk, len(resid)),
                backend=self.backend)
            codes_j = jnp.asarray(codes)

            recon_aq = (aq_mod.aq_decode(aq_books, codes_j)
                        + jnp.asarray(cent)[assign])
            aq_norms = jnp.sum(recon_aq * recon_aq, axis=-1)
            if tilde_books is not None:
                ext = jnp.concatenate(
                    [codes_j, jnp.asarray(tilde_books)[assign]], axis=1)
            else:
                ext = codes_j
            recon_pw = pw.decode(ext)
            pw_norms = jnp.sum(recon_pw * recon_pw, axis=-1)

            store.write_shard(
                sid, codes=PackedCodes(pack_codes(codes, m["K"]), m["K"]),
                assign=assign, aq_norms=np.asarray(aq_norms),
                pw_norms=np.asarray(pw_norms))
            store.write_cursor(sid + 1, fill)
            built += 1
            dt = time.time() - t0
            self._log(f"shard {sid + 1}/{m['n_shards']}: {hi - lo} vectors "
                      f"in {dt:.2f}s ({(hi - lo) / dt:.0f} vec/s)")
            if progress is not None:
                progress(sid, dt)
            if guard is not None and guard.should_checkpoint():
                self._log("preemption requested; stopping at shard edge")
                return False
            if max_shards is not None and built >= max_shards:
                return sid + 1 == m["n_shards"] and self._finalize()
        return self._finalize()

    def _finalize(self) -> bool:
        self.store.finalize()
        self._log("store complete")
        return True
