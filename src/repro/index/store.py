"""On-disk sharded index format: JSON manifest + raw per-shard files.

Layout (format_version 1 — see docs/INDEX_FORMAT.md):

    store_dir/
      manifest.json            format version, cfg, decoder metadata,
                               shard table, treespec, `complete` flag
      global/step_000000000/   non-sharded arrays (centroids, codebooks,
                               QINCo2 params) via checkpoint.CheckpointManager
      shards/shard_00000/      per-vector arrays, raw little-endian:
        codes.u8                 (rows, M)  packed uint8 QINCo2 codes
        assign.i32               (rows,)    IVF bucket of each vector
        aq_norms.f32             (rows,)    ||xhat_aq||^2 (w/ centroid)
        pw_norms.f32             (rows,)    ||xhat_pw||^2
        checksums.json           per-file {crc32, bytes} integrity
                                 sidecar (optional: absent on legacy
                                 stores; additive -> no version bump)

Guarantees:
  - `save(index)` -> `load()` round-trips `SearchIndex` exactly: same
    bytes in every array, bit-identical `search()` results. The bucket
    table is NOT stored — it is reconstructed from assignments via
    `ivf.buckets_from_assignments`, which reproduces the build-time fill
    order exactly.
  - Shard writes are atomic (tmp dir + rename), so a killed builder never
    leaves a half-written shard behind; shard presence on disk IS the
    resume cursor ground truth. Every publish (manifest, cursor, shard)
    fsyncs the tmp file AND the containing directory before/after the
    rename, so "atomic" also survives power loss — a torn file can never
    be published under the final name.
  - Integrity is checkable at every read tier: `verify_shard` compares
    sizes (always, derived from the manifest) and crc32 checksums
    (when the sidecar exists) for on-disk files or in-memory host
    arrays; a mismatch raises the typed `ShardIntegrityError` and
    `ShardedIndexView` quarantines the shard (in-memory denylist +
    `index_quarantined_shards_total`). `python -m repro.index.fsck`
    audits a whole store. See docs/INDEX_FORMAT.md "Integrity &
    durability".
  - Reads are mmap-backed (np.memmap): loading touches the code bytes
    once, on the way to the device, with no intermediate parse/copy.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.qinco2 import QincoConfig
from repro.index.codes import CODE_DTYPE, PackedCodes, pack_codes

FORMAT_VERSION = 1
CHECKSUM_FILE = "checksums.json"
# stdlib zlib.crc32: the environment has no crc32c wheel, and the sidecar
# records the algorithm name so a future store can switch without a format
# bump (readers reject unknown algos rather than mis-verify)
CHECKSUM_ALGO = "crc32"

# shards dropped by probe-aware scheduling, process-wide (each view also
# keeps its historical per-view `skipped_shards_total` attribute)
_C_SKIPPED = obs.counter(
    "search_skipped_shards_total",
    "shards skipped by probe-aware scheduling (zero probed buckets)")
_C_INTEGRITY_FAIL = obs.counter(
    "index_integrity_failures_total",
    "shard integrity check failures (size or checksum mismatch)")
_C_QUARANTINED = obs.counter(
    "index_quarantined_shards_total",
    "shards quarantined by a ShardedIndexView after an integrity failure")

# sharded per-vector fields: name -> (file, dtype, trailing shape lambda)
_SHARD_FIELDS = {
    "codes": ("codes.u8", np.uint8),
    "assign": ("assign.i32", np.int32),
    "aq_norms": ("aq_norms.f32", np.float32),
    "pw_norms": ("pw_norms.f32", np.float32),
}


class ShardIntegrityError(RuntimeError):
    """A shard failed an integrity check (missing/truncated file or
    checksum mismatch). Deliberately NOT an OSError: retry policies key
    on OSError for transient device faults, and integrity failures are
    persistent — retrying cannot fix corrupt bytes, only quarantine and
    (at build time) a rewrite can."""

    def __init__(self, shard_id: int, file: str, reason: str):
        self.shard_id = int(shard_id)
        self.file = file
        self.reason = reason
        super().__init__(f"shard {shard_id:05d}: {file}: {reason}")


def _crc_array(arr) -> int:
    a = np.ascontiguousarray(arr)
    return zlib.crc32(memoryview(a).cast("B")) & 0xFFFFFFFF


def _crc_file(path) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def _fsync_path(path) -> None:
    """fsync a file or directory by path, best-effort for directories
    (some platforms/filesystems reject opening or fsyncing a directory —
    the rename is still atomic there, just not power-loss durable)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _durable_write_text(path, text: str) -> None:
    """Write + flush + fsync (the caller renames and fsyncs the dir)."""
    with open(path, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())


# ---------------------------------------------------------------------------
# treespec: JSON-serializable structure description for the global tree
# ---------------------------------------------------------------------------


def tree_spec(tree) -> Any:
    """Describe a pytree of dicts/lists/arrays/None as JSON. Leaves are
    recorded positionally; the walk order matches jax.tree flattening
    (dict keys sorted), so `tree_unflatten_spec` can consume the flat
    leaf list a `CheckpointManager.restore_flat` returns."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        return {"t": "dict",
                "children": {k: tree_spec(tree[k]) for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        return {"t": "list" if isinstance(tree, list) else "tuple",
                "children": [tree_spec(v) for v in tree]}
    return {"t": "leaf"}


def tree_unflatten_spec(spec, leaves: List[Any]) -> Any:
    """Rebuild the tree described by `tree_spec` from flat leaves."""
    it = iter(leaves)

    def walk(s):
        if s["t"] == "none":
            return None
        if s["t"] == "dict":
            return {k: walk(s["children"][k]) for k in sorted(s["children"])}
        if s["t"] in ("list", "tuple"):
            out = [walk(c) for c in s["children"]]
            return out if s["t"] == "list" else tuple(out)
        try:
            return next(it)
        except StopIteration:
            raise ValueError(
                f"treespec expects more leaves than the {len(leaves)} "
                f"provided (truncated/corrupted checkpoint?)") from None

    tree = walk(spec)
    leftover = sum(1 for _ in it)
    if leftover:
        raise ValueError(f"{leftover} leaves beyond what the treespec "
                         f"describes (store/treespec mismatch)")
    return tree


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class IndexStore:
    """Reader/writer for the persistent packed-code index format."""

    def __init__(self, directory):
        self.dir = Path(directory)
        self._manifest: Optional[dict] = None

    # -- paths ---------------------------------------------------------------

    @property
    def manifest_path(self) -> Path:
        return self.dir / "manifest.json"

    def shard_dir(self, shard_id: int) -> Path:
        return self.dir / "shards" / f"shard_{shard_id:05d}"

    def exists(self) -> bool:
        return self.manifest_path.exists()

    @property
    def manifest(self) -> dict:
        if self._manifest is None:
            self._manifest = json.loads(self.manifest_path.read_text())
            v = self._manifest.get("format_version")
            if v != FORMAT_VERSION:
                raise ValueError(
                    f"store {self.dir} has format_version={v}; this reader "
                    f"understands {FORMAT_VERSION} (see INDEX_FORMAT.md)")
        return self._manifest

    # -- writer side ---------------------------------------------------------

    def initialize(self, *, cfg: QincoConfig, global_tree: dict,
                   n_total: int, shard_size: int, k_ivf: int, cap: int,
                   pw_pairs, extra: Optional[dict] = None) -> None:
        """Write the global (non-sharded) state + an incomplete manifest.

        Idempotent-unsafe by design: refuses to clobber an existing store
        (delete the directory to rebuild from scratch)."""
        from repro.index.codes import packable
        if not packable(cfg.K):
            # fail in milliseconds, not after an hours-long fit phase: the
            # v1 format stores codes.u8 only
            raise ValueError(
                f"index store format v{FORMAT_VERSION} stores packed uint8 "
                f"codes; alphabet K={cfg.K} > 256 is not representable")
        if self.exists():
            raise FileExistsError(f"store already initialized at {self.dir}")
        self.dir.mkdir(parents=True, exist_ok=True)
        (self.dir / "shards").mkdir(exist_ok=True)
        CheckpointManager(self.dir / "global", keep=1).save(0, global_tree)
        n_shards = -(-n_total // shard_size)
        manifest = {
            "format_version": FORMAT_VERSION,
            "cfg": dataclasses.asdict(cfg),
            "n_total": int(n_total),
            "shard_size": int(shard_size),
            "n_shards": int(n_shards),
            "M": int(cfg.M),
            "K": int(cfg.K),
            "code_dtype": str(np.dtype(CODE_DTYPE)),
            "k_ivf": int(k_ivf),
            "cap": int(cap),
            "pw_pairs": [list(p) for p in pw_pairs],
            "treespec": tree_spec(global_tree),
            "complete": False,
            "extra": extra or {},
        }
        self._write_manifest(manifest)

    def _write_manifest(self, manifest: dict) -> None:
        tmp = self.manifest_path.with_suffix(".tmp")
        _durable_write_text(tmp, json.dumps(manifest, indent=1))
        os.rename(tmp, self.manifest_path)        # atomic publish
        _fsync_path(self.dir)                     # ...and durable
        self._manifest = manifest

    def update_extra(self, **kv) -> None:
        """Merge keys into the manifest's free-form `extra` (atomic)."""
        m = self.manifest
        self._write_manifest(dict(m, extra=dict(m["extra"], **kv)))

    def shard_rows(self, shard_id: int) -> int:
        m = self.manifest
        lo = shard_id * m["shard_size"]
        return min(m["shard_size"], m["n_total"] - lo)

    def shard_done(self, shard_id: int) -> bool:
        return (self.shard_dir(shard_id) / _SHARD_FIELDS["codes"][0]).exists()

    # -- integrity -----------------------------------------------------------

    def shard_checksums(self, shard_id: int) -> Optional[dict]:
        """The shard's checksum sidecar, or None on a legacy (pre-sidecar)
        shard — size checks still apply there, crc checks do not."""
        path = self.shard_dir(shard_id) / CHECKSUM_FILE
        try:
            text = path.read_text()
        except FileNotFoundError:
            return None
        try:
            cks = json.loads(text)
        except ValueError:
            raise self._integrity_fail(shard_id, CHECKSUM_FILE,
                                       "unparseable sidecar") from None
        if cks.get("algo") != CHECKSUM_ALGO:
            raise self._integrity_fail(
                shard_id, CHECKSUM_FILE,
                f"unknown checksum algo {cks.get('algo')!r} "
                f"(this reader verifies {CHECKSUM_ALGO!r})")
        return cks

    @staticmethod
    def _integrity_fail(shard_id: int, file: str,
                        reason: str) -> ShardIntegrityError:
        _C_INTEGRITY_FAIL.inc()
        return ShardIntegrityError(shard_id, file, reason)

    def verify_shard(self, shard_id: int, *, arrays: Optional[dict] = None,
                     fields: Optional[list] = None) -> None:
        """Raise `ShardIntegrityError` if the shard is missing, truncated,
        or checksum-mismatched; return silently when intact.

        Expected byte sizes derive from the manifest (rows x itemsize), so
        truncation is detectable even on legacy stores with no sidecar;
        crc32 comparison happens whenever the sidecar exists.

        With ``arrays`` (logical field name -> host array) the in-memory
        bytes are checked instead of the files — that is what catches
        corruption introduced *between* disk and device (a bad read, an
        injected bit-flip) at staging-assembly time. ``fields`` restricts
        the check to a subset (defaults: the arrays' keys, else every
        field)."""
        if fields is None:
            fields = sorted(arrays) if arrays is not None \
                else list(_SHARD_FIELDS)
        cks = self.shard_checksums(shard_id)       # may raise (bad sidecar)
        files = cks["files"] if cks is not None else {}
        rows = self.shard_rows(shard_id)
        M = self.manifest["M"]
        d = self.shard_dir(shard_id)
        for name in fields:
            fname, dtype = _SHARD_FIELDS[name]
            expect = rows * (M if name == "codes" else 1) \
                * np.dtype(dtype).itemsize
            rec = files.get(fname)
            if rec is not None and int(rec["bytes"]) != expect:
                raise self._integrity_fail(
                    shard_id, fname, f"sidecar records {rec['bytes']} bytes,"
                    f" manifest implies {expect}")
            if arrays is not None:
                arr = arrays[name]
                if arr.nbytes != expect:
                    raise self._integrity_fail(
                        shard_id, fname, f"host array is {arr.nbytes} "
                        f"bytes, expected {expect}")
                if rec is not None and _crc_array(arr) != int(rec["crc32"]):
                    raise self._integrity_fail(
                        shard_id, fname, "crc32 mismatch on host array "
                        "(corrupt read or bit flip)")
            else:
                path = d / fname
                try:
                    size = path.stat().st_size
                except OSError:
                    raise self._integrity_fail(shard_id, fname,
                                               "missing") from None
                if size != expect:
                    raise self._integrity_fail(
                        shard_id, fname,
                        f"{size} bytes on disk, expected {expect} "
                        f"(truncated?)")
                if rec is not None and _crc_file(path) != int(rec["crc32"]):
                    raise self._integrity_fail(
                        shard_id, fname, "crc32 mismatch on disk")

    def write_shard(self, shard_id: int, *, codes: PackedCodes, assign,
                    aq_norms, pw_norms) -> None:
        """Atomically persist one shard (tmp dir + rename)."""
        rows = self.shard_rows(shard_id)
        arrays = {
            "codes": np.ascontiguousarray(np.asarray(codes.codes)),
            "assign": np.asarray(assign, np.int32),
            "aq_norms": np.asarray(aq_norms, np.float32),
            "pw_norms": np.asarray(pw_norms, np.float32),
        }
        if arrays["codes"].dtype != CODE_DTYPE:
            raise ValueError(f"shard codes must be {np.dtype(CODE_DTYPE)}")
        for name, arr in arrays.items():
            if arr.shape[0] != rows:
                raise ValueError(f"shard {shard_id} field {name}: "
                                 f"{arr.shape[0]} rows, expected {rows}")
        final = self.shard_dir(shard_id)
        tmp = final.with_name(f".tmp_{final.name}")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        cks = {"algo": CHECKSUM_ALGO, "files": {}}
        for name, arr in arrays.items():
            fname = _SHARD_FIELDS[name][0]
            arr.tofile(tmp / fname)
            _fsync_path(tmp / fname)
            cks["files"][fname] = {"crc32": _crc_array(arr),
                                   "bytes": int(arr.nbytes)}
        _durable_write_text(tmp / CHECKSUM_FILE,
                            json.dumps(cks, indent=1, sort_keys=True))
        _fsync_path(tmp)          # dir entries durable before the publish
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_path(final.parent)

    def finalize(self) -> None:
        """Flip the manifest to complete once every shard is on disk."""
        missing = [s for s in range(self.manifest["n_shards"])
                   if not self.shard_done(s)]
        if missing:
            raise ValueError(f"cannot finalize: shards missing {missing}")
        self._write_manifest(dict(self.manifest, complete=True))

    # -- cursors (builder resume, one per build owner) -----------------------

    @property
    def cursor_path(self) -> Path:
        return self.cursor_path_for(0)

    def cursor_path_for(self, owner: int) -> Path:
        """Owner 0 keeps the historical `cursor.json` name; additional
        owners of a data-axis sharded build get `cursor_00001.json`,
        ... — disjoint files, so concurrent owners never clobber each
        other's resume state."""
        if owner == 0:
            return self.dir / "cursor.json"
        return self.dir / f"cursor_{owner:05d}.json"

    def write_cursor(self, next_shard: int, fill, *, owner: int = 0) -> None:
        """Fast-path resume state (next shard + running bucket fill over
        ALL shards < next_shard, owned or not).

        Advisory only: shard presence on disk is ground truth; a stale or
        missing cursor just costs a re-scan of completed shards (plus a
        re-assignment of absent non-owned ones)."""
        path = self.cursor_path_for(owner)
        tmp = path.with_suffix(".tmp")
        _durable_write_text(tmp, json.dumps({"next_shard": int(next_shard),
                                             "fill": [int(f) for f in fill]}))
        os.rename(tmp, path)
        _fsync_path(self.dir)

    def read_cursor(self, *, owner: int = 0) -> Optional[dict]:
        path = self.cursor_path_for(owner)
        if not path.exists():
            return None
        try:
            return json.loads(path.read_text())
        except (ValueError, OSError):
            return None

    # -- reader side ---------------------------------------------------------

    def open_shard(self, shard_id: int) -> Dict[str, np.ndarray]:
        """mmap views over one shard's raw files (zero-copy until touched)."""
        rows = self.shard_rows(shard_id)
        d = self.shard_dir(shard_id)
        M = self.manifest["M"]
        out = {}
        for name, (fname, dtype) in _SHARD_FIELDS.items():
            shape = (rows, M) if name == "codes" else (rows,)
            out[name] = np.memmap(d / fname, dtype=dtype, mode="r",
                                  shape=shape)
        return out

    def done_shards(self) -> int:
        """Number of completed shards, counted as the on-disk prefix."""
        n = 0
        while n < self.manifest["n_shards"] and self.shard_done(n):
            n += 1
        return n

    def load_arrays(self, *, n_shards: Optional[int] = None
                    ) -> Dict[str, np.ndarray]:
        """Per-vector arrays over the first ``n_shards`` shards (default:
        all). Each shard's mmap view is read directly into its slice of
        one preallocated buffer per field — a single host copy, no
        intermediate concatenate."""
        m = self.manifest
        if n_shards is None:
            n_shards = m["n_shards"]
        rows = sum(self.shard_rows(s) for s in range(n_shards))
        out = {}
        for name, (_, dtype) in _SHARD_FIELDS.items():
            shape = (rows, m["M"]) if name == "codes" else (rows,)
            out[name] = np.empty(shape, dtype)
        lo = 0
        for sid in range(n_shards):
            sh = self.open_shard(sid)
            hi = lo + self.shard_rows(sid)
            for name in _SHARD_FIELDS:
                out[name][lo:hi] = sh[name]
            lo = hi
        return out

    def load_global_tree(self) -> dict:
        leaves, _ = CheckpointManager(self.dir / "global",
                                      keep=1).restore_flat(0)
        return tree_unflatten_spec(self.manifest["treespec"], leaves)

    def load(self, *, allow_partial: bool = False, device: bool = True):
        """Reconstruct the full `SearchIndex` (bit-identical round trip).

        With ``allow_partial`` an incomplete store loads the completed
        shard prefix: the index covers the first `done_shards()` worth of
        vectors (database ids are shard-order, so the prefix is a valid
        sub-database)."""
        from repro.core import ivf as ivf_mod
        from repro.core import pairwise as pw_mod
        from repro.core import search as search_mod

        m = self.manifest
        if not m["complete"] and not allow_partial:
            raise ValueError(
                f"store {self.dir} is incomplete (builder still running or "
                f"killed); pass allow_partial=True to read anyway")
        g = self.load_global_tree()
        arrs = self.load_arrays(
            n_shards=None if m["complete"] else self.done_shards())
        cfg = QincoConfig(**m["cfg"])
        buckets, mask = ivf_mod.buckets_from_assignments(
            arrs["assign"], m["k_ivf"], m["cap"])
        as_dev = jnp.asarray if device else np.asarray
        ivf = ivf_mod.IVFIndex(
            centroids=as_dev(g["centroids"]),
            buckets=as_dev(buckets),
            bucket_mask=as_dev(mask),
            assignments=as_dev(arrs["assign"]),
            centroid_codes=(None if g["centroid_codes"] is None
                            else as_dev(g["centroid_codes"])),
            centroid_rq_books=(None if g["centroid_rq_books"] is None
                               else as_dev(g["centroid_rq_books"])))
        pw = pw_mod.PairwiseDecoder(
            pairs=tuple(tuple(p) for p in m["pw_pairs"]),
            codebooks=as_dev(g["pw_codebooks"]), K=m["K"])
        qinco_params = jax.tree.map(as_dev, g["qinco_params"])
        return search_mod.SearchIndex(
            ivf=ivf, codes=as_dev(arrs["codes"]),
            aq_books=as_dev(g["aq_books"]),
            aq_norms=as_dev(arrs["aq_norms"]), pw=pw,
            pw_norms=as_dev(arrs["pw_norms"]),
            qinco_params=qinco_params, cfg=cfg)

    # -- one-shot save of an in-memory index ---------------------------------

    @classmethod
    def save(cls, directory, index, *, shard_size: int = 1 << 20,
             extra: Optional[dict] = None) -> "IndexStore":
        """Persist an in-memory `SearchIndex` through the same writer path
        the streaming builder uses (initialize -> write_shard* -> finalize),
        so one code path defines the format."""
        store = cls(directory)
        n = int(index.codes.shape[0])
        shard_size = max(1, min(shard_size, n))
        ivf = index.ivf
        global_tree = {
            "centroids": ivf.centroids,
            "centroid_codes": ivf.centroid_codes,
            "centroid_rq_books": ivf.centroid_rq_books,
            "aq_books": index.aq_books,
            "pw_codebooks": index.pw.codebooks,
            "qinco_params": index.qinco_params,
        }
        store.initialize(
            cfg=index.cfg, global_tree=global_tree, n_total=n,
            shard_size=shard_size, k_ivf=int(ivf.centroids.shape[0]),
            cap=int(ivf.buckets.shape[1]), pw_pairs=index.pw.pairs,
            extra=extra)
        codes = np.asarray(index.codes)
        if codes.dtype != CODE_DTYPE:
            codes = pack_codes(codes, index.cfg.K)     # narrow legacy int32
        assign = np.asarray(ivf.assignments)
        aq_norms = np.asarray(index.aq_norms)
        pw_norms = np.asarray(index.pw_norms)
        for sid in range(store.manifest["n_shards"]):
            lo = sid * shard_size
            hi = lo + store.shard_rows(sid)
            store.write_shard(
                sid, codes=PackedCodes(codes[lo:hi], index.cfg.K),
                assign=assign[lo:hi], aq_norms=aq_norms[lo:hi],
                pw_norms=pw_norms[lo:hi])
        store.finalize()
        return store

    # -- stats ---------------------------------------------------------------

    def disk_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.dir.rglob("*")
                   if p.is_file())

    def bytes_per_vector(self) -> float:
        return self.disk_bytes() / max(1, self.manifest["n_total"])


# ---------------------------------------------------------------------------
# out-of-core reader: mmap'd shards + an LRU of device-staged shards
# ---------------------------------------------------------------------------


class ShardedIndexView:
    """Out-of-core view of a store: shards stay mmap'd on disk and are
    staged to the device through a bounded `staging.StagingPool` LRU, so
    database size is independent of device memory (`IndexStore.load` by
    contrast materializes every per-vector array resident).

    What IS loaded up front (all O(model), not O(database)):
      - the global tree (centroids, AQ/pairwise codebooks, QINCo2 params);
      - per-shard bucket metadata derived from one streaming pass over the
        `assign.i32` mmaps (4 B/vector touched once, codes never read):
        each row's within-bucket rank — the slot it occupies in the dense
        bucket table `IndexStore.load` would rebuild — plus the final
        per-bucket fill counts. `core/search.search_sharded` uses these to
        reproduce resident `search()`'s candidate ordering (and therefore
        its `lax.top_k` tie-breaking) bit-identically without ever
        materializing the bucket table.

    Staged per shard (`staged()` / `acquire()`, through the pool's LRU):
      - ``ext``      (rows, M+1) codes ++ assignment column — the shared-
                     codes form `ops.adc_topk` scans; packed uint8 when
                     both K and k_ivf fit a byte, else int32;
      - ``wbr``      (rows,) int32 within-bucket ranks;
      - ``aq_norms`` (rows,) float32.

    Staging goes through a `staging.StagingPool`: a private one sized to
    ``max_resident_shards`` worst-case shards by default, or a caller-
    provided shared ``pool`` so several views (multi-tenant serving)
    split ONE byte budget. The pool adds the latency-hiding machinery —
    `prefetch(sid)` stages a shard on a background thread while the
    current one is being scanned, and a bounded host-side cache of the
    assembled ``ext`` arrays makes an evict -> re-stage cycle a
    `device_put` instead of a fresh concatenate+astype over the shard.

    Also derived in the one assignment pass: a per-shard bucket-occupancy
    bitmap, so `schedule_shards` can drop shards containing zero probed
    buckets and order the scan resident-first (fewer evictions) — the
    rank-keyed merge makes scan order irrelevant to results.

    ``allow_partial`` accepts an incomplete store and searches exactly
    the shards present on disk (ids stay global). Shard 0 must exist —
    its row 0 is the id the resident bucket table pads with.

    Integrity (``verify=True``): the construction-time assignment pass
    first verifies each shard's `assign.i32` on disk (a corrupt
    assignment would otherwise silently poison the running bucket fill,
    and with it every LATER shard's within-bucket ranks); staging then
    verifies the assembled host arrays (codes/assign/aq_norms) once per
    host-cache fill inside `_host_shard`. Any failure quarantines the
    shard: it joins the in-memory ``quarantined`` denylist, bumps
    `index_quarantined_shards_total`, and `search_sharded` either skips
    it (``on_shard_error="skip"``, coverage < 1.0) or propagates the
    `ShardIntegrityError`. `pw_norms.f32` is only read through
    `gather_rows` and is NOT staged, so its corruption is caught by
    `repro.index.fsck`, not at serve time. A shard whose assignment is
    corrupt at open never gets ranks/bitmaps; it is scheduled last and
    treated as relevant to every query for coverage accounting.

    ``faults`` accepts a `faults.FaultPlan` whose injection points wrap
    the host-side read (latency spikes, transient `OSError`s, bit-flip
    corruption of the assembled arrays) and the private pool's prefetch
    worker (death/resurrection). ``faults=None`` (the default) is
    zero-cost: a single `is None` test per hook.

    mmap lifetime: `open_shard` views are materialized (copied) before
    staging and row gathers copy into fresh host arrays, so nothing
    returned by this class (or cached by the pool) aliases the store
    directory — deleting or rewriting the store invalidates only future
    calls, never arrays already handed out.
    """

    def __init__(self, store, *, max_resident_shards: int = 2,
                 allow_partial: bool = False, pool=None,
                 host_cache_bytes: Optional[int] = None,
                 prefetch: bool = True, verify: bool = True,
                 faults=None):
        from repro.core import ivf as ivf_mod
        from repro.core import pairwise as pw_mod
        from repro.index.staging import StagingPool

        self.store = store if isinstance(store, IndexStore) \
            else IndexStore(store)
        m = self.store.manifest
        if not m["complete"] and not allow_partial:
            raise ValueError(
                f"store {self.store.dir} is incomplete; pass "
                f"allow_partial=True to search the completed shards only")
        if max_resident_shards < 1:
            raise ValueError("max_resident_shards must be >= 1")
        self.max_resident_shards = int(max_resident_shards)
        self.shard_ids = [s for s in range(m["n_shards"])
                          if self.store.shard_done(s)]
        if not self.shard_ids:
            raise ValueError(f"store {self.store.dir} has no completed "
                             f"shards to search")
        if self.shard_ids[0] != 0:
            raise ValueError("shard 0 is required (bucket-table padding "
                             "ids resolve to row 0)")
        self.cfg = QincoConfig(**m["cfg"])
        self.M = int(m["M"])
        self.K = int(m["K"])
        self.k_ivf = int(m["k_ivf"])
        self.cap = int(m["cap"])
        self.shard_size = int(m["shard_size"])
        self.n_total = int(m["n_total"])
        self.n_rows = sum(self.store.shard_rows(s) for s in self.shard_ids)

        g = self.store.load_global_tree()
        self.centroids = jnp.asarray(g["centroids"])
        self.aq_books = jnp.asarray(g["aq_books"])
        self.centroid_codes = (None if g["centroid_codes"] is None
                               else jnp.asarray(g["centroid_codes"]))
        self.pw = pw_mod.PairwiseDecoder(
            pairs=tuple(tuple(p) for p in m["pw_pairs"]),
            codebooks=jnp.asarray(g["pw_codebooks"]), K=self.K)
        self.qinco_params = jax.tree.map(jnp.asarray, g["qinco_params"])

        self.verify = bool(verify)
        self.faults = faults
        self.quarantined: set = set()
        self._open_bad: set = set()    # quarantined at open: no ranks/bitmap
        if self.verify:
            for sid in self.shard_ids:
                try:
                    self.store.verify_shard(sid, fields=["assign"])
                except ShardIntegrityError:
                    self._quarantine(sid)
                    self._open_bad.add(sid)

        # one pass over the assign mmaps: within-bucket ranks + fills,
        # plus each shard's bucket-occupancy bitmap (which buckets have at
        # least one row here — what probe-aware scheduling skips on)
        fill = np.zeros(self.k_ivf, np.int64)
        self._wbr: Dict[int, np.ndarray] = {}
        self._bucket_hit: Dict[int, np.ndarray] = {}
        for sid in self.shard_ids:
            if sid in self._open_bad:
                continue
            a = np.asarray(self.store.open_shard(sid)["assign"])
            self._wbr[sid], new_fill = ivf_mod.within_bucket_ranks(
                a, self.k_ivf, fill)
            self._bucket_hit[sid] = new_fill > fill        # (k_ivf,) bool
            fill = new_fill
        self.bucket_fill = jnp.asarray(fill.astype(np.int32))  # (k_ivf,)

        # ext dtype: keep the packed-byte wire form whenever it can also
        # carry the assignment column (kernels widen in-VMEM either way)
        self._ext_dtype = (np.uint8 if self.K <= 256 and self.k_ivf <= 256
                           else np.int32)
        worst = max(self.shard_staged_bytes(s) for s in self.shard_ids)
        # ``prefetch`` configures the PRIVATE pool only (a shared pool's
        # policy belongs to whoever constructed it)
        self.pool = pool if pool is not None else StagingPool(
            self.max_resident_shards * worst,
            max_entries=self.max_resident_shards,
            host_cache_bytes=host_cache_bytes, prefetch=prefetch,
            faults=faults)
        self._owner = self.pool.register()
        self.skipped_shards_total = 0

    def _quarantine(self, shard_id: int) -> None:
        if shard_id not in self.quarantined:
            self.quarantined.add(shard_id)
            _C_QUARANTINED.inc()

    # -- staging through the pool --------------------------------------------

    def shard_staged_bytes(self, shard_id: int) -> int:
        """Device bytes one staged shard costs (ext + wbr + aq_norms)."""
        rows = self.store.shard_rows(shard_id)
        return rows * ((self.M + 1) * np.dtype(self._ext_dtype).itemsize
                       + 4 + 4)

    @property
    def budget_bytes(self) -> int:
        """The pool's staging budget (for a private pool:
        ``max_resident_shards`` worst-case shards). `peak_resident_bytes`
        never exceeds this (asserted in tests) — the out-of-core
        guarantee that device residency is bounded by the LRU, not the
        database."""
        return self.pool.budget_bytes

    @property
    def resident_shards(self):
        return self.pool.resident_keys(self._owner)

    @property
    def resident_bytes(self) -> int:
        return self.pool.resident_bytes

    @property
    def peak_resident_bytes(self) -> int:
        return self.pool.peak_resident_bytes

    def _host_shard(self, shard_id: int) -> dict:
        """Assemble one shard's host-side scan arrays (the expensive part
        of staging — mmap read + concatenate + astype; the unit the
        pool's host cache holds on to). Returns fresh arrays only, never
        mmap views (the pool's no-aliasing contract).

        This is also the integrity choke point: with ``verify`` on, the
        read-back bytes are size- and crc-checked here, i.e. once per
        host-cache FILL (a cache hit replays already-verified arrays), so
        steady-state acquires pay nothing. A failure quarantines the
        shard and raises `ShardIntegrityError` — the pool aborts the
        reservation and `search_sharded` decides skip-vs-raise."""
        if self.faults is not None:
            self.faults.on_read(shard_id)      # may sleep / raise OSError
        sh = self.store.open_shard(shard_id)
        arrays = {"codes": np.asarray(sh["codes"]),
                  "assign": np.asarray(sh["assign"]),
                  "aq_norms": np.asarray(sh["aq_norms"])}
        if self.faults is not None and self.faults.corrupts(shard_id):
            arrays = self.faults.corrupt_arrays(shard_id, arrays)
        if self.verify:
            try:
                self.store.verify_shard(shard_id, arrays=arrays)
            except ShardIntegrityError:
                self._quarantine(shard_id)
                raise
        ext = np.concatenate(
            [arrays["codes"].astype(self._ext_dtype, copy=False),
             arrays["assign"].astype(self._ext_dtype)[:, None]], axis=1)
        return {"ext": ext, "wbr": self._wbr[shard_id],
                "aq_norms": arrays["aq_norms"]}

    def acquire(self, shard_id: int) -> dict:
        """Device-staged arrays for one shard, pinned until `release`."""
        from functools import partial
        return self.pool.acquire((self._owner, shard_id),
                                 partial(self._host_shard, shard_id),
                                 self.shard_staged_bytes(shard_id))

    def release(self, shard_id: int) -> None:
        self.pool.release((self._owner, shard_id))

    def prefetch(self, shard_id: int) -> bool:
        """Stage a shard in the background (evict-at-issue; see
        `staging.StagingPool.prefetch`). Safe to call speculatively.
        Quarantined shards are refused — re-reading them can only fail
        the same integrity check again."""
        if shard_id in self.quarantined:
            return False
        from functools import partial
        return self.pool.prefetch((self._owner, shard_id),
                                  partial(self._host_shard, shard_id),
                                  self.shard_staged_bytes(shard_id))

    def staged(self, shard_id: int) -> dict:
        """Device-staged arrays for one shard, through the LRU
        (unpinned — the single-threaded convenience form of `acquire`)."""
        entry = self.acquire(shard_id)
        self.release(shard_id)
        return entry

    # -- probe-aware scan scheduling -----------------------------------------

    def schedule_shards(self, probed_buckets) -> list:
        """Scan order for one query batch: shards with zero probed
        buckets are dropped (their rows could only contribute non-probed
        `-inf` entries, which the rank-keyed merge never selects —
        padding always supplies enough better-ranked slots), and the
        remainder is ordered resident-shards-first to minimize evictions
        under a tight budget. The merge is keyed by resident-candidate
        rank, so any order is bit-identical."""
        probed = np.unique(np.asarray(probed_buckets).reshape(-1))
        hit = [s for s in self.shard_ids if s not in self._open_bad
               and bool(self._bucket_hit[s][probed].any())]
        skipped = len(self.shard_ids) - len(self._open_bad) - len(hit)
        self.skipped_shards_total += skipped      # legacy per-view attr
        if skipped:
            _C_SKIPPED.inc(skipped)
        resident = set(self.resident_shards)
        # shards quarantined at open have no occupancy bitmap, so they
        # cannot be probe-skipped: schedule them last — the search loop
        # raises or skips per its error policy, and coverage accounting
        # needs to see them as scheduled-but-unusable
        return ([s for s in hit if s in resident]
                + [s for s in hit if s not in resident]
                + sorted(self._open_bad))

    # -- shortlist row gather (steps 3-4 of the cascade) ---------------------

    def gather_rows(self, gids):
        """Host gather of shortlist rows straight off the shard mmaps:
        only the requested rows' bytes are touched (the out-of-core
        re-rank reads O(Q * shortlist), not O(N)).

        gids: int array of GLOBAL ids, any shape -> (codes uint8
        (..., M), assign int32 (...,), pw_norms float32 (...,)).
        """
        gids = np.asarray(gids)
        flat = gids.reshape(-1).astype(np.int64)
        codes = np.empty((flat.size, self.M), np.uint8)
        assign = np.empty(flat.size, np.int32)
        pw_norms = np.empty(flat.size, np.float32)
        sid_of = flat // self.shard_size
        loc = flat - sid_of * self.shard_size
        for sid in np.unique(sid_of):
            if not self.store.shard_done(int(sid)):
                raise ValueError(f"row gather hit missing shard {sid} "
                                 f"(id outside the searched set?)")
            sel = sid_of == sid
            sh = self.store.open_shard(int(sid))
            codes[sel] = sh["codes"][loc[sel]]
            assign[sel] = sh["assign"][loc[sel]]
            pw_norms[sel] = sh["pw_norms"][loc[sel]]
        return (codes.reshape(gids.shape + (self.M,)),
                assign.reshape(gids.shape),
                pw_norms.reshape(gids.shape))
