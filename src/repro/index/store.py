"""On-disk sharded index format: JSON manifest + raw per-shard files.

Layout (format_version 1; mutated stores publish 2 — see
docs/INDEX_FORMAT.md "Mutation"):

    store_dir/
      manifest.json            format version, cfg, decoder metadata,
                               shard table, treespec, `complete` flag;
                               v2 adds `deltas`, `tombstone`, `generation`
      global/step_000000000/   non-sharded arrays (centroids, codebooks,
                               QINCo2 params) via checkpoint.CheckpointManager
      shards/shard_00000/      per-vector arrays, raw little-endian:
        codes.u8                 (rows, M)  packed uint8 QINCo2 codes
        assign.i32               (rows,)    IVF bucket of each vector
        aq_norms.f32             (rows,)    ||xhat_aq||^2 (w/ centroid)
        pw_norms.f32             (rows,)    ||xhat_pw||^2
        checksums.json           per-file {crc32, bytes} integrity
                                 sidecar (optional: absent on legacy
                                 stores; additive -> no version bump)
      shards/gen_001/shard_*/  base shards of compacted generation >= 1
                               (generation 0 keeps the flat v1 naming, so
                               v1 readers and unmutated stores are
                               byte-for-byte untouched)
      deltas/delta_00000/      rows sealed by `append()` — exactly the
                               base-shard file set + sidecar, <= shard_size
                               rows each
      tombstones/tomb_00000000.bm
                               packed little-endian delete bitmap over the
                               gross global id space; the manifest record
                               (seq/bytes/crc32) is its integrity sidecar

Guarantees:
  - `save(index)` -> `load()` round-trips `SearchIndex` exactly: same
    bytes in every array, bit-identical `search()` results. The bucket
    table is NOT stored — it is reconstructed from assignments via
    `ivf.buckets_from_assignments`, which reproduces the build-time fill
    order exactly.
  - Shard writes are atomic (tmp dir + rename), so a killed builder never
    leaves a half-written shard behind; shard presence on disk IS the
    resume cursor ground truth. Every publish (manifest, cursor, shard)
    fsyncs the tmp file AND the containing directory before/after the
    rename, so "atomic" also survives power loss — a torn file can never
    be published under the final name.
  - Integrity is checkable at every read tier: `verify_shard` compares
    sizes (always, derived from the manifest) and crc32 checksums
    (when the sidecar exists) for on-disk files or in-memory host
    arrays; a mismatch raises the typed `ShardIntegrityError` and
    `ShardedIndexView` quarantines the shard (in-memory denylist +
    `index_quarantined_shards_total`). `python -m repro.index.fsck`
    audits a whole store. See docs/INDEX_FORMAT.md "Integrity &
    durability".
  - Reads are mmap-backed (np.memmap): loading touches the code bytes
    once, on the way to the device, with no intermediate parse/copy.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.qinco2 import QincoConfig
from repro.index.codes import CODE_DTYPE, PackedCodes, pack_codes

FORMAT_VERSION = 1
# mutation state (deltas / tombstone / generation) bumps the manifest to
# v2 so v1-only readers hard-fail instead of silently serving deleted
# rows; this reader accepts both
MUTATED_FORMAT_VERSION = 2
SUPPORTED_VERSIONS = (FORMAT_VERSION, MUTATED_FORMAT_VERSION)
CHECKSUM_FILE = "checksums.json"
# stdlib zlib.crc32: the environment has no crc32c wheel, and the sidecar
# records the algorithm name so a future store can switch without a format
# bump (readers reject unknown algos rather than mis-verify)
CHECKSUM_ALGO = "crc32"

# shards dropped by probe-aware scheduling, process-wide (each view also
# keeps its historical per-view `skipped_shards_total` attribute)
_C_SKIPPED = obs.counter(
    "search_skipped_shards_total",
    "shards skipped by probe-aware scheduling (zero probed buckets)")
_C_INTEGRITY_FAIL = obs.counter(
    "index_integrity_failures_total",
    "shard integrity check failures (size or checksum mismatch)")
_C_QUARANTINED = obs.counter(
    "index_quarantined_shards_total",
    "shards quarantined by a ShardedIndexView after an integrity failure")
_C_DELTA_SHARDS = obs.counter(
    "index_delta_shards_total",
    "delta shards sealed and published by IndexStore.append")
_C_DELTA_ROWS = obs.counter(
    "index_delta_rows_total",
    "rows appended into delta shards by IndexStore.append")
_C_DELETED = obs.counter(
    "index_deleted_rows_total",
    "rows newly tombstoned by IndexStore.delete")
_C_REFRESH = obs.counter(
    "index_refreshes_total",
    "ShardedIndexView.refresh calls that adopted a changed manifest")
_G_GENERATION = obs.gauge(
    "index_generation",
    "base-shard generation the live view is serving (bumps on compaction)")

# sharded per-vector fields: name -> (file, dtype, trailing shape lambda)
_SHARD_FIELDS = {
    "codes": ("codes.u8", np.uint8),
    "assign": ("assign.i32", np.int32),
    "aq_norms": ("aq_norms.f32", np.float32),
    "pw_norms": ("pw_norms.f32", np.float32),
}


class ShardIntegrityError(RuntimeError):
    """A shard failed an integrity check (missing/truncated file or
    checksum mismatch). Deliberately NOT an OSError: retry policies key
    on OSError for transient device faults, and integrity failures are
    persistent — retrying cannot fix corrupt bytes, only quarantine and
    (at build time) a rewrite can."""

    def __init__(self, shard_id, file: str, reason: str):
        # `shard_id` is an int for base shards (historical contract) or a
        # descriptive string for other shard-format units ("delta 00002",
        # "tombstone 00000001") — same typed failure, same quarantine path
        self.shard_id = shard_id if isinstance(shard_id, str) else int(shard_id)
        self.file = file
        self.reason = reason
        ident = shard_id if isinstance(shard_id, str) \
            else f"shard {shard_id:05d}"
        super().__init__(f"{ident}: {file}: {reason}")


def _crc_array(arr) -> int:
    a = np.ascontiguousarray(arr)
    return zlib.crc32(memoryview(a).cast("B")) & 0xFFFFFFFF


def _crc_file(path) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def _fsync_path(path) -> None:
    """fsync a file or directory by path, best-effort for directories
    (some platforms/filesystems reject opening or fsyncing a directory —
    the rename is still atomic there, just not power-loss durable)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _durable_write_text(path, text: str) -> None:
    """Write + flush + fsync (the caller renames and fsyncs the dir)."""
    with open(path, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())


# ---------------------------------------------------------------------------
# treespec: JSON-serializable structure description for the global tree
# ---------------------------------------------------------------------------


def tree_spec(tree) -> Any:
    """Describe a pytree of dicts/lists/arrays/None as JSON. Leaves are
    recorded positionally; the walk order matches jax.tree flattening
    (dict keys sorted), so `tree_unflatten_spec` can consume the flat
    leaf list a `CheckpointManager.restore_flat` returns."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        return {"t": "dict",
                "children": {k: tree_spec(tree[k]) for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        return {"t": "list" if isinstance(tree, list) else "tuple",
                "children": [tree_spec(v) for v in tree]}
    return {"t": "leaf"}


def tree_unflatten_spec(spec, leaves: List[Any]) -> Any:
    """Rebuild the tree described by `tree_spec` from flat leaves."""
    it = iter(leaves)

    def walk(s):
        if s["t"] == "none":
            return None
        if s["t"] == "dict":
            return {k: walk(s["children"][k]) for k in sorted(s["children"])}
        if s["t"] in ("list", "tuple"):
            out = [walk(c) for c in s["children"]]
            return out if s["t"] == "list" else tuple(out)
        try:
            return next(it)
        except StopIteration:
            raise ValueError(
                f"treespec expects more leaves than the {len(leaves)} "
                f"provided (truncated/corrupted checkpoint?)") from None

    tree = walk(spec)
    leftover = sum(1 for _ in it)
    if leftover:
        raise ValueError(f"{leftover} leaves beyond what the treespec "
                         f"describes (store/treespec mismatch)")
    return tree


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class IndexStore:
    """Reader/writer for the persistent packed-code index format."""

    def __init__(self, directory):
        self.dir = Path(directory)
        self._manifest: Optional[dict] = None

    # -- paths ---------------------------------------------------------------

    @property
    def manifest_path(self) -> Path:
        return self.dir / "manifest.json"

    def shard_dir(self, shard_id: int,
                  generation: Optional[int] = None) -> Path:
        """Base-shard directory. Generation 0 keeps the flat v1 layout;
        compacted generations live under ``shards/gen_NNN/``. Default:
        the manifest's current generation."""
        if generation is None:
            generation = self.generation
        root = self.dir / "shards"
        if generation:
            root = root / f"gen_{generation:03d}"
        return root / f"shard_{shard_id:05d}"

    def delta_dir(self, delta_id: int) -> Path:
        return self.dir / "deltas" / f"delta_{delta_id:05d}"

    def tombstone_path(self, seq: int) -> Path:
        return self.dir / "tombstones" / f"tomb_{seq:08d}.bm"

    @property
    def compact_cursor_path(self) -> Path:
        return self.dir / "compact_cursor.json"

    def exists(self) -> bool:
        return self.manifest_path.exists()

    @property
    def manifest(self) -> dict:
        if self._manifest is None:
            self._manifest = json.loads(self.manifest_path.read_text())
            v = self._manifest.get("format_version")
            if v not in SUPPORTED_VERSIONS:
                raise ValueError(
                    f"store {self.dir} has format_version={v}; this reader "
                    f"understands {list(SUPPORTED_VERSIONS)} "
                    f"(see INDEX_FORMAT.md)")
        return self._manifest

    def reload_manifest(self) -> dict:
        """Drop the cached manifest and re-read from disk. Mutators
        publish whole new manifests atomically (tmp+rename), so a live
        reader polls through this — it either sees the old state or the
        new, never a torn one."""
        self._manifest = None
        return self.manifest

    # -- mutation-state accessors (empty/zero on v1 manifests) ---------------

    @property
    def generation(self) -> int:
        return int(self.manifest.get("generation", 0))

    @property
    def deltas(self) -> List[dict]:
        return list(self.manifest.get("deltas") or [])

    @property
    def tombstone(self) -> Optional[dict]:
        return self.manifest.get("tombstone")

    @property
    def mutated(self) -> bool:
        """True while uncompacted mutation state (deltas or tombstones)
        is pending."""
        m = self.manifest
        return bool(m.get("deltas")) or m.get("tombstone") is not None

    def total_rows(self) -> int:
        """Gross rows: base + sealed deltas. Tombstoned rows keep their
        slots (and ids) until compaction, so this never shrinks within a
        generation."""
        return int(self.manifest["n_total"]) + \
            sum(int(d["rows"]) for d in self.deltas)

    def delta_rows(self, delta_id: int) -> int:
        return int(self.deltas[delta_id]["rows"])

    # -- writer side ---------------------------------------------------------

    def initialize(self, *, cfg: QincoConfig, global_tree: dict,
                   n_total: int, shard_size: int, k_ivf: int, cap: int,
                   pw_pairs, extra: Optional[dict] = None) -> None:
        """Write the global (non-sharded) state + an incomplete manifest.

        Idempotent-unsafe by design: refuses to clobber an existing store
        (delete the directory to rebuild from scratch)."""
        from repro.index.codes import packable
        if not packable(cfg.K):
            # fail in milliseconds, not after an hours-long fit phase: the
            # v1 format stores codes.u8 only
            raise ValueError(
                f"index store format v{FORMAT_VERSION} stores packed uint8 "
                f"codes; alphabet K={cfg.K} > 256 is not representable")
        if self.exists():
            raise FileExistsError(f"store already initialized at {self.dir}")
        self.dir.mkdir(parents=True, exist_ok=True)
        (self.dir / "shards").mkdir(exist_ok=True)
        CheckpointManager(self.dir / "global", keep=1).save(0, global_tree)
        n_shards = -(-n_total // shard_size)
        manifest = {
            "format_version": FORMAT_VERSION,
            "cfg": dataclasses.asdict(cfg),
            "n_total": int(n_total),
            "shard_size": int(shard_size),
            "n_shards": int(n_shards),
            "M": int(cfg.M),
            "K": int(cfg.K),
            "code_dtype": str(np.dtype(CODE_DTYPE)),
            "k_ivf": int(k_ivf),
            "cap": int(cap),
            "pw_pairs": [list(p) for p in pw_pairs],
            "treespec": tree_spec(global_tree),
            "complete": False,
            "extra": extra or {},
        }
        self._write_manifest(manifest)

    def _write_manifest(self, manifest: dict) -> None:
        tmp = self.manifest_path.with_suffix(".tmp")
        _durable_write_text(tmp, json.dumps(manifest, indent=1))
        os.rename(tmp, self.manifest_path)        # atomic publish
        _fsync_path(self.dir)                     # ...and durable
        self._manifest = manifest

    def update_extra(self, **kv) -> None:
        """Merge keys into the manifest's free-form `extra` (atomic)."""
        m = self.manifest
        self._write_manifest(dict(m, extra=dict(m["extra"], **kv)))

    def shard_rows(self, shard_id: int) -> int:
        m = self.manifest
        lo = shard_id * m["shard_size"]
        return min(m["shard_size"], m["n_total"] - lo)

    def shard_done(self, shard_id: int) -> bool:
        return (self.shard_dir(shard_id) / _SHARD_FIELDS["codes"][0]).exists()

    # -- integrity -----------------------------------------------------------

    def _read_sidecar(self, d: Path, ident) -> Optional[dict]:
        path = d / CHECKSUM_FILE
        try:
            text = path.read_text()
        except FileNotFoundError:
            return None
        try:
            cks = json.loads(text)
        except ValueError:
            raise self._integrity_fail(ident, CHECKSUM_FILE,
                                       "unparseable sidecar") from None
        if cks.get("algo") != CHECKSUM_ALGO:
            raise self._integrity_fail(
                ident, CHECKSUM_FILE,
                f"unknown checksum algo {cks.get('algo')!r} "
                f"(this reader verifies {CHECKSUM_ALGO!r})")
        return cks

    def shard_checksums(self, shard_id: int) -> Optional[dict]:
        """The shard's checksum sidecar, or None on a legacy (pre-sidecar)
        shard — size checks still apply there, crc checks do not."""
        return self._read_sidecar(self.shard_dir(shard_id), shard_id)

    @staticmethod
    def _integrity_fail(shard_id: int, file: str,
                        reason: str) -> ShardIntegrityError:
        _C_INTEGRITY_FAIL.inc()
        return ShardIntegrityError(shard_id, file, reason)

    def verify_shard(self, shard_id: int, *, arrays: Optional[dict] = None,
                     fields: Optional[list] = None) -> None:
        """Raise `ShardIntegrityError` if the shard is missing, truncated,
        or checksum-mismatched; return silently when intact.

        Expected byte sizes derive from the manifest (rows x itemsize), so
        truncation is detectable even on legacy stores with no sidecar;
        crc32 comparison happens whenever the sidecar exists.

        With ``arrays`` (logical field name -> host array) the in-memory
        bytes are checked instead of the files — that is what catches
        corruption introduced *between* disk and device (a bad read, an
        injected bit-flip) at staging-assembly time. ``fields`` restricts
        the check to a subset (defaults: the arrays' keys, else every
        field)."""
        self._verify_dir(self.shard_dir(shard_id), self.shard_rows(shard_id),
                         shard_id, arrays=arrays, fields=fields)

    def verify_delta(self, delta_id: int, *, arrays: Optional[dict] = None,
                     fields: Optional[list] = None) -> None:
        """`verify_shard` for a sealed delta shard (same file set, same
        sidecar, same typed failure)."""
        self._verify_dir(self.delta_dir(delta_id), self.delta_rows(delta_id),
                         f"delta {delta_id:05d}", arrays=arrays,
                         fields=fields)

    def _verify_dir(self, d: Path, rows: int, ident, *,
                    arrays: Optional[dict] = None,
                    fields: Optional[list] = None) -> None:
        """The one integrity checker for any shard-format directory (base
        shard of any generation, delta shard). ``ident`` is the int shard
        id or a descriptive string for the error message."""
        if fields is None:
            fields = sorted(arrays) if arrays is not None \
                else list(_SHARD_FIELDS)
        cks = self._read_sidecar(d, ident)         # may raise (bad sidecar)
        files = cks["files"] if cks is not None else {}
        M = self.manifest["M"]
        for name in fields:
            fname, dtype = _SHARD_FIELDS[name]
            expect = rows * (M if name == "codes" else 1) \
                * np.dtype(dtype).itemsize
            rec = files.get(fname)
            if rec is not None and int(rec["bytes"]) != expect:
                raise self._integrity_fail(
                    ident, fname, f"sidecar records {rec['bytes']} bytes,"
                    f" manifest implies {expect}")
            if arrays is not None:
                arr = arrays[name]
                if arr.nbytes != expect:
                    raise self._integrity_fail(
                        ident, fname, f"host array is {arr.nbytes} "
                        f"bytes, expected {expect}")
                if rec is not None and _crc_array(arr) != int(rec["crc32"]):
                    raise self._integrity_fail(
                        ident, fname, "crc32 mismatch on host array "
                        "(corrupt read or bit flip)")
            else:
                path = d / fname
                try:
                    size = path.stat().st_size
                except OSError:
                    raise self._integrity_fail(ident, fname,
                                               "missing") from None
                if size != expect:
                    raise self._integrity_fail(
                        ident, fname,
                        f"{size} bytes on disk, expected {expect} "
                        f"(truncated?)")
                if rec is not None and _crc_file(path) != int(rec["crc32"]):
                    raise self._integrity_fail(
                        ident, fname, "crc32 mismatch on disk")

    @staticmethod
    def _as_shard_arrays(codes, assign, aq_norms, pw_norms) -> dict:
        arrays = {
            "codes": np.ascontiguousarray(np.asarray(
                codes.codes if isinstance(codes, PackedCodes) else codes)),
            "assign": np.asarray(assign, np.int32),
            "aq_norms": np.asarray(aq_norms, np.float32),
            "pw_norms": np.asarray(pw_norms, np.float32),
        }
        if arrays["codes"].dtype != CODE_DTYPE:
            raise ValueError(f"shard codes must be {np.dtype(CODE_DTYPE)}")
        return arrays

    def write_shard(self, shard_id: int, *, codes: PackedCodes, assign,
                    aq_norms, pw_norms) -> None:
        """Atomically persist one shard (tmp dir + rename)."""
        self._publish_array_dir(
            self.shard_dir(shard_id),
            self._as_shard_arrays(codes, assign, aq_norms, pw_norms),
            self.shard_rows(shard_id), f"shard {shard_id}")

    def _publish_array_dir(self, final: Path, arrays: dict, rows: int,
                           ident: str) -> None:
        """The ONE writer for any shard-format directory — base shards
        (builder, one-shot save), delta shards (`append`), and compaction
        output all publish through here, which is what makes "compaction
        output is byte-identical to a fresh build of the survivors" a
        structural property rather than a test-enforced coincidence.

        tmp dir -> tofile+fsync per field -> checksum sidecar -> fsync ->
        rename -> fsync parent: atomic and power-loss durable."""
        for name, arr in arrays.items():
            if arr.shape[0] != rows:
                raise ValueError(f"{ident} field {name}: "
                                 f"{arr.shape[0]} rows, expected {rows}")
        tmp = final.with_name(f".tmp_{final.name}")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        cks = {"algo": CHECKSUM_ALGO, "files": {}}
        for name, arr in arrays.items():
            fname = _SHARD_FIELDS[name][0]
            arr.tofile(tmp / fname)
            _fsync_path(tmp / fname)
            cks["files"][fname] = {"crc32": _crc_array(arr),
                                   "bytes": int(arr.nbytes)}
        _durable_write_text(tmp / CHECKSUM_FILE,
                            json.dumps(cks, indent=1, sort_keys=True))
        _fsync_path(tmp)          # dir entries durable before the publish
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_path(final.parent)

    def finalize(self) -> None:
        """Flip the manifest to complete once every shard is on disk."""
        missing = [s for s in range(self.manifest["n_shards"])
                   if not self.shard_done(s)]
        if missing:
            raise ValueError(f"cannot finalize: shards missing {missing}")
        self._write_manifest(dict(self.manifest, complete=True))

    # -- cursors (builder resume, one per build owner) -----------------------

    @property
    def cursor_path(self) -> Path:
        return self.cursor_path_for(0)

    def cursor_path_for(self, owner: int) -> Path:
        """Owner 0 keeps the historical `cursor.json` name; additional
        owners of a data-axis sharded build get `cursor_00001.json`,
        ... — disjoint files, so concurrent owners never clobber each
        other's resume state."""
        if owner == 0:
            return self.dir / "cursor.json"
        return self.dir / f"cursor_{owner:05d}.json"

    def write_cursor(self, next_shard: int, fill, *, owner: int = 0) -> None:
        """Fast-path resume state (next shard + running bucket fill over
        ALL shards < next_shard, owned or not).

        Advisory only: shard presence on disk is ground truth; a stale or
        missing cursor just costs a re-scan of completed shards (plus a
        re-assignment of absent non-owned ones)."""
        path = self.cursor_path_for(owner)
        tmp = path.with_suffix(".tmp")
        _durable_write_text(tmp, json.dumps({"next_shard": int(next_shard),
                                             "fill": [int(f) for f in fill]}))
        os.rename(tmp, path)
        _fsync_path(self.dir)

    def read_cursor(self, *, owner: int = 0) -> Optional[dict]:
        path = self.cursor_path_for(owner)
        if not path.exists():
            return None
        try:
            return json.loads(path.read_text())
        except (ValueError, OSError):
            return None

    def read_compact_cursor(self) -> Optional[dict]:
        """The compactor's resume state (target generation + the mutation
        signature it is folding), or None. Advisory like the build
        cursor: shard presence in the target generation dir is ground
        truth, and a signature mismatch wipes the partial output."""
        try:
            return json.loads(self.compact_cursor_path.read_text())
        except (OSError, ValueError):
            return None

    # -- live mutation: delta shards + tombstone bitmap ----------------------

    def gross_fill(self) -> np.ndarray:
        """Per-bucket occupancy over base + delta rows, tombstoned rows
        INCLUDED. Deleted rows keep their bucket slots until compaction,
        which is what keeps every already-staged shard's within-bucket
        ranks immutable under append/delete — the live view never has to
        invalidate its pool. One O(N) pass over the assign mmaps
        (4 B/row), codes never touched."""
        m = self.manifest
        fill = np.zeros(m["k_ivf"], np.int64)
        for sid in range(m["n_shards"]):
            a = np.asarray(self.open_shard(sid)["assign"])
            fill += np.bincount(a, minlength=m["k_ivf"])
        for d in self.deltas:
            a = np.asarray(self.open_delta(int(d["id"]))["assign"])
            fill += np.bincount(a, minlength=m["k_ivf"])
        return fill

    def append(self, xs, *, encode_chunk: int = 4096,
               backend: str = "auto") -> np.ndarray:
        """Encode new vectors into sealed delta shards and publish them
        atomically in a v2 manifest. Returns the new rows' global ids.

        Each delta holds at most ``shard_size`` rows, so a staged delta
        never exceeds the pool's worst-case shard budget. Encoding runs
        through the builder's `encode_rows` — the exact per-shard
        pipeline a fresh build runs — with spill assignment continuing
        from the GROSS bucket fill. (Appending after deletes may
        therefore spill earlier than a fresh build of the survivors
        would; compaction restores tight packing. In the spill-free
        regime the delta's bytes equal what a fresh build of the same
        rows would produce.)"""
        from repro.index import builder as builder_mod
        m = self.manifest
        if not m["complete"]:
            raise ValueError(f"store {self.dir} is incomplete; only a "
                             f"finalized store accepts appends")
        xs = np.ascontiguousarray(np.asarray(xs, np.float32))
        if xs.ndim != 2:
            raise ValueError(f"append expects (n, d) vectors, got "
                             f"shape {xs.shape}")
        if len(xs) == 0:
            return np.empty(0, np.int64)
        g = self.load_global_tree()
        if xs.shape[1] != np.asarray(g["centroids"]).shape[1]:
            raise ValueError(
                f"append dim {xs.shape[1]} != store dim "
                f"{np.asarray(g['centroids']).shape[1]}")
        gt = builder_mod.make_pw_decoder(m, g)
        gt["aq_books"] = jnp.asarray(g["aq_books"])
        gt["qinco_params"] = jax.tree.map(jnp.asarray, g["qinco_params"])
        cfg = QincoConfig(**m["cfg"])
        fill = self.gross_fill()
        base = self.total_rows()
        prior = self.deltas
        shard_size = int(m["shard_size"])
        records = []
        for lo in range(0, len(xs), shard_size):
            chunk = xs[lo:lo + shard_size]
            packed, assign, aq_norms, pw_norms, fill = builder_mod.encode_rows(
                chunk, gt, cfg, fill, m["cap"],
                encode_chunk=encode_chunk, backend=backend)
            did = len(prior) + len(records)
            self._publish_array_dir(
                self.delta_dir(did),
                self._as_shard_arrays(PackedCodes(packed, m["K"]), assign,
                                      aq_norms, pw_norms),
                len(chunk), f"delta {did:05d}")
            records.append({"id": did, "rows": int(len(chunk))})
        manifest = dict(m, deltas=prior + records,
                        format_version=MUTATED_FORMAT_VERSION)
        manifest.setdefault("generation", 0)
        manifest.setdefault("tombstone", None)
        self._write_manifest(manifest)
        _C_DELTA_SHARDS.inc(len(records))
        _C_DELTA_ROWS.inc(int(len(xs)))
        return np.arange(base, base + len(xs), dtype=np.int64)

    def delete(self, ids) -> int:
        """Tombstone global ids; returns how many were NEWLY deleted.

        The whole bitmap (packed little-endian over the gross id space)
        is rewritten to a fresh ``tomb_{seq}.bm`` and the manifest —
        which doubles as the bitmap's integrity sidecar (bytes + crc32)
        — is swapped atomically. Readers pinned to the old manifest keep
        reading the old seq file; superseded files are unlinked only by
        `gc_orphans` (the unlink-after-release rule)."""
        m = self.manifest
        n = self.total_rows()
        ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
        if ids.size == 0:
            return 0
        if ids[0] < 0 or ids[-1] >= n:
            raise ValueError(f"delete ids outside [0, {n})")
        bits = self.tombstone_bits(n_rows=n)
        newly = int(np.count_nonzero(~bits[ids]))
        if newly == 0:
            return 0
        bits[ids] = True
        t = m.get("tombstone")
        seq = int(t["seq"]) + 1 if t is not None else 0
        packed = np.packbits(bits, bitorder="little")
        path = self.tombstone_path(seq)
        path.parent.mkdir(exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            f.write(packed.tobytes())
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
        _fsync_path(path.parent)
        rec = {"seq": seq, "bytes": int(packed.nbytes),
               "crc32": _crc_array(packed),
               "n_deleted": int(np.count_nonzero(bits)), "n_rows": int(n)}
        manifest = dict(m, tombstone=rec,
                        format_version=MUTATED_FORMAT_VERSION)
        manifest.setdefault("generation", 0)
        manifest.setdefault("deltas", [])
        self._write_manifest(manifest)
        _C_DELETED.inc(newly)
        return newly

    def tombstone_bits(self, n_rows: Optional[int] = None) -> np.ndarray:
        """The delete bitmap as bool over the gross id space, zero-padded
        to ``n_rows`` (default `total_rows()` — rows appended after the
        bitmap was written are alive by construction). Verifies the file
        against the manifest record; a mismatch is a typed
        `ShardIntegrityError`, like any other corrupt unit."""
        if n_rows is None:
            n_rows = self.total_rows()
        t = self.manifest.get("tombstone")
        if t is None:
            return np.zeros(n_rows, bool)
        ident = f"tombstone {int(t['seq']):08d}"
        path = self.tombstone_path(int(t["seq"]))
        try:
            raw = path.read_bytes()
        except OSError:
            raise self._integrity_fail(ident, path.name, "missing") from None
        packed = np.frombuffer(raw, np.uint8)
        if packed.nbytes != int(t["bytes"]):
            raise self._integrity_fail(
                ident, path.name,
                f"{packed.nbytes} bytes on disk, manifest records "
                f"{t['bytes']}")
        if _crc_array(packed) != int(t["crc32"]):
            raise self._integrity_fail(ident, path.name,
                                       "crc32 mismatch on disk")
        bits = np.unpackbits(packed, bitorder="little")[:int(t["n_rows"])]
        out = np.zeros(n_rows, bool)
        k = min(n_rows, bits.size)
        out[:k] = bits[:k].astype(bool)
        return out

    def orphan_paths(self) -> List[Path]:
        """Paths the CURRENT manifest no longer references: delta dirs
        folded by a compaction, base-shard generations older than the
        manifest's, superseded tombstone seq files, tmp debris, and a
        compact cursor whose target generation already published. A
        partially-written target generation named by a live compact
        cursor is excluded (it is resume state, not garbage)."""
        m = self.manifest
        found: List[Path] = []
        gen = int(m.get("generation", 0))
        keep_gen = {gen}
        cur = self.read_compact_cursor()
        if cur is not None:
            if int(cur.get("generation", -1)) > gen:
                keep_gen.add(int(cur["generation"]))
            else:
                found.append(self.compact_cursor_path)  # already published
        sroot = self.dir / "shards"
        if sroot.exists():
            for p in sorted(sroot.iterdir()):
                if p.name.startswith("gen_"):
                    if int(p.name[4:]) not in keep_gen:
                        found.append(p)
                elif p.name.startswith("shard_"):
                    if 0 not in keep_gen:
                        found.append(p)
                else:                             # .tmp_* debris
                    found.append(p)
        droot = self.dir / "deltas"
        if droot.exists():
            live = {self.delta_dir(int(d["id"])).name for d in self.deltas}
            found.extend(p for p in sorted(droot.iterdir())
                         if p.name not in live)
        troot = self.dir / "tombstones"
        if troot.exists():
            t = m.get("tombstone")
            live_t = {self.tombstone_path(int(t["seq"])).name} \
                if t is not None else set()
            found.extend(p for p in sorted(troot.iterdir())
                         if p.name not in live_t)
        return found

    def gc_orphans(self) -> List[Path]:
        """Unlink every `orphan_paths` entry.

        Safe only once no reader is pinned to an older manifest — the
        serving view calls this after its last old-state pin releases,
        and mutators/compactors never unlink. Must not race a live
        builder or compactor writing into this store. Returns the
        removed paths."""
        removed: List[Path] = []
        for p in self.orphan_paths():
            try:
                if p.is_dir():
                    shutil.rmtree(p)
                else:
                    p.unlink()
            except OSError:
                continue                          # a concurrent gc won
            removed.append(p)
        if removed:
            _fsync_path(self.dir)
        return removed

    # -- reader side ---------------------------------------------------------

    def _open_array_dir(self, d: Path, rows: int) -> Dict[str, np.ndarray]:
        M = self.manifest["M"]
        out = {}
        for name, (fname, dtype) in _SHARD_FIELDS.items():
            shape = (rows, M) if name == "codes" else (rows,)
            out[name] = np.memmap(d / fname, dtype=dtype, mode="r",
                                  shape=shape)
        return out

    def open_shard(self, shard_id: int) -> Dict[str, np.ndarray]:
        """mmap views over one shard's raw files (zero-copy until touched)."""
        return self._open_array_dir(self.shard_dir(shard_id),
                                    self.shard_rows(shard_id))

    def open_delta(self, delta_id: int) -> Dict[str, np.ndarray]:
        """`open_shard` for a sealed delta shard."""
        return self._open_array_dir(self.delta_dir(delta_id),
                                    self.delta_rows(delta_id))

    def done_shards(self) -> int:
        """Number of completed shards, counted as the on-disk prefix."""
        n = 0
        while n < self.manifest["n_shards"] and self.shard_done(n):
            n += 1
        return n

    def load_arrays(self, *, n_shards: Optional[int] = None
                    ) -> Dict[str, np.ndarray]:
        """Per-vector arrays over the first ``n_shards`` shards (default:
        all). Each shard's mmap view is read directly into its slice of
        one preallocated buffer per field — a single host copy, no
        intermediate concatenate."""
        m = self.manifest
        if n_shards is None:
            n_shards = m["n_shards"]
        rows = sum(self.shard_rows(s) for s in range(n_shards))
        out = {}
        for name, (_, dtype) in _SHARD_FIELDS.items():
            shape = (rows, m["M"]) if name == "codes" else (rows,)
            out[name] = np.empty(shape, dtype)
        lo = 0
        for sid in range(n_shards):
            sh = self.open_shard(sid)
            hi = lo + self.shard_rows(sid)
            for name in _SHARD_FIELDS:
                out[name][lo:hi] = sh[name]
            lo = hi
        return out

    def load_global_tree(self) -> dict:
        leaves, _ = CheckpointManager(self.dir / "global",
                                      keep=1).restore_flat(0)
        return tree_unflatten_spec(self.manifest["treespec"], leaves)

    def load(self, *, allow_partial: bool = False, device: bool = True):
        """Reconstruct the full `SearchIndex` (bit-identical round trip).

        With ``allow_partial`` an incomplete store loads the completed
        shard prefix: the index covers the first `done_shards()` worth of
        vectors (database ids are shard-order, so the prefix is a valid
        sub-database)."""
        from repro.core import ivf as ivf_mod
        from repro.core import pairwise as pw_mod
        from repro.core import search as search_mod

        m = self.manifest
        if not m["complete"] and not allow_partial:
            raise ValueError(
                f"store {self.dir} is incomplete (builder still running or "
                f"killed); pass allow_partial=True to read anyway")
        if self.mutated:
            raise ValueError(
                f"store {self.dir} carries uncompacted mutation state "
                f"(delta shards and/or tombstones); `load` materializes "
                f"base shards only and would silently drop appends or "
                f"resurrect deletes — serve it through ShardedIndexView, "
                f"or run `python -m repro.index.compact` first")
        g = self.load_global_tree()
        arrs = self.load_arrays(
            n_shards=None if m["complete"] else self.done_shards())
        cfg = QincoConfig(**m["cfg"])
        buckets, mask = ivf_mod.buckets_from_assignments(
            arrs["assign"], m["k_ivf"], m["cap"])
        as_dev = jnp.asarray if device else np.asarray
        ivf = ivf_mod.IVFIndex(
            centroids=as_dev(g["centroids"]),
            buckets=as_dev(buckets),
            bucket_mask=as_dev(mask),
            assignments=as_dev(arrs["assign"]),
            centroid_codes=(None if g["centroid_codes"] is None
                            else as_dev(g["centroid_codes"])),
            centroid_rq_books=(None if g["centroid_rq_books"] is None
                               else as_dev(g["centroid_rq_books"])))
        pw = pw_mod.PairwiseDecoder(
            pairs=tuple(tuple(p) for p in m["pw_pairs"]),
            codebooks=as_dev(g["pw_codebooks"]), K=m["K"])
        qinco_params = jax.tree.map(as_dev, g["qinco_params"])
        return search_mod.SearchIndex(
            ivf=ivf, codes=as_dev(arrs["codes"]),
            aq_books=as_dev(g["aq_books"]),
            aq_norms=as_dev(arrs["aq_norms"]), pw=pw,
            pw_norms=as_dev(arrs["pw_norms"]),
            qinco_params=qinco_params, cfg=cfg)

    # -- one-shot save of an in-memory index ---------------------------------

    @classmethod
    def save(cls, directory, index, *, shard_size: int = 1 << 20,
             extra: Optional[dict] = None) -> "IndexStore":
        """Persist an in-memory `SearchIndex` through the same writer path
        the streaming builder uses (initialize -> write_shard* -> finalize),
        so one code path defines the format."""
        store = cls(directory)
        n = int(index.codes.shape[0])
        shard_size = max(1, min(shard_size, n))
        ivf = index.ivf
        global_tree = {
            "centroids": ivf.centroids,
            "centroid_codes": ivf.centroid_codes,
            "centroid_rq_books": ivf.centroid_rq_books,
            "aq_books": index.aq_books,
            "pw_codebooks": index.pw.codebooks,
            "qinco_params": index.qinco_params,
        }
        store.initialize(
            cfg=index.cfg, global_tree=global_tree, n_total=n,
            shard_size=shard_size, k_ivf=int(ivf.centroids.shape[0]),
            cap=int(ivf.buckets.shape[1]), pw_pairs=index.pw.pairs,
            extra=extra)
        codes = np.asarray(index.codes)
        if codes.dtype != CODE_DTYPE:
            codes = pack_codes(codes, index.cfg.K)     # narrow legacy int32
        assign = np.asarray(ivf.assignments)
        aq_norms = np.asarray(index.aq_norms)
        pw_norms = np.asarray(index.pw_norms)
        for sid in range(store.manifest["n_shards"]):
            lo = sid * shard_size
            hi = lo + store.shard_rows(sid)
            store.write_shard(
                sid, codes=PackedCodes(codes[lo:hi], index.cfg.K),
                assign=assign[lo:hi], aq_norms=aq_norms[lo:hi],
                pw_norms=pw_norms[lo:hi])
        store.finalize()
        return store

    # -- stats ---------------------------------------------------------------

    def disk_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.dir.rglob("*")
                   if p.is_file())

    def bytes_per_vector(self) -> float:
        return self.disk_bytes() / max(1, self.manifest["n_total"])


# ---------------------------------------------------------------------------
# out-of-core reader: mmap'd shards + an LRU of device-staged shards
# ---------------------------------------------------------------------------


class _ViewState:
    """One immutable snapshot of what a `ShardedIndexView` is serving —
    the manifest's shard set resolved into scan units ("tokens"), their
    metadata, and the tombstone mask. `refresh()` builds a NEW state and
    swaps it in atomically; a search pins the state it started with
    (`view.pin()` / `view.unpin(st)`) and is therefore immune to any
    concurrent mutation, including a compaction that changes every path.

    Tokens: a base shard keeps its integer id (>= 0); delta shard j is
    token ``-(j + 1)``. Negative ints sort, hash, and key the staging
    pool exactly like shard ids, so nothing downstream special-cases
    deltas — and on an unmutated store tokens ARE the historical shard
    ids, byte-for-byte the same pool keys as before.

    Within-bucket ranks are GROSS (tombstoned rows keep their slots):
    a staged shard's (ext, wbr, aq_norms) is an immutable fact of its
    bytes, so append/delete never invalidate pool entries — only a
    compaction (generation change) retires the owner wholesale.
    ``bucket_fill`` is the ALIVE fill (what a rebuilt survivor store
    would pad with); the gross fill continues in `fill_gross` so new
    delta shards can extend the ranks incrementally."""

    __slots__ = ("owner", "generation", "sig", "tokens", "scan_order",
                 "rows", "lo", "wbr", "hit", "dead", "open_bad",
                 "fill_gross", "bucket_fill", "n_base", "n_rows", "n_dead",
                 "delta_lo", "delta_tokens", "refs")


class ShardedIndexView:
    """Out-of-core view of a store: shards stay mmap'd on disk and are
    staged to the device through a bounded `staging.StagingPool` LRU, so
    database size is independent of device memory (`IndexStore.load` by
    contrast materializes every per-vector array resident).

    What IS loaded up front (all O(model), not O(database)):
      - the global tree (centroids, AQ/pairwise codebooks, QINCo2 params);
      - per-shard bucket metadata derived from one streaming pass over the
        `assign.i32` mmaps (4 B/vector touched once, codes never read):
        each row's within-bucket rank — the slot it occupies in the dense
        bucket table `IndexStore.load` would rebuild — plus the final
        per-bucket fill counts. `core/search.search_sharded` uses these to
        reproduce resident `search()`'s candidate ordering (and therefore
        its `lax.top_k` tie-breaking) bit-identically without ever
        materializing the bucket table.

    Staged per shard (`staged()` / `acquire()`, through the pool's LRU):
      - ``ext``      (rows, M+1) codes ++ assignment column — the shared-
                     codes form `ops.adc_topk` scans; packed uint8 when
                     both K and k_ivf fit a byte, else int32;
      - ``wbr``      (rows,) int32 within-bucket ranks;
      - ``aq_norms`` (rows,) float32.

    Staging goes through a `staging.StagingPool`: a private one sized to
    ``max_resident_shards`` worst-case shards by default, or a caller-
    provided shared ``pool`` so several views (multi-tenant serving)
    split ONE byte budget. The pool adds the latency-hiding machinery —
    `prefetch(sid)` stages a shard on a background thread while the
    current one is being scanned, and a bounded host-side cache of the
    assembled ``ext`` arrays makes an evict -> re-stage cycle a
    `device_put` instead of a fresh concatenate+astype over the shard.

    Also derived in the one assignment pass: a per-shard bucket-occupancy
    bitmap, so `schedule_shards` can drop shards containing zero probed
    buckets and order the scan resident-first (fewer evictions) — the
    rank-keyed merge makes scan order irrelevant to results.

    ``allow_partial`` accepts an incomplete store and searches exactly
    the shards present on disk (ids stay global). Shard 0 must exist —
    its row 0 is the id the resident bucket table pads with.

    Integrity (``verify=True``): the construction-time assignment pass
    first verifies each shard's `assign.i32` on disk (a corrupt
    assignment would otherwise silently poison the running bucket fill,
    and with it every LATER shard's within-bucket ranks); staging then
    verifies the assembled host arrays (codes/assign/aq_norms) once per
    host-cache fill inside `_host_shard`. Any failure quarantines the
    shard: it joins the in-memory ``quarantined`` denylist, bumps
    `index_quarantined_shards_total`, and `search_sharded` either skips
    it (``on_shard_error="skip"``, coverage < 1.0) or propagates the
    `ShardIntegrityError`. `pw_norms.f32` is only read through
    `gather_rows` and is NOT staged, so its corruption is caught by
    `repro.index.fsck`, not at serve time. A shard whose assignment is
    corrupt at open never gets ranks/bitmaps; it is scheduled last and
    treated as relevant to every query for coverage accounting.

    ``faults`` accepts a `faults.FaultPlan` whose injection points wrap
    the host-side read (latency spikes, transient `OSError`s, bit-flip
    corruption of the assembled arrays) and the private pool's prefetch
    worker (death/resurrection). ``faults=None`` (the default) is
    zero-cost: a single `is None` test per hook.

    mmap lifetime: `open_shard` views are materialized (copied) before
    staging and row gathers copy into fresh host arrays, so nothing
    returned by this class (or cached by the pool) aliases the store
    directory — deleting or rewriting the store invalidates only future
    calls, never arrays already handed out.

    Live mutation: everything per-shard above actually lives on an
    immutable `_ViewState` snapshot. `refresh()` resolves the store's
    current manifest (new delta shards from `append`, a new tombstone
    bitmap from `delete`, a new generation from compaction) into a new
    snapshot and swaps it in; `search_sharded` pins the snapshot it
    starts with (`pin`/`unpin`), so admitted queries are never changed
    mid-flight. Delta shards stage through the same pool under negative
    tokens; tombstoned rows are masked inside the fused `adc_topk` scan
    via per-token `dead` bitmaps (see `kernels.ops.TOMBSTONE_PENALTY`).
    On an unmutated store all of this is inert: one snapshot, tokens ==
    shard ids, `dead` empty — the historical bit-exact path.
    """

    def __init__(self, store, *, max_resident_shards: int = 2,
                 allow_partial: bool = False, pool=None,
                 host_cache_bytes: Optional[int] = None,
                 prefetch: bool = True, verify: bool = True,
                 faults=None):
        from repro.core import pairwise as pw_mod
        from repro.index.staging import StagingPool

        self.store = store if isinstance(store, IndexStore) \
            else IndexStore(store)
        m = self.store.manifest
        if not m["complete"] and not allow_partial:
            raise ValueError(
                f"store {self.store.dir} is incomplete; pass "
                f"allow_partial=True to search the completed shards only")
        if max_resident_shards < 1:
            raise ValueError("max_resident_shards must be >= 1")
        self.max_resident_shards = int(max_resident_shards)
        self.cfg = QincoConfig(**m["cfg"])
        self.M = int(m["M"])
        self.K = int(m["K"])
        self.k_ivf = int(m["k_ivf"])
        self.cap = int(m["cap"])
        self.shard_size = int(m["shard_size"])
        self.n_total = int(m["n_total"])

        g = self.store.load_global_tree()
        self.centroids = jnp.asarray(g["centroids"])
        self.aq_books = jnp.asarray(g["aq_books"])
        self.centroid_codes = (None if g["centroid_codes"] is None
                               else jnp.asarray(g["centroid_codes"]))
        self.pw = pw_mod.PairwiseDecoder(
            pairs=tuple(tuple(p) for p in m["pw_pairs"]),
            codebooks=jnp.asarray(g["pw_codebooks"]), K=self.K)
        self.qinco_params = jax.tree.map(jnp.asarray, g["qinco_params"])

        self.verify = bool(verify)
        self.faults = faults
        self.quarantined: set = set()

        # ext dtype: keep the packed-byte wire form whenever it can also
        # carry the assignment column (kernels widen in-VMEM either way)
        self._ext_dtype = (np.uint8 if self.K <= 256 and self.k_ivf <= 256
                           else np.int32)
        # worst-case staged shard = one full shard_size unit — delta
        # shards are sealed at <= shard_size rows precisely so they fit
        # the same bound (and on a complete store a full base shard IS
        # shard_size rows, so this equals the historical per-shard max)
        worst = self.shard_size * (
            (self.M + 1) * np.dtype(self._ext_dtype).itemsize + 4 + 4)
        # ``prefetch`` configures the PRIVATE pool only (a shared pool's
        # policy belongs to whoever constructed it)
        self.pool = pool if pool is not None else StagingPool(
            self.max_resident_shards * worst,
            max_entries=self.max_resident_shards,
            host_cache_bytes=host_cache_bytes, prefetch=prefetch,
            faults=faults)
        self._lock = threading.Lock()
        self._refresh_lock = threading.Lock()
        self._retired: List[_ViewState] = []
        self._st = self._build_state(None)
        _G_GENERATION.set(self._st.generation)
        self.skipped_shards_total = 0

    # -- state snapshots: build / pin / refresh ------------------------------

    def _build_state(self, prev: Optional[_ViewState]) -> "_ViewState":
        """Resolve the store's CURRENT manifest into a `_ViewState`.
        With ``prev`` of the same generation, the pass is incremental:
        ranks/bitmaps are computed only for tokens prev hasn't seen
        (one pass per NEW shard), continuing prev's gross fill — valid
        because within a generation shards are only ever added (a
        builder extends the base prefix of an incomplete store; `append`
        seals new deltas on a complete one; never both)."""
        from repro.core import ivf as ivf_mod
        store = self.store
        m = store.manifest
        gen = int(m.get("generation", 0))
        base_ids = [s for s in range(m["n_shards"]) if store.shard_done(s)]
        deltas = [(int(d["id"]), int(d["rows"]))
                  for d in (m.get("deltas") or [])]
        t = m.get("tombstone")
        sig = (gen, len(base_ids), tuple(d for d, _ in deltas),
               None if t is None else int(t["seq"]))
        if prev is not None and prev.sig == sig:
            return prev
        if not base_ids:
            raise ValueError(f"store {store.dir} has no completed "
                             f"shards to search")
        if base_ids[0] != 0:
            raise ValueError("shard 0 is required (bucket-table padding "
                             "ids resolve to row 0)")

        st = _ViewState()
        st.generation = gen
        st.sig = sig
        st.refs = 0
        st.n_base = int(m["n_total"])
        st.scan_order = list(base_ids) + [-(d + 1) for d, _ in deltas]
        st.tokens = sorted(st.scan_order)
        st.rows = {s: store.shard_rows(s) for s in base_ids}
        st.lo = {s: s * self.shard_size for s in base_ids}
        off = st.n_base
        st.delta_tokens = []
        dlo = []
        for did, r in deltas:
            tok = -(did + 1)
            st.rows[tok] = r
            st.lo[tok] = off
            st.delta_tokens.append(tok)
            dlo.append(off)
            off += r
        st.delta_lo = np.asarray(dlo, np.int64)
        st.n_rows = sum(st.rows.values())

        incremental = prev is not None and prev.generation == gen
        if incremental:
            st.owner = prev.owner
            wbr, hit = dict(prev.wbr), dict(prev.hit)
            fill = prev.fill_gross.copy()
            open_bad = set(prev.open_bad)
            done = set(prev.scan_order)
        else:
            st.owner = self.pool.register()
            wbr, hit = {}, {}
            fill = np.zeros(self.k_ivf, np.int64)
            open_bad, done = set(), set()

        # one pass over each NEW token's assign mmap: within-bucket ranks
        # continuing the running GROSS fill, plus the bucket-occupancy
        # bitmap probe-aware scheduling skips on
        for tok in st.scan_order:
            if tok in done:
                continue
            if self.verify:
                try:
                    if tok < 0:
                        store.verify_delta(-tok - 1, fields=["assign"])
                    else:
                        store.verify_shard(tok, fields=["assign"])
                except ShardIntegrityError:
                    self._quarantine(tok)
                    open_bad.add(tok)
                    continue
            a = np.asarray(self._open_token(tok, st)["assign"])
            wbr[tok], new_fill = ivf_mod.within_bucket_ranks(
                a, self.k_ivf, fill)
            hit[tok] = new_fill > fill            # (k_ivf,) bool
            fill = new_fill
        st.wbr, st.hit, st.open_bad, st.fill_gross = wbr, hit, open_bad, fill

        # tombstones: slice the global bitmap into per-token dead masks
        # (None for all-alive tokens keeps the historical bit-exact jit
        # variant) and subtract dead rows from the padding fill
        st.dead = {}
        st.n_dead = 0
        alive = fill
        if t is not None:
            bits = store.tombstone_bits(n_rows=st.n_base + sum(
                r for _, r in deltas))
            dead_fill = np.zeros(self.k_ivf, np.int64)
            for tok in st.scan_order:
                if tok in open_bad:
                    continue
                db = bits[st.lo[tok]:st.lo[tok] + st.rows[tok]]
                if db.any():
                    st.dead[tok] = np.ascontiguousarray(db)
                    st.n_dead += int(np.count_nonzero(db))
                    a = np.asarray(self._open_token(tok, st)["assign"])
                    dead_fill += np.bincount(a[db], minlength=self.k_ivf)
            alive = fill - dead_fill
        st.bucket_fill = jnp.asarray(alive.astype(np.int32))   # (k_ivf,)
        return st

    def _open_token(self, token: int, st: "_ViewState") -> dict:
        """mmap one token's files, addressed entirely through the state
        snapshot (a retired state keeps reading its own generation's
        paths even after the manifest moved on)."""
        if token < 0:
            d = self.store.delta_dir(-token - 1)
        else:
            d = self.store.shard_dir(token, generation=st.generation)
        return self.store._open_array_dir(d, st.rows[token])

    def pin(self) -> "_ViewState":
        """Pin the current state for one search call: everything the
        call touches (tokens, ranks, dead masks, pool keys) comes from
        this snapshot, so a concurrent `refresh` never changes a search
        already admitted. Balance with `unpin`."""
        with self._lock:
            st = self._st
            st.refs += 1
            return st

    def unpin(self, st: "_ViewState") -> None:
        with self._lock:
            st.refs -= 1
        self._maybe_gc()

    def refresh(self) -> bool:
        """Re-read the manifest and adopt newly published deltas,
        tombstones, or a compacted generation without reopening the
        view. Returns True when anything changed. In-flight searches
        keep their pinned snapshot; after a generation change the old
        state's staged entries are dropped — and the superseded on-disk
        files unlinked — only once its last pin releases."""
        with self._refresh_lock:
            self.store.reload_manifest()
            prev = self._st
            new = self._build_state(prev)
            if new is prev:
                return False
            with self._lock:
                self._st = new
                if new.owner != prev.owner:
                    self._retired.append(prev)
                    # a new generation rewrote every path: stale verdicts
                    # (and stale open_bad) do not carry over
                    self.quarantined = set()
            _C_REFRESH.inc()
            _G_GENERATION.set(new.generation)
        self._maybe_gc()
        return True

    def _maybe_gc(self) -> None:
        """Drop retired states whose last pin (and last pool pin) has
        released; once none remain, unlink the files the current
        manifest no longer references. This is the unlink-after-release
        rule compaction relies on: the compactor itself never unlinks."""
        drop, gc_store = [], False
        with self._lock:
            still = []
            for st in self._retired:
                if st.refs == 0 and self.pool.owner_pins(st.owner) == 0:
                    drop.append(st)
                else:
                    still.append(st)
            self._retired = still
            if drop and not still:
                cur_gen = self._st.generation
                gc_store = any(st.generation != cur_gen for st in drop)
        for st in drop:
            self.pool.drop_owner(st.owner)
        if gc_store:
            try:
                self.store.gc_orphans()
            except OSError:
                pass

    # -- legacy single-state attribute shims ---------------------------------

    @property
    def shard_ids(self) -> list:
        return list(self._st.tokens)

    @property
    def n_rows(self) -> int:
        """Gross rows served (base + deltas, tombstoned rows included)."""
        return self._st.n_rows

    @property
    def n_alive(self) -> int:
        return self._st.n_rows - self._st.n_dead

    @property
    def generation(self) -> int:
        return self._st.generation

    @property
    def _owner(self) -> int:
        return self._st.owner

    @property
    def _wbr(self) -> dict:
        return self._st.wbr

    @property
    def _bucket_hit(self) -> dict:
        return self._st.hit

    @property
    def _open_bad(self) -> set:
        return self._st.open_bad

    @property
    def bucket_fill(self):
        return self._st.bucket_fill

    def _quarantine(self, shard_id: int) -> None:
        if shard_id not in self.quarantined:
            self.quarantined.add(shard_id)
            _C_QUARANTINED.inc()

    # -- staging through the pool --------------------------------------------

    def shard_staged_bytes(self, shard_id: int, st=None) -> int:
        """Device bytes one staged token costs (ext + wbr + aq_norms)."""
        st = self._st if st is None else st
        rows = st.rows[shard_id]
        return rows * ((self.M + 1) * np.dtype(self._ext_dtype).itemsize
                       + 4 + 4)

    @property
    def budget_bytes(self) -> int:
        """The pool's staging budget (for a private pool:
        ``max_resident_shards`` worst-case shards). `peak_resident_bytes`
        never exceeds this (asserted in tests) — the out-of-core
        guarantee that device residency is bounded by the LRU, not the
        database."""
        return self.pool.budget_bytes

    @property
    def resident_shards(self):
        return self.pool.resident_keys(self._st.owner)

    @property
    def resident_bytes(self) -> int:
        return self.pool.resident_bytes

    @property
    def peak_resident_bytes(self) -> int:
        return self.pool.peak_resident_bytes

    def _host_shard(self, shard_id: int, st=None) -> dict:
        """Assemble one token's host-side scan arrays (the expensive part
        of staging — mmap read + concatenate + astype; the unit the
        pool's host cache holds on to). Returns fresh arrays only, never
        mmap views (the pool's no-aliasing contract). Base shards and
        delta shards assemble identically — only the source dir differs.

        This is also the integrity choke point: with ``verify`` on, the
        read-back bytes are size- and crc-checked here, i.e. once per
        host-cache FILL (a cache hit replays already-verified arrays), so
        steady-state acquires pay nothing. A failure quarantines the
        shard and raises `ShardIntegrityError` — the pool aborts the
        reservation and `search_sharded` decides skip-vs-raise."""
        st = self._st if st is None else st
        if self.faults is not None:
            self.faults.on_read(shard_id)      # may sleep / raise OSError
        sh = self._open_token(shard_id, st)
        arrays = {"codes": np.asarray(sh["codes"]),
                  "assign": np.asarray(sh["assign"]),
                  "aq_norms": np.asarray(sh["aq_norms"])}
        if self.faults is not None and self.faults.corrupts(shard_id):
            arrays = self.faults.corrupt_arrays(shard_id, arrays)
        if self.verify:
            try:
                if shard_id < 0:
                    self.store._verify_dir(
                        self.store.delta_dir(-shard_id - 1),
                        st.rows[shard_id], f"delta {-shard_id - 1:05d}",
                        arrays=arrays)
                else:
                    self.store._verify_dir(
                        self.store.shard_dir(shard_id,
                                             generation=st.generation),
                        st.rows[shard_id], shard_id, arrays=arrays)
            except ShardIntegrityError:
                self._quarantine(shard_id)
                raise
        ext = np.concatenate(
            [arrays["codes"].astype(self._ext_dtype, copy=False),
             arrays["assign"].astype(self._ext_dtype)[:, None]], axis=1)
        return {"ext": ext, "wbr": st.wbr[shard_id],
                "aq_norms": arrays["aq_norms"]}

    def acquire(self, shard_id: int, st=None) -> dict:
        """Device-staged arrays for one token, pinned until `release`."""
        from functools import partial
        st = self._st if st is None else st
        return self.pool.acquire((st.owner, shard_id),
                                 partial(self._host_shard, shard_id, st),
                                 self.shard_staged_bytes(shard_id, st))

    def release(self, shard_id: int, st=None) -> None:
        st = self._st if st is None else st
        self.pool.release((st.owner, shard_id))

    def prefetch(self, shard_id: int, st=None) -> bool:
        """Stage a token in the background (evict-at-issue; see
        `staging.StagingPool.prefetch`). Safe to call speculatively.
        Quarantined shards are refused — re-reading them can only fail
        the same integrity check again."""
        if shard_id in self.quarantined:
            return False
        from functools import partial
        st = self._st if st is None else st
        return self.pool.prefetch((st.owner, shard_id),
                                  partial(self._host_shard, shard_id, st),
                                  self.shard_staged_bytes(shard_id, st))

    def staged(self, shard_id: int, st=None) -> dict:
        """Device-staged arrays for one token, through the LRU
        (unpinned — the single-threaded convenience form of `acquire`)."""
        entry = self.acquire(shard_id, st)
        self.release(shard_id, st)
        return entry

    # -- probe-aware scan scheduling -----------------------------------------

    def schedule_shards(self, probed_buckets, st=None) -> list:
        """Scan order for one query batch: tokens with zero probed
        buckets are dropped (their rows could only contribute non-probed
        `-inf` entries, which the rank-keyed merge never selects —
        padding always supplies enough better-ranked slots), and the
        remainder is ordered resident-tokens-first to minimize evictions
        under a tight budget. The merge is keyed by resident-candidate
        rank, so any order is bit-identical. Occupancy bitmaps are GROSS:
        a token whose probed rows are all tombstoned still folds (its
        dead rows score below every finite candidate), trading a little
        scan waste for never having to rebuild bitmaps on delete."""
        st = self._st if st is None else st
        probed = np.unique(np.asarray(probed_buckets).reshape(-1))
        hit = [s for s in st.scan_order if s not in st.open_bad
               and bool(st.hit[s][probed].any())]
        skipped = len(st.scan_order) - len(st.open_bad) - len(hit)
        self.skipped_shards_total += skipped      # legacy per-view attr
        if skipped:
            _C_SKIPPED.inc(skipped)
        resident = set(self.pool.resident_keys(st.owner))
        # tokens quarantined at open have no occupancy bitmap, so they
        # cannot be probe-skipped: schedule them last — the search loop
        # raises or skips per its error policy, and coverage accounting
        # needs to see them as scheduled-but-unusable
        return ([s for s in hit if s in resident]
                + [s for s in hit if s not in resident]
                + sorted(st.open_bad))

    # -- shortlist row gather (steps 3-4 of the cascade) ---------------------

    def gather_rows(self, gids, st=None):
        """Host gather of shortlist rows straight off the shard mmaps:
        only the requested rows' bytes are touched (the out-of-core
        re-rank reads O(Q * shortlist), not O(N)).

        Base ids resolve by division (manifest addressing, id gaps where
        shards are missing); ids >= the base row count resolve into delta
        shards through the state's start offsets. All paths are addressed
        through the pinned state, so a gather keeps working mid-compaction.

        gids: int array of GLOBAL ids, any shape -> (codes uint8
        (..., M), assign int32 (...,), pw_norms float32 (...,)).
        """
        st = self._st if st is None else st
        gids = np.asarray(gids)
        flat = gids.reshape(-1).astype(np.int64)
        codes = np.empty((flat.size, self.M), np.uint8)
        assign = np.empty(flat.size, np.int32)
        pw_norms = np.empty(flat.size, np.float32)
        base_sel = flat < st.n_base
        sid_of = np.where(base_sel, flat // self.shard_size, np.int64(-1))
        for sid in np.unique(sid_of[base_sel]):
            sid = int(sid)
            if sid not in st.rows:
                raise ValueError(f"row gather hit missing shard {sid} "
                                 f"(id outside the searched set?)")
            sel = sid_of == sid
            sh = self._open_token(sid, st)
            loc = flat[sel] - sid * self.shard_size
            codes[sel] = sh["codes"][loc]
            assign[sel] = sh["assign"][loc]
            pw_norms[sel] = sh["pw_norms"][loc]
        if not base_sel.all():
            rest = np.nonzero(~base_sel)[0]
            if st.delta_lo.size == 0 or \
                    flat[rest].max() >= st.n_base + \
                    sum(st.rows[t] for t in st.delta_tokens):
                raise ValueError(f"row gather hit id beyond the served "
                                 f"rows (id outside the searched set?)")
            which = np.searchsorted(st.delta_lo, flat[rest],
                                    side="right") - 1
            for w in np.unique(which):
                tok = st.delta_tokens[int(w)]
                sel = rest[which == w]
                sh = self._open_token(tok, st)
                loc = flat[sel] - st.lo[tok]
                codes[sel] = sh["codes"][loc]
                assign[sel] = sh["assign"][loc]
                pw_norms[sel] = sh["pw_norms"][loc]
        return (codes.reshape(gids.shape + (self.M,)),
                assign.reshape(gids.shape),
                pw_norms.reshape(gids.shape))
