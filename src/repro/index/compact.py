"""Background compaction: fold delta shards + tombstones into base shards.

`Compactor.run` rewrites a mutated store (sealed delta shards from
`IndexStore.append`, a tombstone bitmap from `IndexStore.delete`) into a
fresh base-shard **generation** holding exactly the surviving rows in
global-id order, then swaps the manifest atomically. The output is
byte-identical to `IndexStore.save` over the same survivor arrays — both
publish through `IndexStore._publish_array_dir`, so "compaction == fresh
build of the survivors" is structural (and fsck-verifiable), not a
coincidence kept alive by tests.

Crash safety / resume:
  - every output shard publishes atomically (tmp dir + rename + fsync),
    so a killed compactor never leaves a half shard under a final name;
  - `compact_cursor.json` records the target generation AND the mutation
    signature being folded (delta ids + tombstone seq). A resume whose
    live signature still matches skips already-published output shards;
    a mismatch (more mutations landed since) wipes the partial target
    generation and starts over — the cursor is advisory, shard presence
    is ground truth, exactly like the build cursor;
  - the manifest swap is the commit point: readers see the old
    generation in full, then the new generation in full, never a mix.

The compactor NEVER unlinks superseded files (old-generation shards,
folded delta dirs, the old tombstone bitmap). That is `gc_orphans`'s
job, and the live `ShardedIndexView` runs it only after the last search
pinned to the old state releases — the unlink-after-release rule
(docs/INDEX_FORMAT.md "Mutation", docs/SERVING.md "Graceful drain").

CLI:  python -m repro.index.compact STORE [--gc] [--json]
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Optional

import numpy as np

from repro import obs
from repro.index.store import (MUTATED_FORMAT_VERSION, IndexStore,
                               _durable_write_text, _fsync_path)

_C_RUNS = obs.counter(
    "compact_runs_total", "compaction runs that published a new generation")
_C_SHARDS = obs.counter(
    "compact_shards_written_total",
    "base shards written by the compactor (resume skips count nothing)")
_C_DROPPED = obs.counter(
    "compact_rows_dropped_total", "tombstoned rows dropped by compaction")
_C_SECONDS = obs.counter(
    "compact_seconds_total", "wall seconds spent inside Compactor.run")


class Compactor:
    """Merge a store's pending mutation state into a new base generation.

    Single-writer by contract (like the builder): at most one compactor
    per store at a time, and it must not race `append`/`delete` — the
    signature check turns such a race into a clean restart, not
    corruption, but concurrent mutators should simply pause mutation
    while a compaction runs (the CI smoke does exactly that)."""

    def __init__(self, store, *, verify: bool = True):
        self.store = store if isinstance(store, IndexStore) \
            else IndexStore(store)
        self.verify = bool(verify)

    # -- survivor gather -----------------------------------------------------

    def _gather_survivors(self, bits: np.ndarray) -> dict:
        """Host arrays of the alive rows in global-id order: base shards
        first (manifest order), then deltas (append order) — the same
        order a fresh build over the survivor vectors would encode."""
        store = self.store
        m = store.manifest
        units = []                                  # (arrays-dict, lo, rows)
        for sid in range(m["n_shards"]):
            if self.verify:
                store.verify_shard(sid)
            units.append((store.open_shard(sid), sid * m["shard_size"],
                          store.shard_rows(sid)))
        for d in store.deltas:
            did = int(d["id"])
            if self.verify:
                store.verify_delta(did)
        lo = m["n_total"]
        for d in store.deltas:
            did, rows = int(d["id"]), int(d["rows"])
            units.append((store.open_delta(did), lo, rows))
            lo += rows
        n_alive = int(np.count_nonzero(~bits))
        out = {
            "codes": np.empty((n_alive, m["M"]), np.uint8),
            "assign": np.empty(n_alive, np.int32),
            "aq_norms": np.empty(n_alive, np.float32),
            "pw_norms": np.empty(n_alive, np.float32),
        }
        at = 0
        for sh, lo, rows in units:
            alive = ~bits[lo:lo + rows]
            k = int(np.count_nonzero(alive))
            if k == 0:
                continue
            for name, arr in out.items():
                arr[at:at + k] = np.asarray(sh[name])[alive]
            at += k
        assert at == n_alive
        return out

    # -- the run -------------------------------------------------------------

    def run(self, *, max_shards: Optional[int] = None) -> dict:
        """Fold pending deltas + tombstones into generation ``gen + 1``.

        ``max_shards`` bounds how many NEW output shards this call
        publishes before returning (cursor left in place) — the hook the
        kill/resume tests use to stop the compactor at a deterministic
        point; a later `run()` resumes from the published prefix.

        Returns a report dict; ``compacted`` is False when the store had
        nothing pending (the no-op case) or True once the new manifest
        published. ``partial`` marks a `max_shards` early return."""
        t0 = time.perf_counter()
        store = self.store
        m = store.reload_manifest()
        if not m["complete"]:
            raise ValueError(f"store {store.dir} is incomplete; only a "
                             f"finalized store can be compacted")
        if not store.mutated:
            return {"compacted": False, "reason": "no pending mutation"}
        gen = store.generation
        target = gen + 1
        t = m.get("tombstone")
        sig = {"deltas": [int(d["id"]) for d in store.deltas],
               "tombstone_seq": None if t is None else int(t["seq"])}

        bits = store.tombstone_bits()               # verified vs manifest
        n_alive = int(np.count_nonzero(~bits))
        if n_alive == 0:
            raise ValueError(f"refusing to compact {store.dir} to an "
                             f"empty store (every row is tombstoned)")

        gen_root = store.dir / "shards" / f"gen_{target:03d}"
        cur = store.read_compact_cursor()
        if cur is not None and (int(cur.get("generation", -1)) != target
                                or cur.get("sig") != sig):
            # mutation state moved on (or a stale cursor from a published
            # run survived): the partial output folds the WRONG row set
            shutil.rmtree(gen_root, ignore_errors=True)
            try:
                os.unlink(store.compact_cursor_path)
            except OSError:
                pass
        tmp = store.compact_cursor_path.with_suffix(".tmp")
        _durable_write_text(tmp, json.dumps(
            {"generation": target, "sig": sig, "n_alive": n_alive}))
        os.rename(tmp, store.compact_cursor_path)
        _fsync_path(store.dir)

        arrs = self._gather_survivors(bits)
        shard_size = int(m["shard_size"])
        n_shards_new = -(-n_alive // shard_size)
        written = 0
        for sid in range(n_shards_new):
            final = gen_root / f"shard_{sid:05d}"
            if (final / "codes.u8").exists():
                continue                            # resume: already published
            if max_shards is not None and written >= max_shards:
                _C_SECONDS.inc(time.perf_counter() - t0)
                return {"compacted": False, "partial": True,
                        "generation": target, "shards_written": written,
                        "shards_total": n_shards_new}
            lo = sid * shard_size
            rows = min(shard_size, n_alive - lo)
            store._publish_array_dir(
                final, {name: arr[lo:lo + rows]
                        for name, arr in arrs.items()},
                rows, f"shard {sid}")
            written += 1
        _C_SHARDS.inc(written)

        manifest = dict(m, n_total=n_alive, n_shards=int(n_shards_new),
                        generation=target, deltas=[], tombstone=None,
                        format_version=MUTATED_FORMAT_VERSION,
                        complete=True)
        store._write_manifest(manifest)             # the commit point
        try:
            os.unlink(store.compact_cursor_path)
        except OSError:
            pass
        _fsync_path(store.dir)
        dropped = int(np.count_nonzero(bits))
        _C_RUNS.inc()
        _C_DROPPED.inc(dropped)
        _C_SECONDS.inc(time.perf_counter() - t0)
        return {"compacted": True, "generation": target,
                "n_alive": n_alive, "rows_dropped": dropped,
                "shards_written": written, "shards_total": n_shards_new}


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.index.compact",
        description="Fold a store's delta shards + tombstones into a new "
                    "base generation (atomic manifest swap; superseded "
                    "files are left for gc)")
    p.add_argument("store", help="index store directory")
    p.add_argument("--gc", action="store_true",
                   help="also unlink superseded files afterwards — ONLY "
                        "safe when no live reader is pinned to the old "
                        "generation (an attached server gc's for itself "
                        "after its refresh)")
    p.add_argument("--max-shards", type=int, default=None,
                   help="publish at most N new shards then stop (resume "
                        "later); test/ops hook")
    p.add_argument("--json", action="store_true", help="machine output")
    args = p.parse_args(argv)
    report = Compactor(args.store).run(max_shards=args.max_shards)
    if report.get("compacted") and args.gc:
        removed = IndexStore(args.store).gc_orphans()
        report["gc_removed"] = [str(r) for r in removed]
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        print(report)
    return 0 if report.get("compacted") or "reason" in report else 1


if __name__ == "__main__":
    raise SystemExit(main())
