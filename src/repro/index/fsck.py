"""Store integrity audit: ``python -m repro.index.fsck STORE_DIR``.

Walks one `IndexStore` end to end — manifest, global checkpoint tree,
every shard (all four per-vector files, sizes always, crc32 when the
shard has a checksum sidecar), every delta shard, the live tombstone
bitmap (bytes + crc32 + popcount vs the manifest record), the compact
cursor, and the resume cursors — and reports
every problem it finds, naming the exact shard and file. Exit status 0
means clean (warnings like legacy unchecksummed shards or a stale
cursor do not fail the audit); 1 means at least one hard error.

This is the offline complement to the serve-time checks: staging only
verifies the fields it stages (codes/assign/aq_norms, once per
host-cache fill) and `pw_norms.f32` is only ever touched by shortlist
row gathers, so a full sweep — including shards a query never probed —
needs this tool. Run it before blessing a store for serving, after any
storage incident, and on anything a resumed build just repaired.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.index.store import (IndexStore, ShardIntegrityError,
                               _SHARD_FIELDS)


def fsck_store(store, *, verbose: bool = False, log=print) -> dict:
    """Audit one store; returns a JSON-able report dict.

    Report keys: ``ok`` (no hard errors), ``errors`` (list of strings,
    each naming shard/file/reason), ``shards_ok`` / ``shards_corrupt`` /
    ``shards_missing`` (ids), ``legacy_unchecksummed`` (intact but
    size-check-only), ``warnings`` (non-fatal findings).
    """
    store = store if isinstance(store, IndexStore) else IndexStore(store)
    report = {"dir": str(store.dir), "ok": True, "errors": [],
              "warnings": [], "shards_ok": [], "shards_corrupt": [],
              "shards_missing": [], "legacy_unchecksummed": []}

    def error(msg):
        report["ok"] = False
        report["errors"].append(msg)
        log(f"[fsck] ERROR: {msg}")

    def warn(msg):
        report["warnings"].append(msg)
        log(f"[fsck] warning: {msg}")

    try:
        m = store.manifest
    except Exception as e:
        error(f"manifest: {type(e).__name__}: {e}")
        return report
    try:
        store.load_global_tree()
    except Exception as e:
        error(f"global tree: {type(e).__name__}: {e}")

    n_shards = m["n_shards"]
    for sid in range(n_shards):
        if not store.shard_done(sid):
            report["shards_missing"].append(sid)
            if m["complete"]:
                error(f"shard {sid:05d}: missing from a complete store")
            continue
        try:
            store.verify_shard(sid, fields=list(_SHARD_FIELDS))
        except ShardIntegrityError as e:
            report["shards_corrupt"].append(sid)
            error(str(e))
            continue
        report["shards_ok"].append(sid)
        if store.shard_checksums(sid) is None:
            report["legacy_unchecksummed"].append(sid)
        if verbose:
            log(f"[fsck] shard {sid:05d}: ok")
    if report["legacy_unchecksummed"]:
        warn(f"{len(report['legacy_unchecksummed'])} shard(s) predate the "
             f"checksum sidecar (sizes verified, content not)")
    if report["shards_missing"] and not m["complete"]:
        warn(f"store incomplete: {len(report['shards_missing'])} shard(s) "
             f"not yet built")

    # -- mutation state (format v2): delta shards + tombstone bitmap ------
    report["deltas_ok"] = []
    report["deltas_corrupt"] = []
    for d in store.deltas:
        did = int(d["id"])
        try:
            store.verify_delta(did, fields=list(_SHARD_FIELDS))
        except ShardIntegrityError as e:
            report["deltas_corrupt"].append(did)
            error(str(e))
            continue
        report["deltas_ok"].append(did)
        if verbose:
            log(f"[fsck] delta {did:05d}: ok")
    if m.get("tombstone") is not None:
        try:
            bits = store.tombstone_bits()
            t = m["tombstone"]
            if int(bits.sum()) != int(t["n_deleted"]):
                error(f"tombstone {t['seq']:08d}: popcount "
                      f"{int(bits.sum())} != manifest n_deleted "
                      f"{t['n_deleted']}")
        except (ShardIntegrityError, OSError) as e:
            error(f"tombstone: {e}")
    cc = store.read_compact_cursor()
    if cc is not None:
        live_sig = {"deltas": [int(d["id"]) for d in store.deltas],
                    "tombstone_seq": None if m.get("tombstone") is None
                    else int(m["tombstone"]["seq"])}
        if int(cc.get("generation", -1)) != store.generation + 1 \
                or cc.get("sig") != live_sig:
            warn("compact_cursor.json: stale (compaction published or the "
                 "mutation set moved on; the next run restarts cleanly)")
        else:
            warn("compact_cursor.json: compaction in progress (advisory; "
                 "partial target-generation shards are expected)")

    # orphans: on-disk state the live manifest no longer references —
    # harmless (a reader pinned to the old generation may still need
    # them) but worth surfacing so operators know gc has not run yet
    orphans = store.orphan_paths()
    if orphans:
        warn(f"{len(orphans)} superseded path(s) awaiting gc "
             f"(old generations / folded deltas / stale tombstones); "
             f"run gc_orphans() or `python -m repro.index.compact --gc` "
             f"once no reader is pinned to the old generation")

    done = set(report["shards_ok"]) | set(report["shards_corrupt"])
    for path in sorted(store.dir.glob("cursor*.json")):
        owner = 0 if path.name == "cursor.json" \
            else int(path.stem.split("_")[1])
        cur = store.read_cursor(owner=owner)
        if cur is None:
            warn(f"{path.name}: unreadable (advisory only; resume will "
                 f"re-scan)")
        elif any(s not in done for s in range(cur["next_shard"])):
            warn(f"{path.name}: next_shard={cur['next_shard']} but an "
                 f"earlier shard is absent (stale cursor; resume "
                 f"re-validates against disk)")

    log(f"[fsck] {store.dir}: "
        f"{len(report['shards_ok'])}/{n_shards} shards ok, "
        f"{len(report['shards_corrupt'])} corrupt, "
        f"{len(report['shards_missing'])} missing -> "
        f"{'CLEAN' if report['ok'] else 'ERRORS'}")
    return report


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.index.fsck",
        description="Audit an index store's integrity (sizes + checksums "
                    "for every shard file; manifest, global tree, cursors).")
    p.add_argument("store", help="store directory (contains manifest.json)")
    p.add_argument("--json", action="store_true",
                   help="emit the full report as JSON on stdout")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="log every shard, not just problems")
    args = p.parse_args(argv)
    quiet = (lambda *a, **k: None) if args.json else print
    report = fsck_store(args.store, verbose=args.verbose, log=quiet)
    if args.json:
        print(json.dumps(report, indent=1))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
