"""Packed code containers: uint8 end-to-end (ROADMAP "pack codes int8").

QINCo2 codes have alphabet K <= 256 in every paper setting (8/16-byte
regimes), i.e. one byte per quantization step. The repo historically kept
codes as int32 `(N, M)` arrays — 4x the HBM footprint and 4x the
host->device wire of the information content. `PackedCodes` makes uint8
the canonical at-rest representation; `kernels/ops.adc_scores` /
`pairwise_scores` consume the packed bytes directly (widening to int32
only inside the kernel), so packed bytes are what lives in HBM.

Works on both numpy (host/store side) and jax (device side) arrays: all
helpers preserve the input's array namespace.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

CODE_DTYPE = np.uint8          # the packed on-disk / HBM code dtype
MAX_PACKED_K = 256             # alphabet that fits one byte


def packable(K: int) -> bool:
    """True when a K-ary alphabet fits the packed byte representation."""
    return 0 < K <= MAX_PACKED_K


def pack_codes(codes, K: int):
    """Narrow integer codes to uint8. codes: (..., M) int, values in
    [0, K); K must be <= 256. numpy in -> numpy out, jax in -> jax out."""
    if not packable(K):
        raise ValueError(
            f"cannot pack alphabet K={K} into uint8 (max {MAX_PACKED_K}); "
            f"keep int32 codes for larger alphabets")
    return codes.astype(CODE_DTYPE)


def unpack_codes(codes):
    """Widen packed codes back to int32 (for arithmetic on code values)."""
    return codes.astype(np.int32)


@dataclasses.dataclass
class PackedCodes:
    """A `(N, M)` uint8 code matrix plus the metadata that makes the raw
    bytes self-describing (alphabet, packing invariants).

    This is the unit the store shards and the builder emits: `.codes` is
    exactly what `store.write_shard` puts on disk and what `ops.adc_scores`
    scans in HBM.
    """
    codes: Any                   # (N, M) uint8 (np.ndarray or jax array)
    K: int                       # code alphabet (values are < K <= 256)

    def __post_init__(self):
        if self.codes.dtype != CODE_DTYPE:
            raise ValueError(f"PackedCodes wants {np.dtype(CODE_DTYPE)} "
                             f"codes, got {self.codes.dtype}")
        if not packable(self.K):
            raise ValueError(f"alphabet K={self.K} does not fit uint8")

    @classmethod
    def pack(cls, codes, K: int) -> "PackedCodes":
        return cls(pack_codes(codes, K), K)

    def unpack(self):
        return unpack_codes(self.codes)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def nbytes(self) -> int:
        """Storage footprint: exactly N * M bytes (1 byte/step)."""
        return int(np.prod(self.codes.shape))

    @property
    def bytes_per_vector(self) -> int:
        return int(self.codes.shape[-1])

    def __len__(self) -> int:
        return int(self.codes.shape[0])

    def __getitem__(self, sl) -> "PackedCodes":
        return PackedCodes(self.codes[sl], self.K)


jax.tree_util.register_dataclass(
    PackedCodes, data_fields=("codes",), meta_fields=("K",))
