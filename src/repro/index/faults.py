"""Seeded, deterministic fault injection for the out-of-core stack.

At billion scale the storage layer misbehaves routinely: reads return
garbage, stall, or fail outright, and background threads die. The
serving stack's answer (integrity checksums + quarantine in
`store.py`, retry/worker-resurrection in `staging.py`, skip/deadline
degradation in `core/search.py`) is only trustworthy if the failure
paths actually run — so this module gives tests and the chaos CI smoke
a way to drive them against a perfectly healthy disk.

`FaultPlan` is a pure decision oracle: every fault decision is a hash
of ``(seed, kind, key, ...)``, so a plan is deterministic across
processes and thread schedules — two runs with the same seed inject
the same faults at the same injection points, which is what lets the
chaos tests assert exact outcomes (and lets a failing chaos run be
replayed). Injection points live in `ShardedIndexView._host_shard`
(read latency / transient errors / bit-flip corruption of the
assembled arrays) and `StagingPool._worker_loop` (prefetch-worker
death); a view or pool constructed without a plan pays nothing — the
hooks are a single ``is None`` check.

Fault kinds:

  - **latency spike** — ``time.sleep(latency_s)`` before a shard read;
    decided per (key, attempt), exercises deadline ejection.
  - **transient read error** — raises `TransientReadError` (an
    `OSError`, what a flaky block device surfaces); decided per
    (key, attempt), so a retry usually clears it. The staging retry
    path must absorb these with zero result impact.
  - **bit-flip corruption** — flips one bit in one of the assembled
    host arrays; decided per key only (PERSISTENT for the run, like
    real media corruption), so retries cannot clear it and the
    integrity check must quarantine the shard.
  - **worker death** — the prefetch worker thread exits mid-queue;
    decided per job sequence number. The pool must resurrect it and
    `acquire` must recover the in-flight shard.

Network fault kinds (injected CLIENT-side by
`repro.launch.search_client`, exercising the `repro.launch.transport`
server the way the storage kinds exercise staging — the server must
survive all four without a crash, a hang, or a duplicate answer):

  - **connection drop** — the client opens a connection, writes part of
    the request frame, and drops it; the server's reader must discard
    the truncated frame (`transport_conn_aborts_total`) and the client
    retries on a fresh connection (the request was never admitted, so
    the retry cannot duplicate work). Decided per (key, attempt) so a
    retry usually goes through.
  - **slow / partial writes** — the request frame is dribbled out in
    small chunks with sleeps between them; the server's `_recv_exact`
    loop must reassemble it (partial reads are normal, not errors).
  - **malformed frame** — a valid length prefix around a garbage
    payload; the server answers `INVALID_ARGUMENT`
    (`transport_frame_errors_total`) and closes. Decided per key and
    NOT retried-away — the client sends the real request as a separate
    fresh attempt (a malformed frame is a client bug in production,
    chaos fodder here).
  - **client vanish** — the full request is sent but the client
    disconnects without reading the response; the server's write fails
    (`transport_send_failures_total`) and the query still counts as
    answered exactly once. Decided per (key, attempt).
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict, Optional

import numpy as np

from repro import obs

_C_INJECTED = obs.counter(
    "faults_injected_total",
    "faults injected by an active FaultPlan (label kind=)")


class TransientReadError(OSError):
    """Injected stand-in for a flaky-device read failure. An `OSError`
    subclass on purpose: the staging retry policy keys on OSError (what
    real mmap/file reads raise), never on injection-specific types."""


class FaultPlan:
    """Deterministic fault schedule, keyed by a seed.

    Probabilities are per decision point; a probability of 0 (the
    default for every kind) makes that kind decision-free. Decisions
    are pure functions of ``(seed, kind, key, ...)`` — no RNG state —
    except the per-key read *attempt* counter (so a retry of the same
    shard is a fresh decision) and the worker-death job sequence, both
    of which advance deterministically with the call sequence.

    ``read_error_max_per_key`` caps injected read errors per key: with
    ``p_read_error=1.0, read_error_max_per_key=1`` every shard fails
    exactly its first read and succeeds on retry — the deterministic
    way to assert "transient faults are retried away".
    """

    def __init__(self, seed: int = 0, *, p_read_error: float = 0.0,
                 read_error_max_per_key: Optional[int] = None,
                 p_latency: float = 0.0, latency_s: float = 0.002,
                 p_corrupt: float = 0.0, p_worker_death: float = 0.0,
                 p_conn_drop: float = 0.0, p_slow_write: float = 0.0,
                 slow_write_chunk: int = 64, slow_write_s: float = 0.001,
                 p_malformed: float = 0.0, p_client_vanish: float = 0.0):
        for name, p in (("p_read_error", p_read_error),
                        ("p_latency", p_latency), ("p_corrupt", p_corrupt),
                        ("p_worker_death", p_worker_death),
                        ("p_conn_drop", p_conn_drop),
                        ("p_slow_write", p_slow_write),
                        ("p_malformed", p_malformed),
                        ("p_client_vanish", p_client_vanish)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} outside [0, 1]")
        self.seed = int(seed)
        self.p_read_error = float(p_read_error)
        self.read_error_max_per_key = (None if read_error_max_per_key is None
                                       else int(read_error_max_per_key))
        self.p_latency = float(p_latency)
        self.latency_s = float(latency_s)
        self.p_corrupt = float(p_corrupt)
        self.p_worker_death = float(p_worker_death)
        self.p_conn_drop = float(p_conn_drop)
        self.p_slow_write = float(p_slow_write)
        self.slow_write_chunk = int(slow_write_chunk)
        self.slow_write_s = float(slow_write_s)
        self.p_malformed = float(p_malformed)
        self.p_client_vanish = float(p_client_vanish)
        self._lock = threading.Lock()
        self._attempts: Dict = {}
        self._read_faults: Dict = {}
        self._death_seq = 0
        self.injected: Dict[str, int] = {}       # kind -> count (tests)

    # -- the oracle ----------------------------------------------------------

    def _roll(self, *event) -> float:
        """Uniform [0, 1) hash of (seed, *event) — the only randomness."""
        h = hashlib.blake2b(repr((self.seed,) + event).encode(),
                            digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def _count(self, kind: str) -> None:
        with self._lock:
            self.injected[kind] = self.injected.get(kind, 0) + 1
        _C_INJECTED.labels(kind=kind).inc()

    # decision predicates, exposed so harnesses can pick seeds that
    # guarantee a scenario (e.g. "at least one corrupt shard") without
    # probabilistic flakiness
    def would_read_error(self, key, attempt: int) -> bool:
        return (self.p_read_error > 0
                and self._roll("read_error", key, attempt) < self.p_read_error)

    def corrupts(self, key) -> bool:
        """Persistent per-key corruption decision (attempt-independent:
        retries must NOT clear it — that is quarantine's job)."""
        return (self.p_corrupt > 0
                and self._roll("corrupt", key) < self.p_corrupt)

    # -- injection points ----------------------------------------------------

    def on_read(self, key) -> None:
        """One host-side shard read attempt: may sleep (latency spike)
        and/or raise `TransientReadError`. Called by the staging
        ``host_fn`` before touching the mmaps."""
        with self._lock:
            attempt = self._attempts.get(key, 0)
            self._attempts[key] = attempt + 1
            nfail = self._read_faults.get(key, 0)
        if (self.p_latency > 0
                and self._roll("latency", key, attempt) < self.p_latency):
            self._count("latency")
            time.sleep(self.latency_s)
        if self.would_read_error(key, attempt):
            if (self.read_error_max_per_key is None
                    or nfail < self.read_error_max_per_key):
                with self._lock:
                    self._read_faults[key] = nfail + 1
                self._count("read_error")
                raise TransientReadError(
                    f"injected transient read error on {key} "
                    f"(attempt {attempt})")

    def corrupt_arrays(self, key, arrays: dict) -> dict:
        """Flip one deterministic bit in one of the host arrays
        (copies; the originals — and the mmaps behind them — are never
        touched). Models silent media corruption surfacing through a
        read: the integrity check must catch it downstream."""
        names = sorted(arrays)
        name = names[int(self._roll("corrupt_field", key) * len(names))
                     % len(names)]
        a = np.array(arrays[name], copy=True)
        raw = a.reshape(-1).view(np.uint8)
        pos = int(self._roll("corrupt_byte", key) * raw.size) % raw.size
        raw[pos] ^= np.uint8(1 << (int(self._roll("corrupt_bit", key) * 8)
                                   % 8))
        self._count("corrupt")
        out = dict(arrays)
        out[name] = a
        return out

    # -- network kinds (client-side injection; see module docstring) ---------
    # Pure decision predicates + counting: the *mechanics* (partial
    # writes, socket closes) live in `repro.launch.search_client`, which
    # calls these per request attempt. Exposed as predicates for the same
    # reason as `would_read_error`: harnesses pick seeds that GUARANTEE a
    # scenario (">= 1 malformed frame") instead of hoping.

    def would_conn_drop(self, key, attempt: int) -> bool:
        return (self.p_conn_drop > 0
                and self._roll("conn_drop", key, attempt) < self.p_conn_drop)

    def conn_drop(self, key, attempt: int) -> bool:
        if self.would_conn_drop(key, attempt):
            self._count("conn_drop")
            return True
        return False

    def slow_write(self, key, attempt: int) -> bool:
        if (self.p_slow_write > 0
                and self._roll("slow_write", key, attempt)
                < self.p_slow_write):
            self._count("slow_write")
            return True
        return False

    def would_malform(self, key) -> bool:
        """Per key only (one garbage frame per request, not per retry —
        a malformed frame is not something a retry policy clears)."""
        return (self.p_malformed > 0
                and self._roll("malformed", key) < self.p_malformed)

    def malformed(self, key) -> bool:
        if self.would_malform(key):
            self._count("malformed")
            return True
        return False

    def would_client_vanish(self, key, attempt: int) -> bool:
        return (self.p_client_vanish > 0
                and self._roll("client_vanish", key, attempt)
                < self.p_client_vanish)

    def client_vanish(self, key, attempt: int) -> bool:
        if self.would_client_vanish(key, attempt):
            self._count("client_vanish")
            return True
        return False

    def worker_death(self) -> bool:
        """One prefetch-worker job pull: True = the worker thread should
        die now (per job-sequence decision)."""
        if self.p_worker_death <= 0:
            return False
        with self._lock:
            seq = self._death_seq
            self._death_seq += 1
        if self._roll("worker_death", seq) < self.p_worker_death:
            self._count("worker_death")
            return True
        return False


def parse_chaos(spec: str) -> FaultPlan:
    """Build a `FaultPlan` from a CLI spec like
    ``"p_read_error=0.2,p_corrupt=0.1,latency_s=0.005,seed=7"``.
    Keys are `FaultPlan` constructor arguments; `seed`,
    `read_error_max_per_key` parse as ints, the rest as floats."""
    kv = {}
    for part in spec.split(","):
        if not part.strip():
            continue
        k, _, v = part.partition("=")
        k = k.strip()
        if not v:
            raise ValueError(f"chaos spec entry {part!r} is not key=value")
        kv[k] = (int(v) if k in ("seed", "read_error_max_per_key",
                                 "slow_write_chunk")
                 else float(v))
    return FaultPlan(kv.pop("seed", 0), **kv)


def corrupt_file(path, *, seed: int = 0, flips: int = 1) -> None:
    """Flip ``flips`` deterministic bits of an on-disk file in place —
    the test/chaos-harness way to manufacture a genuinely corrupt shard
    (fsck / quarantine / resume-rewrite fixtures)."""
    with open(path, "r+b") as f:
        f.seek(0, 2)
        size = f.tell()
        if size == 0:
            raise ValueError(f"cannot corrupt empty file {path}")
        for i in range(flips):
            h = hashlib.blake2b(repr((seed, i, str(path))).encode(),
                                digest_size=8).digest()
            v = int.from_bytes(h, "big")
            pos, bit = (v >> 3) % size, v & 7
            f.seek(pos)
            b = f.read(1)[0]
            f.seek(pos)
            f.write(bytes([b ^ (1 << bit)]))
