"""Shared bounded device staging for out-of-core shard scans.

`StagingPool` is the latency-hiding heart of the out-of-core search
path: a device-side LRU of staged shards under ONE byte budget that
several `ShardedIndexView`s (multi-tenant serving) can share, plus

  - a **background prefetch worker**: `prefetch(key, ...)` assembles the
    host-side arrays and dispatches the (async) `jax.device_put` on a
    worker thread, so the mmap read + `np.concatenate`/`astype` + H2D
    copy of shard s+1 overlap the `ops.adc_topk` scan of shard s;
  - a **host cache of assembled arrays** (bounded separately from the
    device LRU): an evict -> re-stage cycle replays only the
    `device_put`, not a fresh concatenate+astype over the whole shard;
  - **evict-at-issue accounting**: room for a stage or prefetch is made
    (LRU eviction of unpinned entries) and its bytes reserved BEFORE the
    device buffers allocate, so `peak_resident_bytes <= budget_bytes`
    holds at allocation time — never `max_entries + 1` shards allocated,
    even with a prefetch in flight. A prefetch that cannot make room
    without evicting a pinned (in-use) entry is skipped, not forced: the
    pipeline degrades to the sequential stage-then-scan order instead of
    breaking the budget bound.

Lifetime rules (also in docs/INDEX_FORMAT.md):
  - An entry is *pinned* between `acquire` and `release`; pinned entries
    are never evicted. Each searching thread pins at most one shard at a
    time, so any budget >= one worst-case shard per concurrent searcher
    makes progress (a sync `acquire` that cannot make room waits for a
    `release`, it does not over-allocate).
  - Eviction drops the pool's reference only; arrays already handed out
    (or still feeding an in-flight async computation) stay alive through
    their own references — the budget bound is an *allocation*-time
    guarantee, matching the pre-pool LRU semantics.
  - The host cache stores the assembled arrays themselves (the `host_fn`
    contract is to return copies, never mmap views), so a cached shard
    never aliases the store directory: deleting or rewriting the store
    invalidates future `host_fn` calls only.

Thread safety: all pool state is guarded by one condition variable;
`acquire`/`release`/`prefetch` may be called from any number of threads
(concurrent queries over views sharing the pool are tested).
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro import obs

# Registry metrics, one labeled child per pool (`pool=<seq>`), declared
# once at import (docs/OBSERVABILITY.md naming scheme). `stats()` is the
# compatibility view over these — the pre-telemetry `_stats` dict keys —
# so `staging_staged_total{pool="2"}` on the scrape endpoint and
# `pool.stats()["staged"]` are the same number by construction.
_COUNTERS = {
    "staged": obs.counter(
        "staging_staged_total", "shards staged to device (sync or worker)"),
    "device_hits": obs.counter(
        "staging_device_hits_total", "acquires served from the device LRU"),
    "host_hits": obs.counter(
        "staging_host_hits_total",
        "stagings that replayed the host cache instead of reassembling"),
    "prefetch_issued": obs.counter(
        "staging_prefetch_issued_total", "background prefetches issued"),
    "prefetch_hits": obs.counter(
        "staging_prefetch_hits_total",
        "acquires that waited on an in-flight prefetch"),
    "prefetch_skipped": obs.counter(
        "staging_prefetch_skipped_total",
        "prefetches skipped (no room without evicting a pinned entry)"),
    "evictions": obs.counter(
        "staging_evictions_total", "LRU evictions of staged shards"),
    "stall_s": obs.counter(
        "staging_stall_seconds_total",
        "time acquire() spent blocked waiting for staging"),
    "retries": obs.counter(
        "staging_retries_total",
        "acquire() stagings retried after a transient (OSError) failure"),
    "worker_restarts": obs.counter(
        "staging_worker_restarts_total",
        "prefetch worker threads resurrected after dying"),
}
_G_RESIDENT_BYTES = obs.gauge(
    "staging_resident_bytes", "device bytes currently staged (incl. "
    "in-flight reservations)")
_G_RESIDENT_ENTRIES = obs.gauge(
    "staging_resident_entries", "staged + in-flight shard entries")
_POOL_SEQ = itertools.count(1)


class _Entry:
    __slots__ = ("device", "nbytes", "pins")

    def __init__(self, device, nbytes: int):
        self.device = device
        self.nbytes = nbytes
        self.pins = 0


class _Inflight:
    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int):
        self.nbytes = nbytes


class StagingPool:
    """Byte-budgeted device LRU + host cache + prefetch worker.

    Entries are keyed by ``(owner, shard_id)`` where ``owner`` comes from
    `register()` — several views share the pool without key collisions.
    The staging callback ``host_fn() -> dict[str, np.ndarray]`` does the
    expensive host assembly (mmap read, concatenate, astype) and MUST
    return fresh arrays (no mmap views); the pool device_puts the dict.

    ``budget_bytes`` bounds the device-staged bytes (reserved at stage /
    prefetch *issue* time). ``max_entries`` optionally also bounds the
    entry count — a per-view pool passes its ``max_resident_shards`` so
    the historical shard-count LRU semantics hold exactly.
    ``host_cache_bytes`` bounds the host-side cache of assembled arrays
    (``None`` defaults to ``2 * budget_bytes``; ``0`` disables).

    Fault tolerance: a sync `acquire` whose ``host_fn`` (or device_put)
    raises an `OSError` — a flaky read — retries up to ``retries`` times
    with capped deterministic exponential backoff (``retry_backoff_s *
    2**attempt``, capped at 0.25 s; no jitter, so failure schedules are
    reproducible). Non-OSError failures (notably the persistent
    `store.ShardIntegrityError`) propagate immediately. Every failure
    path — sync stage, prefetch issue, worker job — aborts its byte
    reservation, so the budget never shrinks permanently (regression
    tested). A prefetch worker that dies is resurrected on the next
    `prefetch` or on an `acquire` that finds itself waiting behind the
    dead worker's queue (``staging_worker_restarts_total``). ``faults``
    takes a `faults.FaultPlan` used ONLY for worker-death injection here
    (read-path injection lives in the view's ``host_fn``).
    """

    def __init__(self, budget_bytes: int, *, max_entries: Optional[int] = None,
                 host_cache_bytes: Optional[int] = None,
                 prefetch: bool = True, retries: int = 2,
                 retry_backoff_s: float = 0.02, faults=None):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be >= 1")
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.budget_bytes = int(budget_bytes)
        self.max_entries = max_entries
        self.host_cache_bytes = (2 * self.budget_bytes
                                 if host_cache_bytes is None
                                 else int(host_cache_bytes))
        self.prefetch_enabled = bool(prefetch)
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self._faults = faults

        self._cond = threading.Condition()
        self._lru: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._inflight: Dict[tuple, _Inflight] = {}
        self._host: "OrderedDict[tuple, tuple]" = OrderedDict()  # k->(tree,nb)
        self._host_bytes = 0
        self._resident_bytes = 0
        self.peak_resident_bytes = 0
        self.peak_resident_entries = 0
        self._owner_seq = 0
        self.pool_id = next(_POOL_SEQ)
        lbl = {"pool": str(self.pool_id)}
        self._m = {k: c.labels(**lbl) for k, c in _COUNTERS.items()}
        self._g_bytes = _G_RESIDENT_BYTES.labels(**lbl)
        self._g_entries = _G_RESIDENT_ENTRIES.labels(**lbl)
        self._q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None

    # -- registry ------------------------------------------------------------

    def register(self) -> int:
        """Claim an owner id for one view (key namespace inside the pool)."""
        with self._cond:
            self._owner_seq += 1
            return self._owner_seq

    # -- introspection -------------------------------------------------------

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    def resident_keys(self, owner: Optional[int] = None) -> list:
        """LRU-ordered staged keys; with ``owner``, that owner's shard ids."""
        with self._cond:
            if owner is None:
                return list(self._lru)
            return [sid for (o, sid) in self._lru if o == owner]

    def owner_pins(self, owner: int) -> int:
        """Outstanding acquire pins across one owner's entries. A retired
        view state is safe to `drop_owner` (and its on-disk generation
        safe to unlink) only once this reaches zero."""
        with self._cond:
            return sum(e.pins for (o, _), e in self._lru.items()
                       if o == owner)

    def stats(self) -> dict:
        """The legacy per-pool stats dict, now a compatibility view over
        this pool's registry series (`staging_*_total{pool=<id>}` on the
        scrape endpoint — same numbers by construction, tested). Counts
        freeze while the global registry is disabled (`obs.disable()`,
        the zero-overhead mode)."""
        return {k: (s.value if k == "stall_s" else int(s.value))
                for k, s in self._m.items()}

    # -- budget accounting (cond held) ---------------------------------------

    def _entries(self) -> int:
        return len(self._lru) + len(self._inflight)

    def _sync_gauges(self) -> None:
        self._g_bytes.set(self._resident_bytes)
        self._g_entries.set(self._entries())

    def _make_room(self, nbytes: int) -> bool:
        """Evict unpinned LRU entries until ``nbytes`` more fit the budget
        (bytes AND entry count). False if pinned/in-flight entries block."""
        if nbytes > self.budget_bytes:
            raise ValueError(f"one shard ({nbytes} B) exceeds the staging "
                             f"budget ({self.budget_bytes} B)")
        while (self._resident_bytes + nbytes > self.budget_bytes
               or (self.max_entries is not None
                   and self._entries() + 1 > self.max_entries)):
            victim = next((k for k, e in self._lru.items() if e.pins == 0),
                          None)
            if victim is None:
                return False
            self._resident_bytes -= self._lru.pop(victim).nbytes
            self._m["evictions"].inc()
            self._sync_gauges()
        return True

    def _begin(self, key, nbytes: int) -> _Inflight:
        """Reserve bytes + an entry slot (room already made)."""
        self._resident_bytes += nbytes
        inf = _Inflight(nbytes)
        self._inflight[key] = inf
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self._resident_bytes)
        self.peak_resident_entries = max(self.peak_resident_entries,
                                         self._entries())
        self._sync_gauges()
        return inf

    def _install(self, key, device, inf: _Inflight) -> _Entry:
        entry = _Entry(device, inf.nbytes)
        del self._inflight[key]
        self._lru[key] = entry                              # MRU
        self._cond.notify_all()
        return entry

    def _abort(self, key, inf: _Inflight) -> None:
        self._resident_bytes -= inf.nbytes
        self._inflight.pop(key, None)
        self._sync_gauges()
        self._cond.notify_all()

    # -- host assembly + device transfer (cond NOT held) ---------------------

    def _transfer(self, key, host_fn: Callable[[], dict]):
        host = None
        with self._cond:
            cached = self._host.get(key)
            if cached is not None:
                self._host.move_to_end(key)
                self._m["host_hits"].inc()
                host = cached[0]
        if host is None:
            host = host_fn()
            nb = sum(int(np.asarray(a).nbytes) for a in host.values())
            with self._cond:
                if 0 < nb <= self.host_cache_bytes \
                        and key not in self._host:
                    while (self._host
                           and self._host_bytes + nb > self.host_cache_bytes):
                        _, (_, old_nb) = self._host.popitem(last=False)
                        self._host_bytes -= old_nb
                    self._host[key] = (host, nb)
                    self._host_bytes += nb
        return jax.device_put(host)                         # async dispatch

    # -- the worker ----------------------------------------------------------

    def _ensure_worker(self) -> None:
        """Start the worker if absent — or resurrect it if it died (the
        queue, and any jobs still on it, survive the thread). cond held."""
        if self._worker is not None and not self._worker.is_alive():
            self._worker = None
            self._m["worker_restarts"].inc()
        if self._worker is None:
            if self._q is None:
                self._q = queue.Queue()
            self._worker = threading.Thread(target=self._worker_loop,
                                            daemon=True)
            self._worker.start()

    def _worker_loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            key, host_fn, inf = job
            if self._faults is not None and self._faults.worker_death():
                # simulated crash: abort THIS job's reservation (no leaked
                # bytes) and exit without draining the queue — jobs behind
                # it stay in flight until `_ensure_worker` resurrects a
                # worker over the same queue, or the waiting acquire is
                # notified by the abort and stages synchronously
                with self._cond:
                    self._abort(key, inf)
                return
            try:
                device = self._transfer(key, host_fn)
            except BaseException:
                with self._cond:
                    self._abort(key, inf)
                continue                    # acquire() will re-stage sync
            with self._cond:
                self._m["staged"].inc()
                self._install(key, device, inf)

    # -- public staging API --------------------------------------------------

    def prefetch(self, key, host_fn: Callable[[], dict],
                 nbytes: int) -> bool:
        """Stage ``key`` in the background. Eviction (of unpinned entries
        only) and byte reservation happen NOW, on the issuing thread, so
        the budget bound holds when the device buffers allocate. Returns
        False (and stages nothing) when the key is already resident or in
        flight, prefetch is disabled, or room cannot be made without
        touching a pinned entry."""
        if not self.prefetch_enabled:
            return False
        with self._cond:
            if key in self._lru or key in self._inflight:
                return False
            if not self._make_room(nbytes):
                self._m["prefetch_skipped"].inc()
                return False
            inf = self._begin(key, nbytes)
            try:
                self._ensure_worker()       # may spawn/resurrect a thread
                self._q.put((key, host_fn, inf))
            except BaseException:
                # thread spawn can fail under resource pressure: never
                # leak the reservation made two lines up
                self._abort(key, inf)
                raise
            self._m["prefetch_issued"].inc()
        return True

    def acquire(self, key, host_fn: Callable[[], dict], nbytes: int,
                timeout_s: float = 120.0):
        """Staged device arrays for ``key``, pinned until `release(key)`.

        Fast path: LRU hit (touch + pin). If a prefetch is in flight the
        call waits for it (the *stall* the prefetch pipeline is hiding —
        wait time lands in ``stats()['stall_s']``); otherwise it stages
        synchronously on the calling thread (full staging time is the
        stall). A call that cannot make room waits for another thread's
        `release` rather than over-allocating.

        A sync stage that fails with an `OSError` (transient read fault)
        aborts its reservation and retries up to ``self.retries`` times
        with capped deterministic backoff; any other failure (or retry
        exhaustion) propagates with the reservation aborted — failure
        never leaks budget bytes."""
        t0 = time.perf_counter()
        waited_inflight = False
        attempt = 0
        while True:
            with self._cond:
                while True:
                    entry = self._lru.get(key)
                    if entry is not None:
                        self._lru.move_to_end(key)
                        entry.pins += 1
                        self._m["device_hits"].inc()
                        if waited_inflight:
                            self._m["prefetch_hits"].inc()
                            self._m["stall_s"].inc(time.perf_counter() - t0)
                        return entry.device
                    if key in self._inflight:
                        waited_inflight = True
                        # the in-flight job may sit on the queue of a DEAD
                        # worker — resurrect it so this wait can end (an
                        # in-flight sync stage on another thread has no
                        # worker involvement: only revive, never spawn)
                        if self._worker is not None:
                            self._ensure_worker()
                        if not self._cond.wait(timeout=timeout_s):
                            raise TimeoutError(
                                f"staging of {key} did not complete within "
                                f"{timeout_s}s")
                        continue
                    if self._make_room(nbytes):
                        inf = self._begin(key, nbytes)
                        break
                    if not self._cond.wait(timeout=timeout_s):
                        raise TimeoutError(
                            f"no staging budget for {key} within {timeout_s}s "
                            f"(budget {self.budget_bytes} B all pinned — more "
                            f"concurrent searchers than budgeted shards?)")
            try:
                device = self._transfer(key, host_fn)
            except BaseException as e:
                with self._cond:
                    self._abort(key, inf)
                # OSError = transient device/read fault -> bounded retry.
                # ShardIntegrityError is deliberately NOT an OSError:
                # corrupt bytes don't get better on re-read.
                if isinstance(e, OSError) and attempt < self.retries:
                    attempt += 1
                    self._m["retries"].inc()
                    time.sleep(min(self.retry_backoff_s
                                   * (1 << (attempt - 1)), 0.25))
                    continue
                raise
            with self._cond:
                self._m["staged"].inc()
                entry = self._install(key, device, inf)
                entry.pins += 1
                self._m["stall_s"].inc(time.perf_counter() - t0)
                return entry.device

    def release(self, key) -> None:
        """Unpin one `acquire` of ``key`` (the entry stays LRU-resident)."""
        with self._cond:
            entry = self._lru.get(key)
            if entry is not None and entry.pins > 0:
                entry.pins -= 1
                self._cond.notify_all()

    def drop_owner(self, owner: int) -> None:
        """Forget one owner's device entries and host-cache lines (a view
        being closed). Pinned or in-flight entries are left to finish."""
        with self._cond:
            for k in [k for k, e in self._lru.items()
                      if k[0] == owner and e.pins == 0]:
                self._resident_bytes -= self._lru.pop(k).nbytes
                self._m["evictions"].inc()
            self._sync_gauges()
            for k in [k for k in self._host if k[0] == owner]:
                _, nb = self._host.pop(k)
                self._host_bytes -= nb
            self._cond.notify_all()
