"""Fault-tolerant checkpointing: atomic, keep-k, resumable, reshardable.

- save: each leaf written as .npy inside a temp dir, then atomic rename;
  a MANIFEST.json records the tree structure, shapes, dtypes, and step.
- restore: loads into *any* target sharding (jax.device_put against the new
  mesh) — this is the elastic-scaling path: a checkpoint written on a
  16x16 mesh restores onto 2x16x16 or a single host.
- preemption: `PreemptionGuard` installs SIGTERM/SIGINT handlers that flag
  a final checkpoint before exit.
- keep-k garbage collection + a `latest` pointer file.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        leaves, treedef = _flatten(tree)
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef),
                    "leaves": [], "extra": extra or {}}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            manifest["leaves"].append(
                {"dtype": str(arr.dtype), "shape": list(arr.shape)})
            # ml_dtypes (bf16 etc.) don't survive np.save: store a uint8
            # view and reconstruct from the manifest dtype on restore
            np.save(tmp / f"leaf_{i:05d}.npy",
                    np.ascontiguousarray(arr).view(np.uint8))
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        (self.dir / "latest").write_text(str(step))
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if (p / "MANIFEST.json").exists()]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Load leaves and place onto `shardings` (resharding as needed).

        target_tree provides the pytree structure (values ignored)."""
        loaded, extra = self.restore_flat(step)
        leaves, treedef = _flatten(target_tree)
        assert len(loaded) == len(leaves), (
            f"checkpoint has {len(loaded)} leaves, "
            f"target expects {len(leaves)} — structure mismatch")
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, extra

    def restore_flat(self, step: int):
        """Load a checkpoint's leaves as a flat list — no target tree.

        The single deserialization path (`restore` builds on it); also for
        consumers that persist their own structure description (e.g.
        `index/store.py` keeps a JSON treespec in the store manifest) and
        therefore can unflatten without a live template pytree.
        Returns (leaves in save order, extra dict)."""
        src = self.dir / f"step_{step:09d}"
        manifest = json.loads((src / "MANIFEST.json").read_text())
        import jax.numpy as jnp
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            raw = np.load(src / f"leaf_{i:05d}.npy")
            leaves.append(raw.view(jnp.dtype(meta["dtype"])).reshape(
                meta["shape"]))
        return leaves, manifest["extra"]

    def restore_latest(self, target_tree: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target_tree, shardings)
        return step, tree, extra


class PreemptionGuard:
    """SIGTERM/SIGINT -> request a final checkpoint at the next step edge."""

    def __init__(self):
        self.requested = threading.Event()
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass                                 # non-main thread

    def _handler(self, signum, frame):
        self.requested.set()

    def should_checkpoint(self) -> bool:
        return self.requested.is_set()

    def restore_handlers(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


class StragglerMonitor:
    """Tracks per-step wall times; flags steps beyond mean + k*std.

    On a real fleet each host reports its step time; a coordinator
    cross-checks and triggers hot-spare swaps for persistent outliers."""

    def __init__(self, window: int = 50, k: float = 3.0):
        self.times = []
        self.window = window
        self.k = k
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        hist = self.times[-self.window:]
        is_straggler = False
        if len(hist) >= 10:
            mu = float(np.mean(hist))
            sd = float(np.std(hist)) + 1e-9
            if dt > mu + self.k * sd:
                is_straggler = True
                self.flagged.append((step, dt, mu))
        self.times.append(dt)
        return is_straggler
