"""Mixture-of-experts layers (dbrx-132b, kimi-k2-1t-a32b).

Dispatch strategy (TPU/GSPMD-native, no ragged ops): token-choice top-k
gating followed by per-expert top-C token selection ("expert slots"), then
dense per-expert einsums with experts sharded over the `model` axis (EP)
and the capacity dim sharded over `data`. See DESIGN.md §6.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import dense
from repro.models.common import ParamSpec, ShardCtx, shard


def moe_param_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    m = arch.moe
    d = arch.d_model
    if arch.parallel.moe_2d:
        # 2D expert sharding (§Perf): experts over `model`, expert-FFN dim
        # over `data`. Every weight element lives on exactly one device, so
        # experts are never all-gathered and their grads reduce locally.
        gate_axes = ("experts", None, "moe_ffn")
        down_axes = ("experts", "moe_ffn", None)
    else:
        gate_axes = ("experts", "embed", None)
        down_axes = ("experts", None, "embed")
    p = {
        "router": ParamSpec((d, m.num_experts), ("embed", "experts"),
                            jnp.float32, "normal", 0.02),
        "w_gate": ParamSpec((m.num_experts, d, m.d_ff_expert), gate_axes,
                            dtype),
        "w_up": ParamSpec((m.num_experts, d, m.d_ff_expert), gate_axes,
                          dtype),
        "w_down": ParamSpec((m.num_experts, m.d_ff_expert, d), down_axes,
                            dtype),
    }
    if m.num_shared_experts:
        ff = m.d_ff_shared * m.num_shared_experts
        p["shared"] = dense.mlp_param_specs(arch, dtype, d_ff=ff)
    return p


def layer_param_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    d = arch.d_model
    return {
        "ln1": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "ln2": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "attn": dense.attn_param_specs(arch, dtype),
        "moe": moe_param_specs(arch, dtype),
    }


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(arch.parallel.param_dtype)
    n_moe = arch.n_layers - arch.moe_first_dense
    p = {"layers": dense._stack_specs(layer_param_specs(arch, dtype), n_moe)}
    if arch.moe_first_dense:
        p["dense_layers"] = dense._stack_specs(
            dense.layer_param_specs(arch, dtype), arch.moe_first_dense)
    return p


# ---------------------------------------------------------------------------
# MoE block
# ---------------------------------------------------------------------------


def _capacity(n_tokens: int, arch: ArchConfig) -> int:
    m = arch.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(m.top_k, min(n_tokens, c))


def moe_block(p, x, arch: ArchConfig, ctx: ShardCtx) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    m = arch.moe
    B, S, d = x.shape
    N = B * S
    C = _capacity(N, arch)
    xt = x.reshape(N, d)

    # --- token-choice gates -------------------------------------------------
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                      # (N, E)
    top_vals, _ = lax.top_k(gates, m.top_k)
    thresh = top_vals[:, -1:]
    gates = jnp.where(gates >= thresh, gates, 0.0)               # keep top-k
    gates = shard(gates, ctx, "batch", "model")

    # --- expert-choice capacity: each expert takes its top-C tokens ---------
    gv, token_idx = lax.top_k(gates.T, C)                        # (E, C)
    moe2d = arch.parallel.moe_2d
    # moe_2d: capacity replicated over data (expert-FFN dim carries `data`);
    # baseline: capacity sharded over data.
    cap_ax = None if moe2d else "batch"
    token_idx = shard(token_idx, ctx, "model", cap_ax)
    gv = shard(gv, ctx, "model", cap_ax)
    xe = jnp.take(xt, token_idx, axis=0)                         # (E, C, d)
    xe = shard(xe, ctx, "model", cap_ax, None)

    # --- per-expert gated MLP ------------------------------------------------
    cd = x.dtype
    h = jnp.einsum("ecd,edf->ecf", xe.astype(cd), p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", xe.astype(cd), p["w_up"].astype(cd))
    h = jax.nn.silu(h) * u
    h = shard(h, ctx, "model", cap_ax, "moe_ffn")
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))
    ye = ye * (gv[..., None] > 0.0) * gv[..., None].astype(cd)
    ye = shard(ye, ctx, "model", cap_ax, None)

    # --- combine back (scatter-add over token ids) --------------------------
    y = jnp.zeros((N, d), cd).at[token_idx.reshape(-1)].add(
        ye.reshape(-1, d))
    y = y.reshape(B, S, d)
    y = shard(y, ctx, "batch", "seq", None)

    if m.num_shared_experts:
        sp = p["shared"]
        y = y + cm.gated_mlp(x, sp["gate"], sp["up"], sp["down"], ctx)

    # Switch-style load-balance aux loss (from pre-mask gates)
    top1 = jnp.argmax(gates, -1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, m.num_experts,
                                          dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return y, aux


# ---------------------------------------------------------------------------
# Layer / forward / decode
# ---------------------------------------------------------------------------


def moe_layer(p, x, arch: ArchConfig, ctx: ShardCtx, *, positions,
              window, theta, collect_kv=False):
    if arch.parallel.parallel_block:
        # fused attn+MoE block: one LN, one residual sum, one TP AR (§Perf)
        h = cm.rms_norm(x, p["ln1"], arch.norm_eps)
        attn_out, k, v = dense.attn_block(p["attn"], h, arch, ctx,
                                          positions=positions, window=window,
                                          theta=theta)
        y, aux = moe_block(p["moe"], h, arch, ctx)
        x = x + attn_out + y
    else:
        h = cm.rms_norm(x, p["ln1"], arch.norm_eps)
        attn_out, k, v = dense.attn_block(p["attn"], h, arch, ctx,
                                          positions=positions, window=window,
                                          theta=theta)
        x = x + attn_out
        h = cm.rms_norm(x, p["ln2"], arch.norm_eps)
        y, aux = moe_block(p["moe"], h, arch, ctx)
        x = x + y
    if collect_kv:
        return x, ((k, v), aux)
    return x, (None, aux)


def forward(params, h, arch: ArchConfig, ctx: ShardCtx, *, positions=None,
            collect_kv: bool = False):
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    win, theta = dense.layer_windows(arch)
    kv_dense = None
    if arch.moe_first_dense:
        def dbody(x, xs):
            lp, w, th = xs
            return dense.dense_layer(lp, x, arch, ctx, positions=positions,
                                     window=w, theta=th,
                                     collect_kv=collect_kv)
        dbody = dense._remat(dbody, arch.parallel.remat_policy)
        nd = arch.moe_first_dense
        h, kv_dense = lax.scan(
            dbody, h, (params["dense_layers"], jnp.asarray(win[:nd]),
                       jnp.asarray(theta[:nd])))

    def body(x, xs):
        lp, w, th = xs
        return moe_layer(lp, x, arch, ctx, positions=positions, window=w,
                         theta=th, collect_kv=collect_kv)

    body = dense._remat(body, arch.parallel.remat_policy)
    nd = arch.moe_first_dense
    h, (kv, aux) = lax.scan(body, h, (params["layers"], jnp.asarray(win[nd:]),
                                      jnp.asarray(theta[nd:])))
    if collect_kv and kv_dense is not None:
        kv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                          kv_dense, kv)
    return h, {"kv": kv, "aux": jnp.sum(aux)}


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False) -> Dict[str, Any]:
    return dense.cache_specs(arch, batch, seq, kv_quant)


def decode_step(params, cache, h, pos, arch: ArchConfig, ctx: ShardCtx, *,
                kv_quant: bool = False):
    win, theta = dense.layer_windows(arch)
    nd = arch.moe_first_dense

    def split_cache(c, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], c)

    new_cache_parts = []
    if nd:
        def dbody(x, xs):
            lp, cs, w, th = xs
            return dense.decode_layer(lp, cs, x, pos, arch, ctx, window=w,
                                      theta=th, kv_quant=kv_quant)
        h, nc = lax.scan(dbody, h,
                         (params["dense_layers"], split_cache(cache, 0, nd),
                          jnp.asarray(win[:nd]), jnp.asarray(theta[:nd])))
        new_cache_parts.append(nc)

    def body(x, xs):
        lp, cs, w, th = xs
        # dense decode attention (skip_mlp), then the MoE MLP
        x2, nc = dense.decode_layer(lp, cs, x, pos, arch, ctx, window=w,
                                    theta=th, kv_quant=kv_quant,
                                    skip_mlp=True)
        h3 = cm.rms_norm(x2, lp["ln2"], arch.norm_eps)
        y, _aux = moe_block(lp["moe"], h3, arch, ctx)
        x3 = x2 + y
        return x3, nc

    h, nc = lax.scan(body, h,
                     (params["layers"], split_cache(cache, nd, arch.n_layers),
                      jnp.asarray(win[nd:]), jnp.asarray(theta[nd:])))
    new_cache_parts.append(nc)
    if len(new_cache_parts) == 1:
        return h, new_cache_parts[0]
    new_cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                             new_cache_parts[0], new_cache_parts[1])
    return h, new_cache
