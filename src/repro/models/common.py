"""Shared model machinery: ParamSpec trees, norms, RoPE, attention, losses.

Parameters are plain nested dicts. Every leaf is declared as a ``ParamSpec``
carrying logical axis names so the same tree yields (a) materialized arrays
for tests, (b) ShapeDtypeStructs for the AOT dry-run, and (c) NamedShardings
via ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# ParamSpec trees
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis names per dim
    dtype: Any = jnp.float32
    init: str = "fan_in"                # fan_in | zeros | ones | normal | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def abstract_params(tree):
    return spec_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def init_params(tree, key):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def _init(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "normal":
            return (s.scale * jax.random.normal(k, s.shape)).astype(s.dtype)
        if s.init == "embed":
            return (jax.random.normal(k, s.shape) * s.scale).astype(s.dtype)
        # fan_in (Kaiming-uniform flavour): fan = first input-like dim
        fan = s.shape[0] if len(s.shape) == 1 else int(
            math.prod(s.shape[:-1]) if s.init == "fan_in_all" else s.shape[-2]
            if len(s.shape) >= 2 else s.shape[0])
        if len(s.shape) >= 2:
            fan = int(math.prod(s.shape[:-1]))
        bound = s.scale / math.sqrt(max(fan, 1))
        return jax.random.uniform(
            k, s.shape, jnp.float32, -bound, bound).astype(s.dtype)

    return treedef.unflatten([_init(s, k) for s, k in zip(leaves, keys)])


def count_params(tree) -> int:
    return sum(int(math.prod(s.shape))
               for s in jax.tree.leaves(tree, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-axis policy used by forward passes via with_sharding_constraint."""
    data_axes: Tuple[str, ...] = ("data",)   # ('pod','data') on multi-pod DP
    model_axis: Optional[str] = "model"
    batch_sharded: bool = True               # False when global_batch < |data|
    cache_seq_sharded: bool = False          # True for long_500k SP decode
    active: bool = True                      # False: skip all constraints
    moe_ffn_axis: Optional[str] = None       # 'data' under the moe_2d policy
    # mesh axis sizes, used to drop non-divisible constraints (e.g. 56 heads
    # over a 16-way model axis would force involuntary resharding in GSPMD)
    axis_sizes: Any = None

    def batch_spec(self):
        return self.data_axes if self.batch_sharded else None

    def seq_spec(self):
        return self.data_axes if (not self.batch_sharded) else None

    def _size(self, spec) -> int:
        if spec is None or self.axis_sizes is None:
            return 1
        axes = (spec,) if isinstance(spec, str) else tuple(spec)
        n = 1
        for a in axes:
            n *= self.axis_sizes.get(a, 1)
        return n


def shard(x, ctx: ShardCtx, *axes):
    """Apply a sharding constraint with logical axes resolved against ctx.

    axes entries: 'batch', 'seq', 'model', 'cache_seq', None. Constraints on
    dims not divisible by the mesh-axis size are dropped.
    """
    if not ctx.active:
        return x
    from jax.sharding import PartitionSpec as P
    resolved = []
    for i, a in enumerate(axes):
        if a == "batch":
            r = ctx.batch_spec()
        elif a == "seq":
            r = ctx.seq_spec()
        elif a == "cache_seq":
            r = ctx.data_axes if ctx.cache_seq_sharded else None
        elif a == "model":
            r = ctx.model_axis
        elif a == "moe_ffn":
            r = ctx.moe_ffn_axis
        else:
            r = None
        if r is not None and x.shape[i] % ctx._size(r) != 0:
            r = None
        resolved.append(r)
    return lax.with_sharding_constraint(x, P(*resolved))


# ---------------------------------------------------------------------------
# Basic layers
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * lax.rsqrt(var + eps) * scale + bias
    return out.astype(dt)


def rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int, offset=0):
    pos = jnp.arange(seq)[:, None] + offset
    inv = 10_000 ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * inv
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# Attention (GQA, chunked-flash for train/prefill; flash-decoding for decode)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def attention(q, k, v, *, causal: bool, window: Optional[int],
              q_offset: int = 0, chunk: int = 512,
              softcap: Optional[float] = None):
    """Chunked attention. q: (B,S,KVH,G,D); k,v: (B,T,KVH,D).

    Scans over query chunks; each chunk attends to the full (masked) key
    range, so peak memory is O(chunk * T) per head instead of O(S * T).
    """
    B, S, KVH, G, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    nq = -(-S // chunk)
    pad = nq * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qb = q.reshape(B, nq, chunk, KVH, G, D)
    kpos = jnp.arange(T)

    def per_chunk(ci, qc):
        # qc: (B, chunk, KVH, G, D)
        qpos = ci * chunk + jnp.arange(chunk) + q_offset
        s = jnp.einsum("bqhgd,bthd->bhgqt", qc.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        s = _softcap(s, softcap)
        mask = jnp.ones((chunk, T), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqt,bthd->bqhgd", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    out = lax.map(lambda args: per_chunk(*args),
                  (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * chunk, KVH, G, D)
    return out[:, :S]


def decode_attention(q, k_chunks_fn, nchunks: int, chunk_len: int,
                     valid_len, *, window: Optional[int] = None):
    """Flash-decoding: one query step over a (possibly quantized/sharded)
    KV cache exposed as a chunk generator.

    q: (B, KVH, G, D). k_chunks_fn(i) -> (k, v) each (B, chunk_len, KVH, D).
    valid_len: scalar count of valid cache positions. Returns (B, KVH, G, D).
    """
    B, KVH, G, D = q.shape
    scale = D ** -0.5
    qf = q.astype(jnp.float32) * scale

    def step(carry, i):
        m, denom, acc = carry
        k, v = k_chunks_fn(i)
        pos = i * chunk_len + jnp.arange(chunk_len)
        s = jnp.einsum("bhgd,bthd->bhgt", qf, k.astype(jnp.float32))
        valid = pos[None] < valid_len
        if window is not None:
            valid &= pos[None] > valid_len - 1 - window
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgt,bthd->bhgd", p, v.astype(jnp.float32))
        return (m_new, denom, acc), None

    init = (jnp.full((B, KVH, G), NEG_INF, jnp.float32),
            jnp.zeros((B, KVH, G), jnp.float32),
            jnp.zeros((B, KVH, G, D), jnp.float32))
    (m, denom, acc), _ = lax.scan(step, init, jnp.arange(nchunks))
    return (acc / jnp.maximum(denom, 1e-30)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Vocab-sharded chunked cross-entropy
# ---------------------------------------------------------------------------


def chunked_softmax_xent(x, w_out, labels, ctx: ShardCtx, chunk: int = 512):
    """mean CE without materializing (B, S, V) logits.

    x: (B,S,d) activations, w_out: (d,V) vocab-sharded, labels: (B,S) int32.
    Scans over seq chunks; within a chunk the logits are (B,chunk,V) and the
    vocab dim stays sharded ('model'); logsumexp/one-hot reductions over the
    sharded vocab dim become psums under GSPMD.
    """
    B, S, d = x.shape
    V = w_out.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xb = jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0)
    lb = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def step(carry, xs):
        xc, lc = xs
        logits = jnp.einsum("bqd,dv->bqv", xc.astype(jnp.float32),
                            w_out.astype(jnp.float32))
        logits = shard(logits, ctx, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, V, dtype=jnp.float32)
        gold = jnp.sum(logits * onehot, axis=-1)
        validm = (lc >= 0).astype(jnp.float32)
        loss_sum, tok = carry
        return (loss_sum + jnp.sum((lse - gold) * validm),
                tok + jnp.sum(validm)), None

    (loss_sum, tok), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                  (xb, lb))
    return loss_sum / jnp.maximum(tok, 1.0)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def gated_mlp(x, wi_gate, wi_up, wo, ctx: ShardCtx):
    h = jnp.einsum("bsd,df->bsf", x, wi_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, wi_up.astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = shard(h, ctx, "batch", "seq", "model")
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))


def gelu_mlp(x, wi, bi, wo, bo):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
                    + bi.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype)) + bo.astype(x.dtype)
