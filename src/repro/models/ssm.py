"""Mamba2 / SSD (state-space duality) blocks  [arXiv:2405.21060].

Training/prefill uses the chunked dual form: quadratic attention-like
computation within chunks of size Q plus a linear inter-chunk state
recurrence (lax.scan). Decode is the single-token recurrence with a
fixed-size (B, H, P, N) state — constant memory, the reason `long_500k`
runs for SSM archs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.common import ParamSpec, ShardCtx, shard


def dims(arch: ArchConfig):
    s = arch.ssm
    d_in = s.expand * arch.d_model
    H = d_in // s.head_dim          # SSD heads
    conv_ch = d_in + 2 * s.ngroups * s.state_dim
    return d_in, H, conv_ch


def layer_param_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    s = arch.ssm
    d = arch.d_model
    d_in, H, conv_ch = dims(arch)
    in_dim = 2 * d_in + 2 * s.ngroups * s.state_dim + H   # z, x, B, C, dt
    return {
        "ln": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "in_proj": ParamSpec((d, in_dim), ("embed", "ssm_inner"), dtype),
        "conv_w": ParamSpec((s.conv_width, conv_ch), (None, "ssm_inner"),
                            dtype, "fan_in", 1.0),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), dtype, "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), jnp.float32, "normal", 0.5),
        "D": ParamSpec((H,), ("ssm_heads",), jnp.float32, "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), jnp.float32, "zeros"),
        "ln_gate": ParamSpec((d_in,), ("ssm_inner",), dtype, "zeros"),
        "out_proj": ParamSpec((d_in, d), ("ssm_inner", "embed"), dtype),
    }


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    from repro.models.dense import _stack_specs
    dtype = jnp.dtype(arch.parallel.param_dtype)
    return {"layers": _stack_specs(layer_param_specs(arch, dtype),
                                   arch.n_layers)}


def _split_proj(arch: ArchConfig, zxbcdt):
    s = arch.ssm
    d_in, H, _ = dims(arch)
    gn = s.ngroups * s.state_dim
    z, x, B_, C_, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, B_, C_, dt


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (W, C). Depthwise causal conv via shifted adds."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out + b


def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums.

    segsum[i, j] = sum_{k=j+1..i} dA_k  for i >= j, -inf otherwise.
    """
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, arch: ArchConfig, ctx: ShardCtx,
                init_state=None):
    """SSD dual form.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    B_, C_: (B, S, G, N). Returns (y: (B, S, H, P), final_state (B,H,P,N)).
    """
    s = arch.ssm
    Bsz, S, H, P = x.shape
    G = B_.shape[2]
    Q = min(s.chunk_size, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    hpg = H // G

    # chunked views
    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H)
    Br = B_.reshape(Bsz, nc, Q, G, s.state_dim)
    Cr = C_.reshape(Bsz, nc, Q, G, s.state_dim)
    dA = dtr * A  # (B, nc, Q, H)

    # ---- intra-chunk (quadratic) term --------------------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cr, Br)   # (B,nc,G,Q,Q)
    scores = jnp.repeat(scores, hpg, axis=2)            # (B,nc,H,Q,Q)
    M = scores * L * jnp.moveaxis(dtr, -1, -2)[..., None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M, xr)

    # ---- chunk states -------------------------------------------------------
    cums = jnp.cumsum(dA, axis=2)                       # (B,nc,Q,H)
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)   # (B,nc,Q,H)
    Brh = jnp.repeat(Br, hpg, axis=3)                   # (B,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Brh, dtr * decay_to_end, xr)    # (B,nc,H,P,N)

    # ---- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))          # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, s.state_dim), jnp.float32)

    def step(carry, xs):
        st, dc = xs                                      # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dc[..., None, None] + st
        return new, prev

    final_state, prev_states = lax.scan(
        step, init_state.astype(jnp.float32),
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # ---- inter-chunk (off-diagonal) output ----------------------------------
    decay_from_start = jnp.exp(cums)                    # (B,nc,Q,H)
    Crh = jnp.repeat(Cr, hpg, axis=3)                   # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                       Crh, decay_from_start, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def mamba_block(p, x, arch: ArchConfig, ctx: ShardCtx, init_state=None,
                return_state: bool = False):
    """Full Mamba2 block. x: (B, S, d) -> (B, S, d)."""
    s = arch.ssm
    d_in, H, conv_ch = dims(arch)
    cd = x.dtype
    h = cm.rms_norm(x, p["ln"], arch.norm_eps)
    zxbcdt = jnp.einsum("bsd,di->bsi", h, p["in_proj"].astype(cd))
    zxbcdt = shard(zxbcdt, ctx, "batch", "seq", "model")
    z, xs_, B_, C_, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xs_, B_, C_], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(cd),
                                   p["conv_b"].astype(cd)))
    xs_, B_, C_ = jnp.split(xbc, [d_in, d_in + s.ngroups * s.state_dim], -1)
    Bsz, S, _ = x.shape
    xh = xs_.reshape(Bsz, S, H, s.head_dim)
    xh = shard(xh, ctx, "batch", "seq", "model", None)
    Bm = B_.reshape(Bsz, S, s.ngroups, s.state_dim).astype(jnp.float32)
    Cm = C_.reshape(Bsz, S, s.ngroups, s.state_dim).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, final_state = ssd_chunked(xh.astype(jnp.float32), dtv, A, Bm, Cm,
                                 arch, ctx, init_state)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(cd)
    # gated RMSNorm then out-projection
    y = cm.rms_norm(y * jax.nn.silu(z), p["ln_gate"], arch.norm_eps)
    out = x + jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    if return_state:
        return out, final_state
    return out


def forward(params, h, arch: ArchConfig, ctx: ShardCtx, *, positions=None,
            collect_kv: bool = False):
    from repro.models.dense import _remat

    def body(x, lp):
        return mamba_block(lp, x, arch, ctx), None

    body = _remat(body, arch.parallel.remat_policy)
    h, _ = lax.scan(body, h, params["layers"])
    return h, {}


# ---------------------------------------------------------------------------
# Decode: constant-memory recurrence
# ---------------------------------------------------------------------------


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False) -> Dict[str, Any]:
    s = arch.ssm
    d_in, H, conv_ch = dims(arch)
    return {
        "ssm_state": ParamSpec((arch.n_layers, batch, H, s.head_dim,
                                s.state_dim),
                               ("layers", "batch", "ssm_heads", None, None),
                               jnp.float32, "zeros"),
        "conv_state": ParamSpec((arch.n_layers, batch, s.conv_width - 1,
                                 conv_ch),
                                ("layers", "batch", None, "ssm_inner"),
                                jnp.float32, "zeros"),
    }


def decode_block(p, cache_slice, x, arch: ArchConfig, ctx: ShardCtx):
    """Single-token Mamba2 step. x: (B, 1, d)."""
    s = arch.ssm
    d_in, H, conv_ch = dims(arch)
    cd = x.dtype
    Bsz = x.shape[0]
    h = cm.rms_norm(x, p["ln"], arch.norm_eps)
    zxbcdt = jnp.einsum("bsd,di->bsi", h, p["in_proj"].astype(cd))[:, 0]
    z, xs_, B_, C_, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xs_, B_, C_], axis=-1)          # (B, conv_ch)

    conv_state = cache_slice["conv_state"]                 # (B, W-1, conv_ch)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(cd))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(cd))
    new_conv_state = window[:, 1:]

    xs_, B_, C_ = jnp.split(xbc, [d_in, d_in + s.ngroups * s.state_dim], -1)
    xh = xs_.reshape(Bsz, H, s.head_dim).astype(jnp.float32)
    Bm = B_.reshape(Bsz, s.ngroups, s.state_dim).astype(jnp.float32)
    Cm = C_.reshape(Bsz, s.ngroups, s.state_dim).astype(jnp.float32)
    hpg = H // s.ngroups
    Bh = jnp.repeat(Bm, hpg, axis=1)                       # (B, H, N)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    A = -jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)

    state = cache_slice["ssm_state"]                       # (B, H, P, N)
    decay = jnp.exp(dtv * A)[..., None, None]
    state = state * decay + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtv, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + p["D"][:, None] * xh
    y = y.reshape(Bsz, d_in).astype(cd)
    y = cm.rms_norm(y * jax.nn.silu(z), p["ln_gate"], arch.norm_eps)
    out = x + jnp.einsum("bi,id->bd", y, p["out_proj"].astype(cd))[:, None]
    return out, {"ssm_state": state, "conv_state": new_conv_state}


def decode_step(params, cache, h, pos, arch: ArchConfig, ctx: ShardCtx, *,
                kv_quant: bool = False):
    def body(x, xs):
        lp, cs = xs
        return decode_block(lp, cs, x, arch, ctx)

    h, new_cache = lax.scan(body, h, (params["layers"], cache))
    return h, new_cache
