"""Zamba2-style hybrid: Mamba2 backbone + a *shared-weight* attention block
applied every `shared_attn_every` backbone layers  [arXiv:2411.15242].

The backbone is scanned in groups: each group = one shared-attn invocation
(same params every time — the Zamba signature) followed by
`shared_attn_every` mamba layers; trailing mamba layers are scanned after.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import dense, ssm
from repro.models.common import ParamSpec, ShardCtx


def _group_layout(arch: ArchConfig):
    per = arch.shared_attn_every
    n_groups = arch.n_layers // per
    trailing = arch.n_layers - n_groups * per
    return per, n_groups, trailing


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(arch.parallel.param_dtype)
    per, n_groups, trailing = _group_layout(arch)
    mamba_layer = ssm.layer_param_specs(arch, dtype)
    p = {
        "shared_attn": dense.layer_param_specs(arch, dtype),  # ONE copy
        "groups": dense._stack_specs(
            dense._stack_specs(mamba_layer, per), n_groups),
    }
    if trailing:
        p["trailing"] = dense._stack_specs(mamba_layer, trailing)
    return p


def _shared_attn(params, x, arch: ArchConfig, ctx: ShardCtx, positions):
    big = jnp.int32(1 << 30)
    theta = jnp.float32(arch.attn.rope_theta)
    x, _ = dense.dense_layer(params["shared_attn"], x, arch, ctx,
                             positions=positions, window=big, theta=theta)
    return x


def forward(params, h, arch: ArchConfig, ctx: ShardCtx, *, positions=None,
            collect_kv: bool = False):
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    per, n_groups, trailing = _group_layout(arch)

    def mamba_body(x, lp):
        return ssm.mamba_block(lp, x, arch, ctx), None

    mamba_body = dense._remat(mamba_body, arch.parallel.remat_policy)

    def group_body(x, glp):
        x = _shared_attn(params, x, arch, ctx, positions)
        x, _ = lax.scan(mamba_body, x, glp)
        return x, None

    group_body = dense._remat(group_body, arch.parallel.remat_policy)
    h, _ = lax.scan(group_body, h, params["groups"])
    if trailing:
        h, _ = lax.scan(mamba_body, h, params["trailing"])
    return h, {}


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False) -> Dict[str, Any]:
    per, n_groups, trailing = _group_layout(arch)
    a = arch.attn
    mamba = ssm.cache_specs(arch, batch, seq)
    # per-layer mamba cache -> (n_groups, per, ...) stacked
    specs = {"groups_ssm": {
        k: ParamSpec((n_groups, per) + v.shape[1:],
                     ("groups", "layers") + v.axes[1:], v.dtype, v.init)
        for k, v in mamba.items()}}
    if trailing:
        specs["trailing_ssm"] = {
            k: ParamSpec((trailing,) + v.shape[1:], v.axes, v.dtype, v.init)
            for k, v in mamba.items()}
    # one KV cache per shared-attn invocation
    if not kv_quant:
        kv = ParamSpec((n_groups, batch, seq, a.num_kv_heads, a.head_dim),
                       ("layers", "batch", "cache_seq", "kv_heads", None),
                       jnp.bfloat16, "zeros")
        specs["attn"] = {"k": kv, "v": kv}
    else:
        mq, kq = arch.kv_quant.m_bytes, arch.kv_quant.codebook_size
        codes = ParamSpec((n_groups, batch, seq, a.num_kv_heads, mq),
                          ("layers", "batch", "cache_seq", "kv_heads", None),
                          jnp.uint8, "zeros")
        cb = ParamSpec((n_groups, a.num_kv_heads, mq, kq, a.head_dim),
                       ("layers", "kv_heads", None, None, None),
                       jnp.bfloat16, "normal")
        specs["attn"] = {"k_codes": codes, "v_codes": codes,
                         "k_cb": cb, "v_cb": cb}
    return specs


def decode_step(params, cache, h, pos, arch: ArchConfig, ctx: ShardCtx, *,
                kv_quant: bool = False):
    per, n_groups, trailing = _group_layout(arch)
    big = jnp.int32(1 << 30)
    theta = jnp.float32(arch.attn.rope_theta)

    def mamba_body(x, xs):
        lp, cs = xs
        return ssm.decode_block(lp, cs, x, arch, ctx)

    def group_body(x, xs):
        glp, attn_cache, ssm_cache = xs
        x, new_attn = dense.decode_layer(
            params["shared_attn"], attn_cache, x, pos, arch, ctx,
            window=big, theta=theta, kv_quant=kv_quant)
        x, new_ssm = lax.scan(mamba_body, x, (glp, ssm_cache))
        return x, (new_attn, new_ssm)

    h, (new_attn, new_gssm) = lax.scan(
        group_body, h, (params["groups"], cache["attn"], cache["groups_ssm"]))
    new_cache = {"attn": new_attn, "groups_ssm": new_gssm}
    if trailing:
        h, new_tr = lax.scan(mamba_body, h,
                             (params["trailing"], cache["trailing_ssm"]))
        new_cache["trailing_ssm"] = new_tr
    return h, new_cache
