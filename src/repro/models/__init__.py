from repro.models.common import (
    ParamSpec, ShardCtx, abstract_params, init_params, shard, spec_map,
)
