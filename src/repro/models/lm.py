"""Unified model API across the five families.

    specs  = param_specs(arch)                  # ParamSpec tree
    params = cm.init_params(specs, key)         # or abstract_params for AOT
    loss   = loss_fn(params, batch, arch, ctx)
    logits, cache = prefill(...) / decode_step(...)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import dense, encdec, hybrid, moe, ssm
from repro.models.common import ParamSpec, ShardCtx, shard

FAMILIES = {"dense": dense, "moe": moe, "ssm": ssm, "hybrid": hybrid,
            "encdec": encdec}


def family(arch: ArchConfig):
    return FAMILIES[arch.family]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(arch.parallel.param_dtype)
    d, V = arch.d_model, arch.vocab_size
    p = {
        "embedding": ParamSpec((V, d), ("vocab", "embed"), dtype, "normal",
                               0.02),
        "final_norm": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "backbone": family(arch).param_specs(arch),
    }
    if not arch.tie_embeddings:
        p["lm_head"] = ParamSpec((d, V), ("embed", "vocab"), dtype, "normal",
                                 0.02)
    return p


def count_params(arch: ArchConfig) -> int:
    return cm.count_params(param_specs(arch))


def active_params(arch: ArchConfig) -> int:
    """Activated parameters per token (MoE: top-k of the experts)."""
    total = count_params(arch)
    if arch.moe is None:
        return total
    m = arch.moe
    expert_p = 3 * arch.d_model * m.d_ff_expert
    n_moe = arch.n_layers - arch.moe_first_dense
    return total - n_moe * (m.num_experts - m.top_k) * expert_p


def _head_matrix(params, arch: ArchConfig):
    if arch.tie_embeddings:
        return params["embedding"].T
    return params["lm_head"]


def _embed(params, tokens, arch: ArchConfig, ctx: ShardCtx):
    h = jnp.take(params["embedding"], tokens, axis=0)
    h = h.astype(jnp.dtype(arch.parallel.compute_dtype))
    if arch.tie_embeddings:
        h = h * math.sqrt(arch.d_model)
    return shard(h, ctx, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------


def _backbone_forward(params, h, batch, arch: ArchConfig, ctx: ShardCtx,
                      collect_kv=False):
    fam = family(arch)
    if arch.family == "encdec":
        return fam.forward(params["backbone"], h, arch, ctx,
                           encoder_frames=batch["frames"],
                           collect_kv=collect_kv)
    return fam.forward(params["backbone"], h, arch, ctx,
                       collect_kv=collect_kv)


def loss_fn(params, batch, arch: ArchConfig, ctx: ShardCtx) -> jnp.ndarray:
    """Mean next-token CE (+ MoE aux). batch: tokens, labels[, frames]."""
    h = _embed(params, batch["tokens"], arch, ctx)
    h, extras = _backbone_forward(params, h, batch, arch, ctx)
    h = cm.rms_norm(h, params["final_norm"], arch.norm_eps)
    w_out = _head_matrix(params, arch)
    loss = cm.chunked_softmax_xent(h, w_out, batch["labels"], ctx)
    if "aux" in extras:
        loss = loss + 0.01 * extras["aux"] / max(arch.n_layers, 1)
    return loss


def prefill(params, batch, arch: ArchConfig, ctx: ShardCtx):
    """Forward over the prompt; returns (last-position logits, extras).

    extras contains per-layer kv for cache construction where the family
    supports it (dense/moe/encdec); ssm/hybrid prefill returns states via
    their own forward (constant-size, recomputed by decode path in serve).
    """
    h = _embed(params, batch["tokens"], arch, ctx)
    h, extras = _backbone_forward(params, h, batch, arch, ctx,
                                  collect_kv=True)
    h = cm.rms_norm(h, params["final_norm"], arch.norm_eps)
    last = h[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", last.astype(jnp.float32),
                        _head_matrix(params, arch).astype(jnp.float32))
    logits = shard(logits, ctx, "batch", None, "model")
    return logits, extras


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False):
    return family(arch).cache_specs(arch, batch, seq, kv_quant)


def decode_step(params, cache, tokens, pos, arch: ArchConfig, ctx: ShardCtx,
                *, kv_quant: bool = False):
    """tokens: (B, 1) -> (logits (B, 1, V), new_cache)."""
    h = _embed(params, tokens, arch, ctx)
    h, new_cache = family(arch).decode_step(params["backbone"], cache, h,
                                            pos, arch, ctx,
                                            kv_quant=kv_quant)
    h = cm.rms_norm(h, params["final_norm"], arch.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        _head_matrix(params, arch).astype(jnp.float32))
    logits = shard(logits, ctx, "batch", None, "model")
    return logits, new_cache


# ---------------------------------------------------------------------------
# Batch specs (inputs for each shape kind)
# ---------------------------------------------------------------------------


def batch_specs(arch: ArchConfig, seq_len: int, global_batch: int,
                kind: str, kv_quant: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins + logical axes for every model input."""
    B, S = global_batch, seq_len
    tok_axes = ("batch", "seq")
    out: Dict[str, Any] = {}
    if kind == "train":
        out["tokens"] = ParamSpec((B, S), tok_axes, jnp.int32, "zeros")
        out["labels"] = ParamSpec((B, S), tok_axes, jnp.int32, "zeros")
        if arch.family == "encdec":
            out["frames"] = ParamSpec(
                (B, arch.encoder_context, arch.d_model),
                ("batch", None, None), jnp.bfloat16, "normal")
    elif kind == "prefill":
        out["tokens"] = ParamSpec((B, S), tok_axes, jnp.int32, "zeros")
        if arch.family == "encdec":
            out["frames"] = ParamSpec(
                (B, arch.encoder_context, arch.d_model),
                ("batch", None, None), jnp.bfloat16, "normal")
    elif kind == "decode":
        out["tokens"] = ParamSpec((B, 1), ("batch", None), jnp.int32, "zeros")
        out["cache"] = cache_specs(arch, B, S, kv_quant)
    else:
        raise ValueError(kind)
    return out
