"""Dense decoder-only transformer (deepseek / mistral / qwen / chameleon /
gemma3). Layers are scanned (stacked params) so the HLO contains one layer
body regardless of depth; gemma3's 5:1 local:global pattern is expressed by
per-layer traced (window, rope_theta) scalars.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.common import ParamSpec, ShardCtx, shard


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def attn_param_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    a = arch.attn
    d = arch.d_model
    p = {
        "wq": ParamSpec((d, a.num_heads, a.head_dim), ("embed", "heads", None), dtype),
        "wk": ParamSpec((d, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", None), dtype),
        "wv": ParamSpec((d, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", None), dtype),
        "wo": ParamSpec((a.num_heads, a.head_dim, d), ("heads", None, "embed"), dtype),
    }
    if a.qkv_bias:
        p["bq"] = ParamSpec((a.num_heads, a.head_dim), ("heads", None), dtype, "zeros")
        p["bk"] = ParamSpec((a.num_kv_heads, a.head_dim), ("kv_heads", None), dtype, "zeros")
        p["bv"] = ParamSpec((a.num_kv_heads, a.head_dim), ("kv_heads", None), dtype, "zeros")
    return p


def mlp_param_specs(arch: ArchConfig, dtype, d_ff=None) -> Dict[str, Any]:
    d, ff = arch.d_model, d_ff or arch.d_ff
    return {
        "gate": ParamSpec((d, ff), ("embed", "mlp"), dtype),
        "up": ParamSpec((d, ff), ("embed", "mlp"), dtype),
        "down": ParamSpec((ff, d), ("mlp", "embed"), dtype),
    }


def layer_param_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    d = arch.d_model
    return {
        "ln1": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "ln2": ParamSpec((d,), ("embed",), dtype, "zeros"),
        "attn": attn_param_specs(arch, dtype),
        "mlp": mlp_param_specs(arch, dtype),
    }


def _stack_specs(tree, n: int):
    return cm.spec_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                            s.init, s.scale), tree)


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(arch.parallel.param_dtype)
    return {"layers": _stack_specs(layer_param_specs(arch, dtype),
                                   arch.n_layers)}


# per-layer attention pattern (gemma3 local:global)
def layer_windows(arch: ArchConfig) -> Tuple[np.ndarray, np.ndarray]:
    a = arch.attn
    n = arch.n_layers
    big = np.int32(1 << 30)        # "no window"
    if a.window is None or a.global_every <= 1:
        win = np.full((n,), big if a.window is None else a.window, np.int32)
        theta = np.full((n,), a.rope_theta, np.float32)
        return win, theta
    is_global = (np.arange(n) % a.global_every) == (a.global_every - 1)
    win = np.where(is_global, big, np.int32(a.window)).astype(np.int32)
    theta = np.where(is_global, np.float32(a.rope_theta),
                     np.float32(10_000.0)).astype(np.float32)
    return win, theta


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _project_qkv(p, x, arch: ArchConfig, positions, theta):
    a = arch.attn
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = cm.rope(q, positions, theta)
    k = cm.rope(k, positions, theta)
    return q, k, v


def attn_block(p, x, arch: ArchConfig, ctx: ShardCtx, *, positions,
               window, theta):
    """Full self-attention over x (train/prefill). Returns (out, k, v)."""
    a = arch.attn
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, arch, positions, theta)
    q = shard(q, ctx, "batch", "seq", "model", None)
    k = shard(k, ctx, "batch", "seq", "model", None)
    G = a.num_heads // a.num_kv_heads
    qg = q.reshape(B, S, a.num_kv_heads, G, a.head_dim)
    win = window  # traced int32; 1<<30 means "none"
    out = _attention_dyn_window(qg, k, v, win, arch, ctx)
    out = out.reshape(B, S, a.num_heads, a.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, k, v


def _attention_dyn_window(qg, k, v, window, arch: ArchConfig, ctx: ShardCtx):
    """Chunked causal attention with a *traced* window size."""
    B, S, KVH, G, D = qg.shape
    T = k.shape[1]
    chunk = min(arch.parallel.attn_chunk, S)
    scale = D ** -0.5
    nq = -(-S // chunk)
    pad = nq * chunk - S
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qb = jnp.moveaxis(qg.reshape(B, nq, chunk, KVH, G, D), 1, 0)
    kpos = jnp.arange(T)
    sc = arch.attn.logit_softcap

    def per_chunk(ci, qc):
        qpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bthd->bhgqt",
                       (qc * scale).astype(jnp.float32),
                       k.astype(jnp.float32))
        s = cm._softcap(s, sc)
        mask = kpos[None, :] <= qpos[:, None]
        mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, cm.NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqt,bthd->bqhgd", pr,
                          v.astype(jnp.float32)).astype(qg.dtype)

    out = lax.map(lambda xs: per_chunk(*xs), (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * chunk, KVH, G, D)
    return out[:, :S]


def dense_layer(p, x, arch: ArchConfig, ctx: ShardCtx, *, positions,
                window, theta, collect_kv: bool = False):
    if arch.parallel.parallel_block:
        # PaLM/GPT-J fused block: attn and MLP read ONE LayerNorm and their
        # partial sums share a single TP all-reduce (§Perf: halves the
        # per-layer TP collective volume).
        h = cm.rms_norm(x, p["ln1"], arch.norm_eps)
        attn_out, k, v = attn_block(p["attn"], h, arch, ctx,
                                    positions=positions, window=window,
                                    theta=theta)
        mlp_out = cm.gated_mlp(h, p["mlp"]["gate"], p["mlp"]["up"],
                               p["mlp"]["down"], ctx)
        x = x + attn_out + mlp_out
    else:
        h = cm.rms_norm(x, p["ln1"], arch.norm_eps)
        attn_out, k, v = attn_block(p["attn"], h, arch, ctx,
                                    positions=positions, window=window,
                                    theta=theta)
        x = x + attn_out
        h = cm.rms_norm(x, p["ln2"], arch.norm_eps)
        x = x + cm.gated_mlp(h, p["mlp"]["gate"], p["mlp"]["up"],
                             p["mlp"]["down"], ctx)
    x = shard(x, ctx, "batch", "seq", None)
    if collect_kv:
        return x, (k, v)
    return x, None


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "nothing":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def forward(params, h, arch: ArchConfig, ctx: ShardCtx, *,
            positions=None, collect_kv: bool = False):
    """h: (B, S, d) embedded inputs -> (h_out, kv or None)."""
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    win, theta = layer_windows(arch)

    def body(x, xs):
        lp, w, th = xs
        return dense_layer(lp, x, arch, ctx, positions=positions,
                           window=w, theta=th, collect_kv=collect_kv)

    body = _remat(body, arch.parallel.remat_policy)
    h, kv = lax.scan(body, h, (params["layers"], jnp.asarray(win),
                               jnp.asarray(theta)))
    return h, {"kv": kv}


# ---------------------------------------------------------------------------
# Decode (one token, KV cache; flash-decoding scan over cache chunks)
# ---------------------------------------------------------------------------


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False) -> Dict[str, Any]:
    a = arch.attn
    L = arch.n_layers
    if not kv_quant:
        kv = ParamSpec((L, batch, seq, a.num_kv_heads, a.head_dim),
                       ("layers", "batch", "cache_seq", "kv_heads", None),
                       jnp.bfloat16, "zeros")
        return {"k": kv, "v": kv}
    mq = arch.kv_quant.m_bytes
    kq = arch.kv_quant.codebook_size
    codes = ParamSpec((L, batch, seq, a.num_kv_heads, mq),
                      ("layers", "batch", "cache_seq", "kv_heads", None),
                      jnp.uint8, "zeros")
    cb = ParamSpec((L, a.num_kv_heads, mq, kq, a.head_dim),
                   ("layers", "kv_heads", None, None, None),
                   jnp.bfloat16, "normal")
    return {"k_codes": codes, "v_codes": codes, "k_cb": cb, "v_cb": cb}


def _dequant_chunk(codes, cb):
    """codes: (B, ch, KVH, Mq) uint8; cb: (KVH, Mq, Kq, D) -> (B, ch, KVH, D).

    One-hot matmul (MXU-friendly) rather than gather — see DESIGN.md §3.
    """
    kq = cb.shape[2]
    onehot = jax.nn.one_hot(codes, kq, dtype=cb.dtype)
    return jnp.einsum("bthmk,hmkd->bthd", onehot, cb)


def _rq_encode_vec(x, cb):
    """Greedy RQ encode. x: (..., KVH, D); cb: (KVH, Mq, Kq, D) -> codes uint8."""
    mq = cb.shape[1]
    r = x.astype(jnp.float32)

    def step(r, m):
        c = cb[:, m].astype(jnp.float32)             # (KVH, Kq, D)
        d2 = (jnp.sum(r * r, -1)[..., None]
              - 2.0 * jnp.einsum("...hd,hkd->...hk", r, c)
              + jnp.sum(c * c, -1))
        idx = jnp.argmin(d2, axis=-1)
        sel = jnp.einsum("...hk,hkd->...hd",
                         jax.nn.one_hot(idx, c.shape[1], dtype=jnp.float32), c)
        return r - sel, idx.astype(jnp.uint8)

    codes = []
    for m in range(mq):
        r, idx = step(r, m)
        codes.append(idx)
    return jnp.stack(codes, axis=-1)


def decode_layer(p, cache_slice, x, pos, arch: ArchConfig, ctx: ShardCtx, *,
                 window, theta, kv_quant: bool, skip_mlp: bool = False):
    """x: (B, 1, d). Returns (x_out, updated cache_slice)."""
    a = arch.attn
    B = x.shape[0]
    h = cm.rms_norm(x, p["ln1"], arch.norm_eps)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p["attn"], h, arch, positions, theta)
    G = a.num_heads // a.num_kv_heads
    qg = q.reshape(B, a.num_kv_heads, G, a.head_dim)

    if not kv_quant:
        k_cache = lax.dynamic_update_slice_in_dim(
            cache_slice["k"], k_new.astype(cache_slice["k"].dtype), pos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            cache_slice["v"], v_new.astype(cache_slice["v"].dtype), pos, axis=1)
        new_cache = {"k": k_cache, "v": v_cache}
        T = k_cache.shape[1]
        chunk_len = min(2048, T)
        nchunks = T // chunk_len

        def chunks(i):
            sl = lambda c: lax.dynamic_slice_in_dim(c, i * chunk_len,
                                                    chunk_len, axis=1)
            return sl(k_cache), sl(v_cache)
    else:
        kc = _rq_encode_vec(k_new[:, 0], cache_slice["k_cb"])
        vc = _rq_encode_vec(v_new[:, 0], cache_slice["v_cb"])
        k_codes = lax.dynamic_update_slice_in_dim(
            cache_slice["k_codes"], kc[:, None], pos, axis=1)
        v_codes = lax.dynamic_update_slice_in_dim(
            cache_slice["v_codes"], vc[:, None], pos, axis=1)
        new_cache = dict(cache_slice, k_codes=k_codes, v_codes=v_codes)
        T = k_codes.shape[1]
        chunk_len = min(2048, T)
        nchunks = T // chunk_len

        def chunks(i):
            slk = lax.dynamic_slice_in_dim(k_codes, i * chunk_len, chunk_len, 1)
            slv = lax.dynamic_slice_in_dim(v_codes, i * chunk_len, chunk_len, 1)
            return (_dequant_chunk(slk, cache_slice["k_cb"]),
                    _dequant_chunk(slv, cache_slice["v_cb"]))

    # window is a traced per-layer int32 (1<<30 encodes "no window")
    out = cm.decode_attention(qg, chunks, nchunks, chunk_len, pos + 1,
                              window=window)
    out = out.reshape(B, 1, a.num_heads, a.head_dim)
    attn_out = jnp.einsum("bshk,hkd->bsd", out,
                          p["attn"]["wo"].astype(x.dtype))
    x = x + attn_out
    if skip_mlp:
        return x, new_cache
    h = cm.rms_norm(x, p["ln2"], arch.norm_eps)
    x = x + cm.gated_mlp(h, p["mlp"]["gate"], p["mlp"]["up"],
                         p["mlp"]["down"], ctx)
    return x, new_cache


def decode_step(params, cache, h, pos, arch: ArchConfig, ctx: ShardCtx, *,
                kv_quant: bool = False):
    """h: (B, 1, d) embedded token. Scans layers; cache arrays are stacked
    with a leading layer dim and fed through scan as both xs and ys."""
    win, theta = layer_windows(arch)

    def body(x, xs):
        lp, cache_slice, w, th = xs
        x, new_slice = decode_layer(lp, cache_slice, x, pos, arch, ctx,
                                    window=w, theta=th, kv_quant=kv_quant)
        return x, new_slice

    h, new_cache = lax.scan(body, h,
                            (params["layers"], cache, jnp.asarray(win),
                             jnp.asarray(theta)))
    return h, new_cache
